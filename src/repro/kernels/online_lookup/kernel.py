"""Pallas TPU kernel: online-store GET over a hash-partitioned key table.

The paper's online store is Redis; its GET is a pointer-chasing hash probe —
a latency primitive with no TPU analogue (no fine-grained random access from
vector units).  The TPU-native design applies the paper's own storage-
partitioning idea (§4.5) to the device: the key space is hash-partitioned
into P shards; a batch of queries is routed (host/XLA side) to its shard;
the kernel then resolves each shard's queries against the shard's slots with
a broadcast compare-match — an O(C/P) streaming scan per query batch at full
lane width instead of O(1) serial probes.  For managed-store shard sizes
(C/P slots fitting VMEM) one sweep resolves every query in the shard.

Keys are int64 IDs split into two int32 planes (TPU vector compare is 32-bit
native); a match requires both planes to agree.

Grid: (partition, slot-block), slot minor/sequential; scratch keeps the best
(1-based) slot per query, 0 = not found.

Device-resident contract (core/online_store.py): the key planes live on
device across calls — the store passes the same jax arrays every GET, so the
only per-call traffic is the routed queries up and the (P, Q) slot indices
down.  Value/timestamp rows are then fetched at those slots by
``ops.gather_rows``; the kernel itself never touches the value planes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["lookup_kernel_call"]


def _lookup_kernel(qlo_ref, qhi_ref, klo_ref, khi_ref, out_ref, best_ref):
    cb = pl.program_id(1)
    n_cb = pl.num_programs(1)

    @pl.when(cb == 0)
    def _init():
        best_ref[...] = jnp.zeros_like(best_ref)

    klo = klo_ref[...]  # (1, Cb)
    khi = khi_ref[...]
    qlo = qlo_ref[...]  # (1, Q)
    qhi = qhi_ref[...]

    cblk = klo.shape[1]
    base = cb * cblk
    slot = base + jax.lax.broadcasted_iota(jnp.int32, (1, cblk), 1)

    # (Q, Cb) compare-match on both 32-bit planes.
    match = (klo == qlo.T) & (khi == qhi.T)
    scored = jnp.where(match, slot + 1, 0)  # 1-based, 0 = miss
    best_ref[...] = jnp.maximum(best_ref[...], scored.max(axis=1)[:, None])

    @pl.when(cb == n_cb - 1)
    def _write():
        out_ref[...] = best_ref[...].T - 1  # back to 0-based/-1


@functools.partial(jax.jit, static_argnames=("slot_block", "interpret"))
def lookup_kernel_call(
    keys_lo: jnp.ndarray,
    keys_hi: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
    *,
    slot_block: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    """keys_* (P, C) int32, q_* (P, Q) int32 -> slot idx (P, Q) int32 (-1 miss).

    C % slot_block == 0 and Q lane-padded are ops.py's responsibility.
    """
    p, c = keys_lo.shape
    _, q = q_lo.shape
    if c % slot_block:
        raise ValueError("C must be a multiple of slot_block")
    grid = (p, c // slot_block)
    return pl.pallas_call(
        _lookup_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q), lambda pb, cb: (pb, 0)),
            pl.BlockSpec((1, q), lambda pb, cb: (pb, 0)),
            pl.BlockSpec((1, slot_block), lambda pb, cb: (pb, cb)),
            pl.BlockSpec((1, slot_block), lambda pb, cb: (pb, cb)),
        ],
        out_specs=pl.BlockSpec((1, q), lambda pb, cb: (pb, 0)),
        out_shape=jax.ShapeDtypeStruct((p, q), jnp.int32),
        scratch_shapes=[pltpu.VMEM((q, 1), jnp.int32)],
        interpret=interpret,
    )(q_lo, q_hi, keys_lo, keys_hi)
