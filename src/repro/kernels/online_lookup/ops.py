"""jit'd wrapper + host routing for the online-lookup kernel.

The online store (core/online_store.py) keeps its device mirror in the
partitioned layout this kernel expects.  This module provides:

  * ``split_i64`` / ``partition_of`` — the shared hashing/key-splitting
    helpers (numpy, host-side) so the store and the kernel agree bit-for-bit.
  * ``lookup`` — the jit'd kernel wrapper over pre-routed (P, Q) queries.
    Passing device-RESIDENT key planes (jax arrays) makes this transfer-free
    on the table side: only the routed queries go up and the (P, Q) slot
    indices come back — O(batch), never O(P·C).
  * ``gather_rows`` — the resident GET's second half: fetch feature rows and
    creation_ts planes at resolved (part, slot) coords on device, so a
    lookup returns (B, D) + (B,) arrays without the host ever holding the
    value planes.
  * ``route_and_lookup`` — host-side convenience: route a flat id batch to
    partitions, pad, run the kernel, gather values, un-permute.  Used by the
    host-mirror path and tests; the store's kernel GET composes the resident
    pieces instead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.online_lookup.kernel import lookup_kernel_call

__all__ = [
    "split_i64",
    "combine_i64",
    "partition_of",
    "gather_rows",
    "lookup",
    "pow2_bucket",
    "route_and_lookup",
    "route_flat",
    "route_queries",
]

_LANE = 128
_MIX = np.uint64(0x9E3779B97F4A7C15)


def pow2_bucket(n: int, floor: int = _LANE) -> int:
    """Round a host-side length up to a power of two (>= ``floor``) — the ONE
    shape-bucketing rule every jitted device op on the GET/merge path uses, so
    a stream of varying batch sizes maps to a small fixed set of compiled
    entries instead of re-tracing per size (log2 buckets, not one per
    routing high-water mark)."""
    b = floor
    while b < n:
        b *= 2
    return b


def split_i64(ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """int64 -> (lo, hi) int32 planes (two's-complement faithful)."""
    u = np.asarray(ids, dtype=np.int64).view(np.uint64)
    lo = (u & np.uint64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)
    hi = (u >> np.uint64(32)).astype(np.uint32).view(np.int32)
    return lo, hi


def combine_i64(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """(lo, hi) int32 planes -> int64 (inverse of ``split_i64``)."""
    u = np.asarray(lo).view(np.uint32).astype(np.uint64) | (
        np.asarray(hi).view(np.uint32).astype(np.uint64) << np.uint64(32)
    )
    return u.view(np.int64)


def partition_of(ids: np.ndarray, num_partitions: int) -> np.ndarray:
    """Fibonacci-hash partition routing (identical for store + queries)."""
    u = np.asarray(ids, dtype=np.int64).view(np.uint64)
    mixed = (u * _MIX) >> np.uint64(33)
    if num_partitions & (num_partitions - 1) == 0:
        # power-of-two partition counts (the default) take the cheap mask;
        # uint64 modulo costs ~2.5ms per 100k keys on its own
        return (mixed & np.uint64(num_partitions - 1)).view(np.int64)
    return (mixed % np.uint64(num_partitions)).astype(np.int64)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def route_flat(
    num_partitions: int, ids: np.ndarray, *payloads: np.ndarray
) -> tuple[np.ndarray, ...]:
    """Vectorized flat->routed scatter shared by the lookup and merge paths.

    ids (B,) -> (routed_ids (P, Qmax) int64 with -2 padding, part (B,),
    pos (B,) [each row's slot within its partition], *routed payloads
    (P, Qmax, ...) zero-padded).
    """
    b = len(ids)
    part = partition_of(ids, num_partitions)
    counts = np.bincount(part, minlength=num_partitions)
    q_max = max(int(counts.max()) if b else 0, 1)
    order = np.argsort(part, kind="stable")
    ps = part[order]
    # rank of each row within its partition's contiguous block
    pos_sorted = np.arange(b) - np.searchsorted(ps, ps)
    pos = np.empty(b, np.int64)
    pos[order] = pos_sorted
    routed_ids = np.full((num_partitions, q_max), -2, np.int64)
    routed_ids[part, pos] = ids
    out = [routed_ids, part, pos]
    for payload in payloads:
        shape = (num_partitions, q_max) + payload.shape[1:]
        r = np.zeros(shape, payload.dtype)
        r[part, pos] = payload
        out.append(r)
    return tuple(out)


@functools.partial(jax.jit, static_argnames=("slot_block", "interpret"))
def lookup(
    keys_lo: jnp.ndarray,
    keys_hi: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
    *,
    slot_block: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pre-routed lookup.  keys (P, C), queries (P, Q) -> slots (P, Q)."""
    p, c = keys_lo.shape
    c_pad = _round_up(c, min(slot_block, _round_up(c, _LANE)))
    sb = min(slot_block, c_pad)
    c_pad = _round_up(c_pad, sb)
    if c_pad != c:
        pad = jnp.full((p, c_pad - c), -1, jnp.int32)
        keys_lo = jnp.concatenate([keys_lo, pad], axis=1)
        keys_hi = jnp.concatenate([keys_hi, pad], axis=1)
    q = q_lo.shape[1]
    q_pad = _round_up(q, _LANE)
    if q_pad != q:
        # pad with (-2, -2): matches neither live keys (>=0 planes possible)
        # nor the empty sentinel (-1, -1).
        padq = jnp.full((p, q_pad - q), -2, jnp.int32)
        q_lo = jnp.concatenate([q_lo, padq], axis=1)
        q_hi = jnp.concatenate([q_hi, padq], axis=1)
    out = lookup_kernel_call(
        keys_lo, keys_hi, q_lo, q_hi, slot_block=sb, interpret=interpret
    )
    return out[:, :q]


def route_queries(
    num_partitions: int, ids: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Route a flat id batch into kernel-ready (P, Q) query planes.

    Returns (q_lo, q_hi, part, pos): int32 planes padded host-side to a
    power-of-two lane bucket (``pow2_bucket``) with every pad entry stamped
    to the (-2, -2) sentinel — the ONE place that invariant lives: pads must
    match neither live keys (split planes can be anything >= 0) nor the
    empty-slot sentinel (-1, -1).  Power-of-two (not next-multiple-of-128)
    padding matters for the serving path: the routing high-water mark
    jitters run-to-run with key imbalance, and at large coalesced batches a
    128-granular pad would straddle bucket boundaries and re-trace the
    jitted kernel per batch; log2 buckets make repeated same-scale GETs hit
    the same compiled entry.  ``part``/``pos`` un-permute kernel results
    back to batch order."""
    routed_ids, part, pos = route_flat(num_partitions, ids)[:3]
    qmax = routed_ids.shape[1]
    qpad = pow2_bucket(qmax)
    if qpad != qmax:
        routed_ids = np.concatenate(
            [routed_ids, np.full((num_partitions, qpad - qmax), -2, np.int64)],
            axis=1,
        )
    q_lo, q_hi = split_i64(routed_ids)
    pad = routed_ids == -2
    q_lo[pad] = -2
    q_hi[pad] = -2
    return q_lo, q_hi, part, pos


@jax.jit
def gather_rows(
    values: jnp.ndarray,
    cr_lo: jnp.ndarray,
    cr_hi: jnp.ndarray,
    part: jnp.ndarray,
    slot: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Resident gather: (part, slot) (B,) int32 coords -> feature rows
    (B, D) f32 + creation_ts planes (B,) int32.  Misses should be clamped
    to slot 0 by the caller and masked after; the creation planes feed the
    TTL check so expiry never needs the host timestamp mirror."""
    return values[part, slot], cr_lo[part, slot], cr_hi[part, slot]


def route_and_lookup(
    keys_lo: np.ndarray,
    keys_hi: np.ndarray,
    values: np.ndarray,
    ids: np.ndarray,
    *,
    interpret: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Flat query path: ids (B,) int64 against table (P, C) + values (P, C, D).

    Returns (values (B, D) float32 — zeros where missing, found (B,) bool).
    """
    num_p, cap = keys_lo.shape
    ids = np.asarray(ids, dtype=np.int64)
    b = len(ids)
    if b == 0:
        return np.zeros((0, values.shape[-1]), np.float32), np.zeros((0,), bool)
    q_lo, q_hi, part, slot_in_part = route_queries(num_p, ids)

    slots = np.asarray(
        lookup(
            jnp.asarray(keys_lo),
            jnp.asarray(keys_hi),
            jnp.asarray(q_lo),
            jnp.asarray(q_hi),
            interpret=interpret,
        )
    )
    got = slots[part, slot_in_part]
    found = got >= 0
    out = np.zeros((b, values.shape[-1]), np.float32)
    if found.any():
        out[found] = values[part[found], got[found]]
    return out, found
