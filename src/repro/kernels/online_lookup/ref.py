"""Pure-jnp oracle for partitioned online-store lookup.

Table layout: keys split into int32 (lo, hi) planes, shape (P, C) each —
P hash partitions of C slots.  Empty slots hold (-1, -1); live IDs are
non-negative int64 so the sentinel is unambiguous.  Queries arrive already
routed to their partition: q_lo/q_hi (P, Q).  Result: slot index in [0, C)
or -1 when absent.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["lookup_ref"]


def lookup_ref(
    keys_lo: jnp.ndarray,
    keys_hi: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
) -> jnp.ndarray:
    # match[p, q, c]
    match = (keys_lo[:, None, :] == q_lo[:, :, None]) & (
        keys_hi[:, None, :] == q_hi[:, :, None]
    )
    c = keys_lo.shape[1]
    scored = jnp.where(match, jnp.arange(c)[None, None, :] + 1, 0)
    return scored.max(axis=2).astype(jnp.int32) - 1
