"""jit'd wrapper: GQA layout handling + padding for the flash kernel.

``flash_attention(q, k, v)`` takes model-layout tensors
(B, S, H, D) x (B, T, KV, D): expands KV heads to H (GQA), flattens
(B, H) -> N, pads S/T to block multiples (padded k rows are masked by
causality for the tail; padded q rows are dropped on return), and calls
the kernel.  The analytic HBM-traffic model used by the roofline's
"with-flash" adjusted memory term lives here too (``flash_bytes``), so the
claim and the implementation sit next to each other.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_kernel_call

__all__ = ["flash_attention", "flash_bytes"]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "causal", "interpret")
)
def flash_attention(
    q: jnp.ndarray,   # (B, S, H, D)
    k: jnp.ndarray,   # (B, T, KV, D)
    v: jnp.ndarray,
    *,
    block_q: int = 512,
    block_k: int = 512,
    causal: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv

    bq = min(block_q, _round_up(s, 8))
    bk = min(block_k, _round_up(t, 8))
    s_pad = _round_up(s, bq)
    t_pad = _round_up(t, bk)

    # GQA expand + flatten to (N, S, D)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)
    kf = jnp.repeat(jnp.moveaxis(k, 2, 1), g, axis=1).reshape(b * h, t, d)
    vf = jnp.repeat(jnp.moveaxis(v, 2, 1), g, axis=1).reshape(b * h, t, d)

    if s_pad != s:
        qf = jnp.pad(qf, ((0, 0), (0, s_pad - s), (0, 0)))
    if t_pad != t:
        # pad keys so padded positions can never win the max: kernel masks
        # ki > qi for causal; for non-causal we mask via a -inf v trick is
        # wrong, so pad K with zeros and rely on explicit masking below.
        kf = jnp.pad(kf, ((0, 0), (0, t_pad - t), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, t_pad - t), (0, 0)))
        if not causal:
            raise NotImplementedError("non-causal padding path unused")

    out = flash_attention_kernel_call(
        qf, kf, vf, block_q=bq, block_k=bk, causal=causal, interpret=interpret
    )
    out = out[:, :s].reshape(b, h, s, d)
    return jnp.moveaxis(out, 1, 2)  # (B, S, H, D)


def flash_bytes(b: int, s: int, t: int, h: int, kv: int, d: int,
                *, dtype_bytes: int = 2, block_k: int = 512) -> int:
    """Analytic HBM traffic of the flash forward: Q read once, K/V streamed
    once per q-block row of the grid, O written once.  This is the number
    the §Roofline 'with-flash' adjusted memory term substitutes for the
    measured XLA score traffic."""
    q_bytes = b * h * s * d * dtype_bytes
    o_bytes = q_bytes
    n_q_blocks = max(1, s // block_k)
    kv_bytes = 2 * b * kv * t * d * dtype_bytes * n_q_blocks
    return q_bytes + o_bytes + kv_bytes
