"""Pure-jnp oracle for causal GQA attention (the flash kernel's ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jnp.ndarray,      # (B, S, H, D)
    k: jnp.ndarray,      # (B, T, KV, D)
    v: jnp.ndarray,      # (B, T, KV, D)
    *,
    causal: bool = True,
) -> jnp.ndarray:
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, d)
