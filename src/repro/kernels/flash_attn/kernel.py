"""Pallas TPU kernel: causal GQA flash attention (forward).

The §Roofline tables show attention score materialization dominating the
memory term on every *_4k/32k train/prefill cell — (B, H, S, S) fp32 blocks
bounced through HBM dozens of times by unfused elementwise chains.  The
flash formulation keeps each (block_q, block_k) score tile in VMEM with
running (max, sum, acc) carries; HBM traffic falls from O(S²) to O(S·D).

TPU mapping:
  grid = (batch·kv_heads·q_groups, num_q_blocks, num_k_blocks), k minor —
  the sequential minor axis lets VMEM scratch (m, l, acc) carry across
  k-blocks of one q-block (same accumulator pattern as our rolling_agg
  kernel's history carry).
  Blocks are (block_q, head_dim) x (block_k, head_dim) — MXU-shaped tiles;
  head_dim is the lane dim (128-friendly for every assigned arch except
  gemma's 256, which tiles as 2x128 lanes transparently).
  Causality: k-blocks strictly above the diagonal are skipped via
  ``pl.when`` (they produce no useful work; the index map still visits
  them — Pallas grids are dense — but the body cost is one predicate).

The backward pass uses the same tiling with recomputed probabilities
(standard flash-bwd); this repo ships the forward kernel + XLA backward
(see ops.py) — the §Perf adjusted-memory analysis only claims the forward
savings.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, causal: bool, scale: float):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    n_kb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qb * block_q
    k_start = kb * block_k

    # causal: skip blocks entirely above the diagonal
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                          # (bq, bk)
        if causal:
            qi = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            ki = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(ki <= qi, s, NEG_INF)
        m_prev = m_ref[...]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                             # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, v, precision=jax.lax.Precision.DEFAULT
        )
        m_ref[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _write():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "causal", "interpret"),
)
def flash_attention_kernel_call(
    q: jnp.ndarray,   # (N, S, D)  N = batch*heads (flattened by ops.py)
    k: jnp.ndarray,   # (N, T, D)  already GQA-expanded to N by ops.py
    v: jnp.ndarray,
    *,
    block_q: int = 512,
    block_k: int = 512,
    causal: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    n, s, d = q.shape
    t = k.shape[1]
    if s % block_q or t % block_k:
        raise ValueError("ops.py must pad S/T to block multiples")
    scale = 1.0 / (d ** 0.5)
    grid = (n, s // block_q, t // block_k)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda n_, qb, kb: (n_, qb, 0)),
            pl.BlockSpec((1, block_k, d), lambda n_, qb, kb: (n_, kb, 0)),
            pl.BlockSpec((1, block_k, d), lambda n_, qb, kb: (n_, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda n_, qb, kb: (n_, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
