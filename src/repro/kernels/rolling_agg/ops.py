"""jit'd public wrapper around the rolling-window aggregation kernel.

Handles everything the raw kernel does not: feature-dim padding to lane
multiples, row padding to block multiples, span bucketing (the kernel needs a
static history depth >= the maximum window row-span), and the derived
aggregations (count is closed-form; mean = sum / count; min/max fall back to
an XLA segment formulation — the prefix trick does not apply to them, which we
document rather than hide).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rolling_agg.kernel import rolling_sum_kernel_call
from repro.kernels.rolling_agg import ref as ref_mod

__all__ = ["rolling_sum", "rolling_sum_xla", "rolling_agg", "window_starts"]

_LANE = 128
_DEFAULT_BLOCK = 256


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def window_starts(
    segment_ids: np.ndarray, timestamps: np.ndarray, window: int
) -> np.ndarray:
    """Host-side window-start computation (rows sorted by (segment, ts)).

    Window semantics: row j is in row i's window iff same segment and
    ``ts_i - window < ts_j <= ts_i``.  Uses a composite monotone key so one
    global vectorized searchsorted handles every segment at once.
    """
    segment_ids = np.asarray(segment_ids, dtype=np.int64)
    timestamps = np.asarray(timestamps, dtype=np.int64)
    if len(segment_ids) == 0:
        return np.zeros((0,), dtype=np.int32)
    t0 = timestamps.min()
    rebased = timestamps - t0
    span = int(rebased.max()) + 2
    key = segment_ids * span + rebased
    if not np.all(np.diff(key) >= 0):
        raise ValueError("rows must be sorted by (segment, timestamp)")
    q = segment_ids * span + np.maximum(rebased - window, -1)
    starts = np.searchsorted(key, q, side="right")
    return starts.astype(np.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "hist", "interpret"))
def rolling_sum(
    values: jnp.ndarray,
    starts: jnp.ndarray,
    *,
    block_rows: int = _DEFAULT_BLOCK,
    hist: int = _DEFAULT_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    """Rolling-window sum.  values (N, F); starts (N,) int32; spans <= hist.

    Returns float32 (N, F).  Padding: rows to block multiple (pad rows use
    start=index so their window is empty+self over zero values), features to
    the 128-lane multiple.
    """
    n, feat = values.shape
    n_pad = _round_up(max(n, 1), block_rows)
    f_pad = _round_up(max(feat, 1), _LANE)
    vals_p = jnp.zeros((n_pad, f_pad), values.dtype)
    vals_p = vals_p.at[:n, :feat].set(values)
    starts_p = jnp.arange(n_pad, dtype=jnp.int32)
    starts_p = starts_p.at[:n].set(starts.astype(jnp.int32))
    out = rolling_sum_kernel_call(
        vals_p, starts_p, block_rows=block_rows, hist=hist, interpret=interpret
    )
    return out[:n, :feat]


@jax.jit
def rolling_sum_xla(values: jnp.ndarray, starts: jnp.ndarray) -> jnp.ndarray:
    """O(N·F) prefix-difference on the XLA path (no Pallas): the same
    P[i+1]-P[starts[i]] identity the kernel uses, via cumsum + gather.
    Long-column catastrophic cancellation is why the Pallas kernel re-zeroes
    its prefix every block (kernel.py) — this fallback accepts fp32 drift."""
    v = values.astype(jnp.float32)
    p_inc = jnp.cumsum(v, axis=0)
    p_exc = jnp.concatenate([jnp.zeros((1, v.shape[1]), v.dtype), p_inc], axis=0)
    ends = p_exc[1 + jnp.arange(values.shape[0])]
    return (ends - p_exc[starts]).astype(jnp.float32)


def _pick_hist(max_span: int, block_rows: int) -> int:
    """Static history depth: next power-of-two multiple of 8 covering the
    span, so recompilation is bounded to O(log(max span)) variants."""
    h = 8
    while h < max_span:
        h *= 2
    return max(h, 8)


def rolling_agg(
    values: jnp.ndarray,
    starts: np.ndarray,
    agg: str,
    *,
    block_rows: int = _DEFAULT_BLOCK,
    interpret: bool = True,
    backend: str = "pallas",
) -> jnp.ndarray:
    """Public entry used by the DSL executor.  ``starts`` must be host-side
    (numpy) — the DSL computes it from store-resident timestamps — which lets
    us pick the static history bucket and validate spans eagerly.

    backend: 'pallas' (TPU target; interpret=True on CPU) or 'xla' (the
    cumsum fallback — what a mesh without the kernel would run)."""
    starts = np.asarray(starts)
    n = values.shape[0]
    if n == 0:
        return jnp.zeros((0, values.shape[1]), jnp.float32)
    spans = np.arange(n) + 1 - starts
    if (spans <= 0).any():
        raise ValueError("window starts must satisfy starts[i] <= i")
    max_span = int(spans.max())

    if agg == "count":
        cnt = jnp.asarray(spans, dtype=jnp.float32)
        return jnp.broadcast_to(cnt[:, None], values.shape).astype(jnp.float32)

    if agg in ("sum", "mean"):
        hist = _pick_hist(max_span, block_rows)
        if backend == "xla":
            s = rolling_sum_xla(values, jnp.asarray(starts, jnp.int32))
        elif hist > 4096:
            # Span too deep for a VMEM history buffer: stay on the XLA
            # path rather than claim an unrealistic VMEM footprint.
            s = rolling_sum_xla(values, jnp.asarray(starts, jnp.int32))
        else:
            s = rolling_sum(
                values,
                jnp.asarray(starts, dtype=jnp.int32),
                block_rows=block_rows,
                hist=hist,
                interpret=interpret,
            )
        if agg == "sum":
            return s
        cnt = jnp.asarray(spans, dtype=jnp.float32)[:, None]
        return s / jnp.maximum(cnt, 1.0)

    if agg in ("min", "max"):
        # Prefix-difference does not apply to min/max; use the jnp oracle
        # formulation (XLA lowers this as masked reductions).
        return ref_mod.rolling_agg_ref(values, jnp.asarray(starts), agg)

    raise ValueError(f"unknown agg {agg!r}")
