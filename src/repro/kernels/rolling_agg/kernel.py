"""Pallas TPU kernel: rolling-window sum via MXU prefix + one-hot gather.

TPU adaptation of the paper's §3.1.6 DSL-optimized rolling aggregation.  A
Spark implementation shuffles rows into windows; on TPU we exploit two
hardware facts instead:

  1. The Pallas grid is *sequential*, so a VMEM scratch buffer can carry the
     trailing ``hist`` rows across row-blocks (flash-attention-style carry).
  2. Prefix sums and gathers both lower to MXU matmuls: the inclusive prefix
     is ``L @ ext`` with a lower-triangular ones matrix, and the per-row
     window start gather is ``one_hot(rel_idx) @ P``.

For a block of B rows with window spans bounded by H rows, the window sum is

    out[i] = P[i+1] - P[starts[i]]          (exclusive prefix P over hist+cur)

and both terms only need the *local* prefix over the (H + B)-row extended
block — the contribution of everything before the history window cancels in
the difference, so no global carry is required.

Grid: 1-D over row blocks.  VMEM working set per step:
  ext (H+B, F) f32 + L (H+B, H+B) f32 + one-hot (B, H+B+1) f32
e.g. H=B=256, F=128: 0.26 MB + 1.0 MB + 0.5 MB — comfortably in 16 MB VMEM,
with MXU-aligned shapes (multiples of (8, 128) after ops.py padding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rolling_sum_kernel_call"]


def _rolling_sum_kernel(starts_ref, vals_ref, out_ref, hist_ref, *, hist: int):
    b = pl.program_id(0)
    blk, feat = vals_ref.shape

    @pl.when(b == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    cur = vals_ref[...].astype(jnp.float32)            # (B, F)
    ext = jnp.concatenate([hist_ref[...], cur], axis=0)  # (H+B, F)
    m = hist + blk

    # Inclusive prefix via lower-triangular MXU matmul: P_inc[k] = sum ext[:k+1].
    row = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    lower = (col <= row).astype(jnp.float32)           # (H+B, H+B)
    p_inc = jax.lax.dot(lower, ext, precision=jax.lax.Precision.HIGHEST)
    # Exclusive prefix P, shape (H+B+1, F): P[0] = 0, P[k] = sum ext[:k].
    p_exc = jnp.concatenate([jnp.zeros((1, feat), jnp.float32), p_inc], axis=0)

    # Window end term: P[i+1] in extended coordinates = P_exc[H + j + 1].
    ends = p_exc[hist + 1 : hist + blk + 1, :]         # (B, F), static slice

    # Window start term: gather P_exc at rel = starts - (b*B - H), via one-hot
    # matmul (the TPU-native dynamic gather).
    starts = starts_ref[...].reshape(blk)              # (B,) int32
    rel = starts - b * blk + hist                      # in [0, H+B)
    onehot = (
        rel[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (blk, m + 1), 1)
    ).astype(jnp.float32)                              # (B, H+B+1)
    gathered = jax.lax.dot(onehot, p_exc, precision=jax.lax.Precision.HIGHEST)

    out_ref[...] = ends - gathered

    # Carry the trailing H rows of raw values into the next block.
    hist_ref[...] = ext[blk : blk + hist, :]


@functools.partial(jax.jit, static_argnames=("block_rows", "hist", "interpret"))
def rolling_sum_kernel_call(
    values: jnp.ndarray,
    starts: jnp.ndarray,
    *,
    block_rows: int = 256,
    hist: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """values: (N, F) with N % block_rows == 0 and window spans <= hist.

    ops.py is responsible for padding/alignment and span checking; this is the
    raw pallas_call wrapper.
    """
    n, feat = values.shape
    if n % block_rows:
        raise ValueError(f"N={n} not a multiple of block_rows={block_rows}")
    if hist < block_rows and hist % 8:
        raise ValueError("hist must be 8-aligned")
    grid = (n // block_rows,)
    kernel = functools.partial(_rolling_sum_kernel, hist=hist)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda b: (b, 0)),   # starts
            pl.BlockSpec((block_rows, feat), lambda b: (b, 0)),  # values
        ],
        out_specs=pl.BlockSpec((block_rows, feat), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((n, feat), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hist, feat), jnp.float32)],
        interpret=interpret,
    )(starts.reshape(n, 1).astype(jnp.int32), values)
