"""Pure-jnp oracle for rolling-window aggregation.

``out[i] = agg(values[starts[i] : i+1])`` — the window is a contiguous row
span ending at row ``i`` (rows are sorted by (entity, timestamp) upstream; the
DSL layer computes ``starts`` so windows never cross entity boundaries).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rolling_sum_ref", "rolling_agg_ref"]


def rolling_sum_ref(values: jnp.ndarray, starts: jnp.ndarray) -> jnp.ndarray:
    """O(N^2) but trivially correct: masked sum per row.

    values: (N, F) float; starts: (N,) int32.  Returns (N, F) float32.
    """
    n = values.shape[0]
    idx = jnp.arange(n)
    # mask[i, j] = starts[i] <= j <= i
    mask = (idx[None, :] >= starts[:, None]) & (idx[None, :] <= idx[:, None])
    return mask.astype(jnp.float32) @ values.astype(jnp.float32)


def rolling_agg_ref(values: jnp.ndarray, starts: jnp.ndarray, agg: str) -> jnp.ndarray:
    """Oracle for every agg the DSL exposes (sum/mean/count/min/max)."""
    n, _ = values.shape
    idx = jnp.arange(n)
    mask = (idx[None, :] >= starts[:, None]) & (idx[None, :] <= idx[:, None])
    v32 = values.astype(jnp.float32)
    if agg == "sum":
        return mask.astype(jnp.float32) @ v32
    if agg == "count":
        cnt = (idx + 1 - starts).astype(jnp.float32)
        return jnp.broadcast_to(cnt[:, None], values.shape).astype(jnp.float32)
    if agg == "mean":
        s = mask.astype(jnp.float32) @ v32
        cnt = (idx + 1 - starts).astype(jnp.float32)[:, None]
        return s / jnp.maximum(cnt, 1.0)
    if agg == "min":
        big = jnp.where(mask[:, :, None], v32[None, :, :], jnp.inf)
        return jnp.min(big, axis=1)
    if agg == "max":
        small = jnp.where(mask[:, :, None], v32[None, :, :], -jnp.inf)
        return jnp.max(small, axis=1)
    raise ValueError(f"unknown agg {agg!r}")
