"""Pallas TPU kernel: online-store latest-wins MERGE (Algorithm 2, §4.5).

Sibling of kernels/online_lookup: same hash-partitioned (P, C) slot layout,
same int64-as-two-int32-plane key codec, so the write path and the read path
share one device-resident table.  Where the lookup kernel answers "which slot
holds this key", the merge kernel answers "which slots must this batch
rewrite" — a broadcast compare-match followed by a masked compare-and-update:

  win[c, q] = key_match(c, q) AND (q.event_ts, q.creation_ts) >lex (slot c)

Each partition's routed batch is pre-reduced to ONE winner record per id
(ops/store responsibility), so at most one query wins any slot and the
update is a one-hot gather: timestamps via an integer masked sum, feature
rows via a 0/1 matmul against the (Q, D) routed values (MXU-friendly, exact
because each output row has exactly one contributing term).

Timestamps are int64 split into (lo, hi) int32 planes like keys; lexicographic
compare is signed on the hi plane, unsigned (sign-bit-flipped) on the lo
plane.  Callers routing fresh inserts through this scan must pre-stamp those
slots with INT64_MIN timestamps so any real record wins them (the resident
store path instead applies inserts via ops.merge_at_slots' ``is_new`` mask).

Grid: (partition, slot-block); queries + routed values stay resident per
partition while slot blocks stream through.

The table planes are ALIASED input->output (``input_output_aliases``): when
the caller's jit donates them (kernels/online_merge/ops.py does), the kernel
rewrites the planes in their existing device buffers instead of allocating
fresh outputs — the device-resident online store (core/online_store.py)
relies on this so a merge never materializes a second copy of the table.
Callers that retain references to the inputs still get value semantics (XLA
falls back to a defensive copy).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["i64_gt", "merge_kernel_call"]

def _u32_gt(a, b):
    """Unsigned > on int32 bit patterns (flip sign bit, compare signed)."""
    sign = jnp.asarray(-(2**31), dtype=jnp.int32)
    return (a ^ sign) > (b ^ sign)


def i64_gt(ahi, alo, bhi, blo):
    """(ahi, alo) > (bhi, blo) as int64: signed hi, unsigned lo.

    Public: the split-plane lexicographic compare is a cross-module contract
    — the Pallas scan kernel below and ops.merge_at_slots (the resident
    scatter path) must agree bit-for-bit on it."""
    return (ahi > bhi) | ((ahi == bhi) & _u32_gt(alo, blo))


def _merge_kernel(
    qlo_ref, qhi_ref, qelo_ref, qehi_ref, qv_ref, cr_ref,
    klo_ref, khi_ref, elo_ref, ehi_ref, clo_ref, chi_ref, v_ref,
    out_elo, out_ehi, out_clo, out_chi, out_v,
):
    qlo = qlo_ref[...]          # (1, Q)
    qhi = qhi_ref[...]
    qelo = qelo_ref[...]
    qehi = qehi_ref[...]
    klo = klo_ref[...].T        # (Cb, 1)
    khi = khi_ref[...].T
    elo = elo_ref[...].T
    ehi = ehi_ref[...].T
    clo = clo_ref[...].T
    chi = chi_ref[...].T
    crlo = cr_ref[0]            # scalars: batch creation_ts planes
    crhi = cr_ref[1]

    match = (klo == qlo) & (khi == qhi)                     # (Cb, Q)
    ev_gt = i64_gt(qehi, qelo, ehi, elo)
    ev_eq = (qehi == ehi) & (qelo == elo)
    cr_gt = i64_gt(crhi, crlo, chi, clo)                   # (Cb, 1)
    win = match & (ev_gt | (ev_eq & cr_gt))                 # (Cb, Q)

    any_win = win.any(axis=1, keepdims=True)                # (Cb, 1)
    wi = win.astype(jnp.int32)
    sel = lambda q: (wi * q).sum(axis=1, keepdims=True)     # one-hot gather

    out_elo[...] = jnp.where(any_win, sel(qelo), elo).T
    out_ehi[...] = jnp.where(any_win, sel(qehi), ehi).T
    out_clo[...] = jnp.where(any_win, crlo, clo).T
    out_chi[...] = jnp.where(any_win, crhi, chi).T

    qv = qv_ref[0]                                          # (Q, D)
    upd = jax.lax.dot_general(
        win.astype(jnp.float32), qv,
        dimension_numbers=(((1,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
    )                                                       # (Cb, D) exact
    out_v[0] = jnp.where(any_win, upd, v_ref[0])


@functools.partial(jax.jit, static_argnames=("slot_block", "interpret"))
def merge_kernel_call(
    keys_lo: jnp.ndarray,
    keys_hi: jnp.ndarray,
    ev_lo: jnp.ndarray,
    ev_hi: jnp.ndarray,
    cr_lo: jnp.ndarray,
    cr_hi: jnp.ndarray,
    values: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
    q_ev_lo: jnp.ndarray,
    q_ev_hi: jnp.ndarray,
    q_values: jnp.ndarray,
    creation_planes: jnp.ndarray,
    *,
    slot_block: int = 512,
    interpret: bool = True,
) -> tuple[jnp.ndarray, ...]:
    """Table planes (P, C) int32 + values (P, C, D) f32, routed winner
    queries (P, Q) int32 + values (P, Q, D), creation_planes (2,) int32
    [lo, hi] -> updated (ev_lo, ev_hi, cr_lo, cr_hi, values).

    C % slot_block == 0 and lane-padded Q/D are ops.py's responsibility;
    at most one query per partition may carry any given key.
    """
    p, c = keys_lo.shape
    _, q = q_lo.shape
    d = values.shape[-1]
    if c % slot_block:
        raise ValueError("C must be a multiple of slot_block")
    grid = (p, c // slot_block)
    tab = lambda: pl.BlockSpec((1, slot_block), lambda pb, cb: (pb, cb))
    qspec = lambda: pl.BlockSpec((1, q), lambda pb, cb: (pb, 0))
    out_shapes = (
        [jax.ShapeDtypeStruct((p, c), jnp.int32)] * 4
        + [jax.ShapeDtypeStruct((p, c, d), jnp.float32)]
    )
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        # ev_lo/ev_hi/cr_lo/cr_hi/values update in place when donated
        # (positions 8..12 of the operand list below -> outputs 0..4)
        input_output_aliases={8: 0, 9: 1, 10: 2, 11: 3, 12: 4},
        in_specs=[
            qspec(), qspec(), qspec(), qspec(),
            pl.BlockSpec((1, q, d), lambda pb, cb: (pb, 0, 0)),
            pl.BlockSpec((2,), lambda pb, cb: (0,)),
            tab(), tab(), tab(), tab(), tab(), tab(),
            pl.BlockSpec((1, slot_block, d), lambda pb, cb: (pb, cb, 0)),
        ],
        out_specs=[
            tab(), tab(), tab(), tab(),
            pl.BlockSpec((1, slot_block, d), lambda pb, cb: (pb, cb, 0)),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(
        q_lo, q_hi, q_ev_lo, q_ev_hi, q_values, creation_planes,
        keys_lo, keys_hi, ev_lo, ev_hi, cr_lo, cr_hi, values,
    )
