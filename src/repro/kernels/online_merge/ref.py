"""Pure-numpy oracle for the partitioned latest-wins merge.

Operates on the un-split int64 view of the table (event/creation ts as
int64, keys as int64), so the kernel's two-plane arithmetic is checked
against ordinary integer comparisons.  Queries arrive routed: ids (P, Q)
int64 with -2 padding (matches nothing), event_ts (P, Q), values (P, Q, D),
one scalar creation_ts per batch.
"""

from __future__ import annotations

import numpy as np

__all__ = ["merge_ref"]


def merge_ref(
    keys: np.ndarray,       # (P, C) int64, -1 empty
    event_ts: np.ndarray,   # (P, C) int64
    creation_ts: np.ndarray,  # (P, C) int64
    values: np.ndarray,     # (P, C, D) f32
    q_ids: np.ndarray,      # (P, Q) int64, -2 padding
    q_ev: np.ndarray,       # (P, Q) int64
    q_values: np.ndarray,   # (P, Q, D) f32
    batch_creation_ts: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns updated (event_ts, creation_ts, values); inputs untouched."""
    ev = event_ts.copy()
    cr = creation_ts.copy()
    vals = values.copy()
    p_n, q_n = q_ids.shape
    for p in range(p_n):
        for q in range(q_n):
            k = q_ids[p, q]
            if k < 0:
                continue
            slots = np.flatnonzero(keys[p] == k)
            for s in slots:
                if (q_ev[p, q], batch_creation_ts) > (ev[p, s], cr[p, s]):
                    ev[p, s] = q_ev[p, q]
                    cr[p, s] = batch_creation_ts
                    vals[p, s] = q_values[p, q]
    return ev, cr, vals
