"""jit'd wrappers + host routing for the online-merge write path.

Two device-side merge variants share the partitioned plane layout:

  * ``merge_at_slots`` — the DEVICE-RESIDENT hot path.  The store's sorted
    key index already resolved each winner record to its (partition, slot),
    so the compare-and-update is an O(batch) gather/lex-compare/scatter over
    donated planes (``donate_argnums``): the table buffers are rewritten in
    place, nothing table-sized crosses host<->device, and only the routed
    batch (coords + winner planes + feature rows) is uploaded.  The
    latest-wins decision itself still happens ON DEVICE — host tallies come
    from the merge plan and agree by construction — which is what makes the
    device planes a self-contained Algorithm-2 state machine (safe to replay
    for geo-replication).
  * ``merge`` / ``route_and_merge`` — the index-free streaming variant:
    route a flat per-id-winner batch to hash partitions, pad to lane shapes,
    split int64 ids/timestamps into int32 planes, and let the Pallas kernel
    broadcast-match every slot block (O(C·Q) scan).  Retained as the parity
    reference and for callers without a host-side slot index; its table
    planes are aliased input->output so it also updates in place when jitted
    with donation.

``gather_slot_ts`` is the read half of the resident protocol: fetch the
current (event_ts, creation_ts) planes at resolved coords so the host merge
plan can compute exact insert/override/no-op tallies against device truth
without pulling whole planes back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.online_lookup.ops import (
    combine_i64,
    route_flat,
    split_i64,
)
from repro.kernels.online_merge.kernel import i64_gt, merge_kernel_call

__all__ = [
    "gather_slot_ts",
    "merge",
    "merge_at_slots",
    "route_and_merge",
    "route_flat",
]

_LANE = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))
def merge_at_slots(
    keys_lo: jnp.ndarray,
    keys_hi: jnp.ndarray,
    ev_lo: jnp.ndarray,
    ev_hi: jnp.ndarray,
    cr_lo: jnp.ndarray,
    cr_hi: jnp.ndarray,
    values: jnp.ndarray,
    part: jnp.ndarray,
    slot: jnp.ndarray,
    q_klo: jnp.ndarray,
    q_khi: jnp.ndarray,
    is_new: jnp.ndarray,
    q_ev_lo: jnp.ndarray,
    q_ev_hi: jnp.ndarray,
    cr_planes: jnp.ndarray,
    q_values: jnp.ndarray,
) -> tuple[jnp.ndarray, ...]:
    """Donated-buffer compare-and-update at index-resolved slots.

    All seven table planes are DONATED — the update happens in the planes'
    existing device buffers; callers must drop their references and adopt
    the returned arrays.  Batch arrays are per-unique-id winner records in
    any order: ``part``/``slot`` (G,) int32 target coords, ``q_klo/q_khi``
    the key planes to stamp where ``is_new`` (fresh inserts, possibly into
    recycled slots), ``q_ev_lo/q_ev_hi`` winner event_ts planes,
    ``cr_planes`` (2,) int32 [lo, hi] of the shared batch creation_ts, and
    ``q_values`` (G, D) feature rows.  Coords must be distinct (the merge
    plan guarantees one winner per id, the index one slot per id).

    Algorithm 2, online branch, per coord: new slots always take the
    record; live slots take it iff (ev, cr) >lex (old_ev, old_cr).  The
    compare runs on device against device truth, so host mirrors can be
    arbitrarily stale.
    """
    old_elo = ev_lo[part, slot]
    old_ehi = ev_hi[part, slot]
    old_clo = cr_lo[part, slot]
    old_chi = cr_hi[part, slot]
    crlo = jnp.broadcast_to(cr_planes[0], part.shape)
    crhi = jnp.broadcast_to(cr_planes[1], part.shape)

    ev_gt = i64_gt(q_ev_hi, q_ev_lo, old_ehi, old_elo)
    ev_eq = (q_ev_hi == old_ehi) & (q_ev_lo == old_elo)
    cr_gt = i64_gt(crhi, crlo, old_chi, old_clo)
    win = is_new | ev_gt | (ev_eq & cr_gt)

    keys_lo = keys_lo.at[part, slot].set(
        jnp.where(is_new, q_klo, keys_lo[part, slot])
    )
    keys_hi = keys_hi.at[part, slot].set(
        jnp.where(is_new, q_khi, keys_hi[part, slot])
    )
    ev_lo = ev_lo.at[part, slot].set(jnp.where(win, q_ev_lo, old_elo))
    ev_hi = ev_hi.at[part, slot].set(jnp.where(win, q_ev_hi, old_ehi))
    cr_lo = cr_lo.at[part, slot].set(jnp.where(win, crlo, old_clo))
    cr_hi = cr_hi.at[part, slot].set(jnp.where(win, crhi, old_chi))
    values = values.at[part, slot].set(
        jnp.where(win[:, None], q_values, values[part, slot])
    )
    return keys_lo, keys_hi, ev_lo, ev_hi, cr_lo, cr_hi, values


@jax.jit
def gather_slot_ts(
    ev_lo: jnp.ndarray,
    ev_hi: jnp.ndarray,
    cr_lo: jnp.ndarray,
    cr_hi: jnp.ndarray,
    part: jnp.ndarray,
    slot: jnp.ndarray,
) -> tuple[jnp.ndarray, ...]:
    """(part, slot) (G,) int32 -> the four int32 timestamp planes at those
    coords — the O(batch) read that lets the host merge plan see device
    truth without syncing whole planes."""
    return (
        ev_lo[part, slot],
        ev_hi[part, slot],
        cr_lo[part, slot],
        cr_hi[part, slot],
    )


@functools.partial(jax.jit, static_argnames=("slot_block", "interpret"))
def merge(
    keys_lo: jnp.ndarray,
    keys_hi: jnp.ndarray,
    ev_lo: jnp.ndarray,
    ev_hi: jnp.ndarray,
    cr_lo: jnp.ndarray,
    cr_hi: jnp.ndarray,
    values: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
    q_ev_lo: jnp.ndarray,
    q_ev_hi: jnp.ndarray,
    q_values: jnp.ndarray,
    creation_planes: jnp.ndarray,
    *,
    slot_block: int = 512,
    interpret: bool = True,
) -> tuple[jnp.ndarray, ...]:
    """Pre-routed merge.  Table planes (P, C) (+ values (P, C, D)), routed
    queries (P, Q) (+ values (P, Q, D)) -> updated ev/cr planes + values.
    Handles slot-block/lane padding; at most one query per key."""
    p, c = keys_lo.shape
    d = values.shape[-1]
    c_pad = _round_up(c, min(slot_block, _round_up(c, _LANE)))
    sb = min(slot_block, c_pad)
    c_pad = _round_up(c_pad, sb)
    if c_pad != c:
        padk = jnp.full((p, c_pad - c), -1, jnp.int32)
        pad0 = jnp.zeros((p, c_pad - c), jnp.int32)
        keys_lo = jnp.concatenate([keys_lo, padk], axis=1)
        keys_hi = jnp.concatenate([keys_hi, padk], axis=1)
        ev_lo = jnp.concatenate([ev_lo, pad0], axis=1)
        ev_hi = jnp.concatenate([ev_hi, pad0], axis=1)
        cr_lo = jnp.concatenate([cr_lo, pad0], axis=1)
        cr_hi = jnp.concatenate([cr_hi, pad0], axis=1)
        values = jnp.concatenate(
            [values, jnp.zeros((p, c_pad - c, d), jnp.float32)], axis=1
        )
    q = q_lo.shape[1]
    q_pad = _round_up(q, _LANE)
    if q_pad != q:
        # (-2, -2) padding: matches neither live keys nor the empty sentinel
        padq = jnp.full((p, q_pad - q), -2, jnp.int32)
        pad0q = jnp.zeros((p, q_pad - q), jnp.int32)
        q_lo = jnp.concatenate([q_lo, padq], axis=1)
        q_hi = jnp.concatenate([q_hi, padq], axis=1)
        q_ev_lo = jnp.concatenate([q_ev_lo, pad0q], axis=1)
        q_ev_hi = jnp.concatenate([q_ev_hi, pad0q], axis=1)
        q_values = jnp.concatenate(
            [q_values, jnp.zeros((p, q_pad - q, d), jnp.float32)], axis=1
        )
    d_pad = _round_up(d, _LANE) if not interpret else d
    if d_pad != d:
        values = jnp.concatenate(
            [values, jnp.zeros((p, c_pad, d_pad - d), jnp.float32)], axis=2
        )
        q_values = jnp.concatenate(
            [q_values, jnp.zeros((p, q_pad, d_pad - d), jnp.float32)], axis=2
        )
    out = merge_kernel_call(
        keys_lo, keys_hi, ev_lo, ev_hi, cr_lo, cr_hi, values,
        q_lo, q_hi, q_ev_lo, q_ev_hi, q_values, creation_planes,
        slot_block=sb, interpret=interpret,
    )
    ev_lo_u, ev_hi_u, cr_lo_u, cr_hi_u, vals_u = out
    return (
        ev_lo_u[:, :c],
        ev_hi_u[:, :c],
        cr_lo_u[:, :c],
        cr_hi_u[:, :c],
        vals_u[:, :c, :d],
    )


def route_and_merge(
    keys_lo: np.ndarray,
    keys_hi: np.ndarray,
    event_ts: np.ndarray,
    creation_ts: np.ndarray,
    values: np.ndarray,
    ids: np.ndarray,
    ev: np.ndarray,
    vals: np.ndarray,
    batch_creation_ts: int,
    *,
    interpret: bool = True,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flat merge path: winner records ids (B,) int64 (UNIQUE), ev (B,) int64,
    vals (B, D) f32 against table planes (P, C) + int64 ts + values (P, C, D).

    Returns updated host-side (event_ts, creation_ts, values) as int64/f32.
    """
    num_p, _ = keys_lo.shape
    ids = np.asarray(ids, np.int64)
    if len(ids) == 0:
        return event_ts.copy(), creation_ts.copy(), values.copy()
    q_ids, _, _, q_ev, q_vals = route_flat(
        num_p, ids, np.asarray(ev, np.int64), np.asarray(vals, np.float32)
    )
    q_lo, q_hi = split_i64(q_ids)
    # padding slots carry ids == -2 on BOTH planes (split of -2 is
    # (-2, -1)); overwrite the planes where the id is the pad sentinel so
    # they can never alias a live key's planes.
    pad = q_ids == -2
    q_lo[pad] = -2
    q_hi[pad] = -2
    q_ev_lo, q_ev_hi = split_i64(q_ev)
    ev_lo, ev_hi = split_i64(event_ts)
    cr_lo, cr_hi = split_i64(creation_ts)
    cr_planes = np.asarray(
        np.concatenate(split_i64(np.asarray([batch_creation_ts]))), np.int32
    )
    out = merge(
        jnp.asarray(keys_lo), jnp.asarray(keys_hi),
        jnp.asarray(ev_lo), jnp.asarray(ev_hi),
        jnp.asarray(cr_lo), jnp.asarray(cr_hi),
        jnp.asarray(values),
        jnp.asarray(q_lo), jnp.asarray(q_hi),
        jnp.asarray(q_ev_lo), jnp.asarray(q_ev_hi),
        jnp.asarray(q_vals), jnp.asarray(cr_planes),
        interpret=interpret,
    )
    ev_lo_u, ev_hi_u, cr_lo_u, cr_hi_u, vals_u = (np.asarray(o) for o in out)
    return (
        combine_i64(ev_lo_u, ev_hi_u),
        combine_i64(cr_lo_u, cr_hi_u),
        vals_u,
    )
