"""jit'd wrapper + host routing for the online-merge kernel.

Mirror of kernels/online_lookup/ops.py on the write side: route a flat,
per-id-winner batch to hash partitions (fully vectorized scatter — this IS
the throughput path), pad to lane shapes, split int64 ids/timestamps into
int32 planes, run the kernel, and recombine the updated planes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.online_lookup.ops import (
    combine_i64,
    route_flat,
    split_i64,
)
from repro.kernels.online_merge.kernel import merge_kernel_call

__all__ = ["merge", "route_and_merge", "route_flat"]

_LANE = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("slot_block", "interpret"))
def merge(
    keys_lo: jnp.ndarray,
    keys_hi: jnp.ndarray,
    ev_lo: jnp.ndarray,
    ev_hi: jnp.ndarray,
    cr_lo: jnp.ndarray,
    cr_hi: jnp.ndarray,
    values: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
    q_ev_lo: jnp.ndarray,
    q_ev_hi: jnp.ndarray,
    q_values: jnp.ndarray,
    creation_planes: jnp.ndarray,
    *,
    slot_block: int = 512,
    interpret: bool = True,
) -> tuple[jnp.ndarray, ...]:
    """Pre-routed merge.  Table planes (P, C) (+ values (P, C, D)), routed
    queries (P, Q) (+ values (P, Q, D)) -> updated ev/cr planes + values.
    Handles slot-block/lane padding; at most one query per key."""
    p, c = keys_lo.shape
    d = values.shape[-1]
    c_pad = _round_up(c, min(slot_block, _round_up(c, _LANE)))
    sb = min(slot_block, c_pad)
    c_pad = _round_up(c_pad, sb)
    if c_pad != c:
        padk = jnp.full((p, c_pad - c), -1, jnp.int32)
        pad0 = jnp.zeros((p, c_pad - c), jnp.int32)
        keys_lo = jnp.concatenate([keys_lo, padk], axis=1)
        keys_hi = jnp.concatenate([keys_hi, padk], axis=1)
        ev_lo = jnp.concatenate([ev_lo, pad0], axis=1)
        ev_hi = jnp.concatenate([ev_hi, pad0], axis=1)
        cr_lo = jnp.concatenate([cr_lo, pad0], axis=1)
        cr_hi = jnp.concatenate([cr_hi, pad0], axis=1)
        values = jnp.concatenate(
            [values, jnp.zeros((p, c_pad - c, d), jnp.float32)], axis=1
        )
    q = q_lo.shape[1]
    q_pad = _round_up(q, _LANE)
    if q_pad != q:
        # (-2, -2) padding: matches neither live keys nor the empty sentinel
        padq = jnp.full((p, q_pad - q), -2, jnp.int32)
        pad0q = jnp.zeros((p, q_pad - q), jnp.int32)
        q_lo = jnp.concatenate([q_lo, padq], axis=1)
        q_hi = jnp.concatenate([q_hi, padq], axis=1)
        q_ev_lo = jnp.concatenate([q_ev_lo, pad0q], axis=1)
        q_ev_hi = jnp.concatenate([q_ev_hi, pad0q], axis=1)
        q_values = jnp.concatenate(
            [q_values, jnp.zeros((p, q_pad - q, d), jnp.float32)], axis=1
        )
    d_pad = _round_up(d, _LANE) if not interpret else d
    if d_pad != d:
        values = jnp.concatenate(
            [values, jnp.zeros((p, c_pad, d_pad - d), jnp.float32)], axis=2
        )
        q_values = jnp.concatenate(
            [q_values, jnp.zeros((p, q_pad, d_pad - d), jnp.float32)], axis=2
        )
    out = merge_kernel_call(
        keys_lo, keys_hi, ev_lo, ev_hi, cr_lo, cr_hi, values,
        q_lo, q_hi, q_ev_lo, q_ev_hi, q_values, creation_planes,
        slot_block=sb, interpret=interpret,
    )
    ev_lo_u, ev_hi_u, cr_lo_u, cr_hi_u, vals_u = out
    return (
        ev_lo_u[:, :c],
        ev_hi_u[:, :c],
        cr_lo_u[:, :c],
        cr_hi_u[:, :c],
        vals_u[:, :c, :d],
    )


def route_and_merge(
    keys_lo: np.ndarray,
    keys_hi: np.ndarray,
    event_ts: np.ndarray,
    creation_ts: np.ndarray,
    values: np.ndarray,
    ids: np.ndarray,
    ev: np.ndarray,
    vals: np.ndarray,
    batch_creation_ts: int,
    *,
    interpret: bool = True,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flat merge path: winner records ids (B,) int64 (UNIQUE), ev (B,) int64,
    vals (B, D) f32 against table planes (P, C) + int64 ts + values (P, C, D).

    Returns updated host-side (event_ts, creation_ts, values) as int64/f32.
    """
    num_p, _ = keys_lo.shape
    ids = np.asarray(ids, np.int64)
    if len(ids) == 0:
        return event_ts.copy(), creation_ts.copy(), values.copy()
    q_ids, _, _, q_ev, q_vals = route_flat(
        num_p, ids, np.asarray(ev, np.int64), np.asarray(vals, np.float32)
    )
    q_lo, q_hi = split_i64(q_ids)
    # padding slots carry ids == -2 on BOTH planes (split of -2 is
    # (-2, -1)); overwrite the planes where the id is the pad sentinel so
    # they can never alias a live key's planes.
    pad = q_ids == -2
    q_lo[pad] = -2
    q_hi[pad] = -2
    q_ev_lo, q_ev_hi = split_i64(q_ev)
    ev_lo, ev_hi = split_i64(event_ts)
    cr_lo, cr_hi = split_i64(creation_ts)
    cr_planes = np.asarray(
        np.concatenate(split_i64(np.asarray([batch_creation_ts]))), np.int32
    )
    out = merge(
        jnp.asarray(keys_lo), jnp.asarray(keys_hi),
        jnp.asarray(ev_lo), jnp.asarray(ev_hi),
        jnp.asarray(cr_lo), jnp.asarray(cr_hi),
        jnp.asarray(values),
        jnp.asarray(q_lo), jnp.asarray(q_hi),
        jnp.asarray(q_ev_lo), jnp.asarray(q_ev_hi),
        jnp.asarray(q_vals), jnp.asarray(cr_planes),
        interpret=interpret,
    )
    ev_lo_u, ev_hi_u, cr_lo_u, cr_hi_u, vals_u = (np.asarray(o) for o in out)
    return (
        combine_i64(ev_lo_u, ev_hi_u),
        combine_i64(cr_lo_u, cr_hi_u),
        vals_u,
    )
