"""jit'd wrapper for the point-in-time search kernel.

Responsibilities: pad the table to (rows, 128) tiles and the query batch to
the block multiple, run the counting-search kernel, and convert counts to
(row index, valid).  Timestamp dtype policy: the kernel compares int32; the
caller (core/pit.py) rebases int64 epoch-ms timestamps to a per-call int32
offset domain host-side and falls back to the jnp oracle when the span does
not fit — TPU int64 vector compare is emulated and not worth claiming.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["pit_search"]

from repro.kernels.pit_join.kernel import pit_search_kernel_call

_LANE = 128
_INT32_MAX = 2**31 - 1


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("q_block", "table_rows_per_block", "interpret")
)
def pit_search(
    table_ts: jnp.ndarray,
    q_ts: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
    *,
    q_block: int = 512,
    table_rows_per_block: int = 8,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """table_ts (M,) int32 sorted within [lo,hi) segments; q_* (B,) int32.

    Returns (idx (B,) int32, valid (B,) bool): the greatest r in [lo, hi)
    with table_ts[r] <= q_ts, or valid=False when the segment has no past
    record (the §4.3 distinction between "not materialized" and "no data" is
    made by the caller, which knows the materialization interval state).
    """
    m = table_ts.shape[0]
    b = q_ts.shape[0]
    tile = table_rows_per_block * _LANE
    m_pad = _round_up(max(m, 1), tile)
    b_pad = _round_up(max(b, 1), q_block)

    tab = jnp.full((m_pad,), _INT32_MAX, jnp.int32).at[:m].set(table_ts)
    tab2d = tab.reshape(m_pad // _LANE, _LANE)

    def pad_q(x, fill):
        return jnp.full((b_pad, 1), fill, jnp.int32).at[:b, 0].set(x.astype(jnp.int32))

    counts = pit_search_kernel_call(
        tab2d,
        pad_q(q_ts, 0),
        pad_q(q_lo, 0),
        pad_q(q_hi, 0),  # padded queries have hi=0 => empty range => count 0
        q_block=q_block,
        table_rows_per_block=table_rows_per_block,
        interpret=interpret,
    )[:b, 0]
    idx = (q_lo + counts - 1).astype(jnp.int32)
    return idx, counts > 0
