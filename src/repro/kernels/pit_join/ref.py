"""Pure-jnp oracle for the point-in-time (as-of) search.

Given a feature table sorted by (entity segment, event_ts) and per-query
segment bounds [lo, hi), find for each query the greatest row index r in
[lo, hi) with table_ts[r] <= q_ts.  Returns (idx, valid): idx int32 (garbage
where invalid), valid bool.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pit_search_ref"]


def pit_search_ref(
    table_ts: jnp.ndarray,
    q_ts: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    m = table_ts.shape[0]
    r = jnp.arange(m)
    # ok[q, r]: row r is in query q's segment and not in q's future.
    ok = (
        (r[None, :] >= q_lo[:, None])
        & (r[None, :] < q_hi[:, None])
        & (table_ts[None, :] <= q_ts[:, None])
    )
    count = ok.sum(axis=1)
    idx = (q_lo + count - 1).astype(jnp.int32)
    return idx, count > 0
