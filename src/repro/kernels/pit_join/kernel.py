"""Pallas TPU kernel: point-in-time search as a counting searchsorted.

The paper's §4.4 query subsystem must find, per observation, the *nearest
past* feature record.  A GPU/CPU implementation binary-searches — O(log M)
random accesses per query.  Random access is the wrong primitive for TPU
vector memory; the TPU-native restatement is:

    idx[q] = lo[q] + |{ r in [lo,hi) : table_ts[r] <= q_ts[q] }| - 1

i.e. a *count* — computable as a streaming broadcast-compare-reduce over
table tiles resident in VMEM, with zero gathers and full VPU utilization.
We trade O(log M) latency-bound probes for O(M/lanes) bandwidth-bound
compares, the right trade on a machine with 128-wide lanes and sequential
grids (same reasoning that makes flash-attention stream K/V tiles).

Grid: (num_query_blocks, num_table_blocks), table minor (sequential), with an
int32 count accumulator in VMEM scratch per query block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pit_search_kernel_call"]

_LANE = 128


def _pit_kernel(qts_ref, qlo_ref, qhi_ref, tab_ref, out_ref, acc_ref, *, rows: int):
    tb = pl.program_id(1)
    n_tb = pl.num_programs(1)

    @pl.when(tb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tab = tab_ref[...]                                   # (R, 128) int32 ts
    qts = qts_ref[...]                                   # (Bq, 1)
    qlo = qlo_ref[...]
    qhi = qhi_ref[...]

    base = tb * rows * _LANE
    r_i = jax.lax.broadcasted_iota(jnp.int32, (rows, _LANE), 0)
    c_i = jax.lax.broadcasted_iota(jnp.int32, (rows, _LANE), 1)
    gidx = base + r_i * _LANE + c_i                      # global row index

    pred = (
        (gidx[None, :, :] >= qlo[:, :, None])
        & (gidx[None, :, :] < qhi[:, :, None])
        & (tab[None, :, :] <= qts[:, :, None])
    )
    acc_ref[...] += pred.sum(axis=(1, 2), dtype=jnp.int32)[:, None]

    @pl.when(tb == n_tb - 1)
    def _write():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("q_block", "table_rows_per_block", "interpret")
)
def pit_search_kernel_call(
    table_ts2d: jnp.ndarray,
    q_ts: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
    *,
    q_block: int = 512,
    table_rows_per_block: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """Counting search.  table_ts2d: (Mr, 128) int32, row-major flattening of
    the padded table (padding rows carry ts = INT32_MAX and are excluded by
    q_hi anyway).  q_*: (B, 1) int32 with B % q_block == 0.  Returns (B, 1)
    int32 counts; caller derives idx = lo + count - 1, valid = count > 0.
    """
    mr, lane = table_ts2d.shape
    if lane != _LANE:
        raise ValueError(f"table must be (rows, {_LANE})")
    b = q_ts.shape[0]
    if b % q_block or mr % table_rows_per_block:
        raise ValueError("shapes must be pre-padded by ops.py")
    grid = (b // q_block, mr // table_rows_per_block)
    kernel = functools.partial(_pit_kernel, rows=table_rows_per_block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_block, 1), lambda qb, tb: (qb, 0)),
            pl.BlockSpec((q_block, 1), lambda qb, tb: (qb, 0)),
            pl.BlockSpec((q_block, 1), lambda qb, tb: (qb, 0)),
            pl.BlockSpec((table_rows_per_block, _LANE), lambda qb, tb: (tb, 0)),
        ],
        out_specs=pl.BlockSpec((q_block, 1), lambda qb, tb: (qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((q_block, 1), jnp.int32)],
        interpret=interpret,
    )(q_ts, q_lo, q_hi, table_ts2d)
