"""Sharded checkpointing with elastic reshard-on-load.

Layout per step:  <dir>/step_<N>/
    manifest.json   — step, leaf paths/shapes/dtypes, extra state (data-plane
                      scheduler JSON, loader cursor), mesh descriptor
    arrays.npz      — flattened "path/to/leaf" -> host array

Properties the tests assert:
  * atomic (tmp dir + rename — a torn write never becomes "latest")
  * deterministic resume: restoring step N and re-running step N+1 produces
    bit-identical train state (8-bit moment quantization is deterministic)
  * elastic: restore does not care what mesh the arrays were saved from;
    the driver re-places leaves with device_put against the CURRENT mesh
    (scale up/down between runs)
  * retention: keep_last bounds disk usage
  * the DATA PLANE resumes too: the paper's "safely resume from where it
    left off without any data loss" (§3.1.2) — scheduler interval state and
    loader cursor ride along in the manifest.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]

_SEP = "/"


def _path_entry(p) -> str:
    if hasattr(p, "key"):    # DictKey
        return str(p.key)
    if hasattr(p, "name"):   # GetAttrKey (registered dataclasses: TrainState)
        return str(p.name)
    return str(p.idx)        # SequenceKey


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Returns (savable arrays, TRUE dtype per leaf).  bfloat16 is stored as
    a uint16 view — npz cannot round-trip ml_dtypes — and restored from the
    manifest's true dtype."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_entry(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        dtypes[key] = arr.dtype.name
        if arr.dtype.name == "bfloat16":
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat, dtypes


def save_checkpoint(
    directory: str | Path,
    step: int,
    state: Any,
    *,
    extra: Optional[dict] = None,
    keep_last: int = 3,
) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat, dtypes = _flatten(state)
    final = directory / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_"))
    try:
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": step,
            "leaves": {
                k: {"shape": list(v.shape), "dtype": dtypes[k]}
                for k, v in flat.items()
            },
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep_last)
    return final


def _gc(directory: Path, keep_last: int) -> None:
    steps = sorted(
        (p for p in directory.glob("step_*") if p.is_dir()),
        key=lambda p: int(p.name.split("_")[1]),
    )
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.glob("step_*")
        if p.is_dir() and (p / "manifest.json").exists()
    ]
    return max(steps, default=None)


def restore_checkpoint(
    directory: str | Path,
    step: int,
    template: Any,
    *,
    shardings: Any = None,
) -> tuple[Any, dict]:
    """Restore into ``template``'s structure.  With ``shardings`` (a pytree of
    jax.sharding.Sharding matching template), leaves are device_put against
    the CURRENT mesh — the elastic reshard path."""
    path = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as npz:
        flat = {k: npz[k] for k in npz.files}

    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    out_leaves = []
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    for i, (pth, tmpl) in enumerate(leaves_paths):
        key = _SEP.join(_path_entry(p) for p in pth)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        saved_dtype = manifest["leaves"].get(key, {}).get("dtype", "")
        if saved_dtype == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != template "
                f"{tmpl.shape}"
            )
        arr = arr.astype(tmpl.dtype)
        if shard_leaves is not None:
            out_leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out_leaves.append(jax.numpy.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, out_leaves)
    return state, manifest.get("extra", {})


class CheckpointManager:
    """Convenience wrapper binding a directory + cadence + retention."""

    def __init__(self, directory: str | Path, *, every: int = 50, keep_last: int = 3):
        self.directory = Path(directory)
        self.every = every
        self.keep_last = keep_last

    def maybe_save(self, step: int, state: Any, extra: Optional[dict] = None):
        if step % self.every == 0 and step > 0:
            return save_checkpoint(
                self.directory, step, state, extra=extra, keep_last=self.keep_last
            )
        return None

    def restore_latest(self, template: Any, *, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None, None
        state, extra = restore_checkpoint(
            self.directory, step, template, shardings=shardings
        )
        return step, state, extra
