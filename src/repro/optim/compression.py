"""Gradient compression for cross-pod (DCN-tier) reduction.

At 1000+ chips the pod-to-pod gradient reduction crosses the slow DCN tier;
the standard mitigation is compressed all-reduce with error feedback:

    send_t   = quantize(grad_t + residual_t)
    residual = (grad_t + residual_t) - dequantize(send_t)

int8 block-quantization reuses the optimizer's deterministic q8 codec
(optim/adamw.py), giving 4x wire reduction vs fp32 / 2x vs bf16 with the
classic EF-SGD convergence guarantee (the residual re-injects quantization
error next step, so the compressed update is unbiased over time).

Usage (training driver):

    comp = GradCompressor()
    grads, state = comp.compress_decompress(grads, state)   # per step
    ... all-reduce the (already compressed-and-restored) grads over 'pod'

In SPMD form the quantize happens before the pod all-reduce and the
dequantize after; expressing that split requires shard_map over 'pod',
which ``pod_allreduce_compressed`` provides.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.optim.adamw import dequantize_q8, quantize_q8

__all__ = ["GradCompressor", "pod_allreduce_compressed"]


class GradCompressor:
    """Error-feedback int8 gradient compression (stateless functional API)."""

    def init(self, grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress_decompress(self, grads: Any, residual: Any) -> tuple[Any, Any]:
        """Returns (restored grads after a quantize/dequantize round trip,
        new residual).  What a receiver would see after the compressed
        exchange — exact for tests, and the building block for the
        shard_map pod reduction."""

        def one(g, r):
            x = g.astype(jnp.float32) + r
            q = quantize_q8(x)
            restored = dequantize_q8(q, x.shape)
            return restored.astype(g.dtype), x - restored

        flat = jax.tree.map(one, grads, residual)
        return (
            jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple)),
            jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple)),
        )


def pod_allreduce_compressed(grads: Any, residual: Any, mesh) -> tuple[Any, Any]:
    """Cross-pod gradient mean with int8 payloads + error feedback.

    Each pod quantizes (grad + residual) to int8, all-reduces the int8
    payload's *dequantized* value over 'pod' (scales are f32 per block —
    the wire payload is q + scales, ~1.03 bytes/param vs 4), and keeps the
    local quantization error as next step's residual."""
    if mesh is None or "pod" not in mesh.axis_names or mesh.shape["pod"] == 1:
        return grads, residual
    npod = mesh.shape["pod"]

    def leaf(g, r):
        def body(g_loc, r_loc):
            x = g_loc.astype(jnp.float32) + r_loc
            q = quantize_q8(x)
            restored = dequantize_q8(q, x.shape)
            new_r = x - restored
            # the compressed exchange: only the restored (int8-fidelity)
            # value crosses pods
            summed = jax.lax.psum(restored, "pod")
            return (summed / npod).astype(g_loc.dtype), new_r

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False,
        )(g, r)

    out = jax.tree.map(leaf, grads, residual)
    return (
        jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple)),
        jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple)),
    )
