"""AdamW with optional block-wise 8-bit moment state.

Distributed-optimization rationale (DESIGN.md §4): at 671B params, fp32
Adam moments alone are 5.4 TB — over 21 GB/chip on a 256-chip pod, past
v5e's 16 GB.  Block-128 int8 moments with fp32 per-block scales (the
bitsandbytes recipe, deterministic round-to-nearest) cut m+v from 8 to
~2.06 bytes/param, and together with bf16 params bring the deepseek-v3
train cell under HBM.  Quantization is exact-roundtrip-deterministic, so
checkpoint/restore and the resume-determinism test hold bit-for-bit.

The optimizer is pure-functional: (init, update) closures over
hyperparameters, state is a plain pytree that inherits the params'
sharding (moments/quantized moments are elementwise-shaped).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["adamw", "Optimizer", "quantize_q8", "dequantize_q8"]

_BLOCK = 128


def quantize_q8(x: jnp.ndarray) -> dict:
    """float -> {q: int8 (same shape as x), scale: f32 (..., ceil(last/128))}.

    SHAPE-PRESERVING: q carries exactly the parameter's shape so it inherits
    the parameter's PartitionSpec verbatim — de/quantization is elementwise
    under GSPMD and induces no resharding collectives.  Blocks run along the
    last dim (128 entries each, zero-padded tail)."""
    x32 = x.astype(jnp.float32)
    if x32.ndim == 0:
        x32 = x32.reshape(1)
    last = x32.shape[-1]
    nb = -(-last // _BLOCK)
    pad = nb * _BLOCK - last
    xp = jnp.pad(x32, [(0, 0)] * (x32.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(*x32.shape[:-1], nb, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0          # (..., nb)
    safe = jnp.where(scale > 0, scale, 1.0)[..., None]
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    q = q.reshape(*x32.shape[:-1], nb * _BLOCK)[..., :last]
    if x.ndim == 0:
        q = q.reshape(())
    return {"q": q.reshape(x.shape), "scale": scale}


def dequantize_q8(qs: dict, shape: tuple, dtype=jnp.float32) -> jnp.ndarray:
    q, scale = qs["q"], qs["scale"]
    q32 = q.astype(jnp.float32)
    if q32.ndim == 0:
        q32 = q32.reshape(1)
    last = q32.shape[-1]
    nb = scale.shape[-1]
    pad = nb * _BLOCK - last
    qp = jnp.pad(q32, [(0, 0)] * (q32.ndim - 1) + [(0, pad)])
    blocks = qp.reshape(*q32.shape[:-1], nb, _BLOCK)
    out = (blocks * scale[..., None]).reshape(*q32.shape[:-1], nb * _BLOCK)
    return out[..., :last].reshape(shape).astype(dtype)


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def adamw(
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: Optional[float] = 1.0,
    quantize_moments: bool = False,
    sequential_updates: bool = True,
) -> Optimizer:
    """``sequential_updates`` chains per-leaf updates through
    jax.lax.optimization_barrier.  Without it XLA's scheduler may hold every
    leaf's fp32 de/quantization temporaries live at once — measured 117 GB/dev
    transient on the deepseek-v3 train cell (~11 full fp32 copies of the
    param shard).  The barrier chain forces leaf-at-a-time liveness, so the
    transient is O(largest leaf), not O(total params)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def zeros_like_moment(p):
            if quantize_moments:
                return quantize_q8(jnp.zeros(p.shape, jnp.float32))
            return jnp.zeros(p.shape, jnp.float32)

        return {
            "count": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros_like_moment, params),
            "v": jax.tree.map(zeros_like_moment, params),
        }

    def update(grads, state, params):
        count = state["count"] + 1

        if grad_clip is not None:
            leaves = jax.tree.leaves(grads)
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
            )
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        step_size = lr_fn(count)
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)

        def one(g, m_q, v_q, p):
            g32 = g.astype(jnp.float32)
            m = (
                dequantize_q8(m_q, p.shape) if quantize_moments else m_q
            )
            v = (
                dequantize_q8(v_q, p.shape) if quantize_moments else v_q
            )
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - step_size * upd).astype(p.dtype)
            new_m = quantize_q8(m) if quantize_moments else m
            new_v = quantize_q8(v) if quantize_moments else v
            return new_p, new_m, new_v

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        out = []
        token = jnp.zeros((), jnp.float32)
        for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
            if sequential_updates:
                g, token = jax.lax.optimization_barrier((g, token))
            new_p, new_m, new_v = one(g, m, v, p)
            if sequential_updates:
                # cheap data dependency on this leaf's completion
                token = new_p.reshape(-1)[0].astype(jnp.float32)
            out.append((new_p, new_m, new_v))
        new_params = treedef.unflatten([o[0] for o in out])
        new_state = {
            "count": count,
            "m": treedef.unflatten([o[1] for o in out]),
            "v": treedef.unflatten([o[2] for o in out]),
        }
        return new_params, new_state

    return Optimizer(init, update)
