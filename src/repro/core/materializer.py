"""Materialization job execution (paper §4.3, §4.5.3–4.5.4).

A job covers one feature window: run Algorithm 1, then merge the resulting
frame into the offline and/or online store — the SAME frame into both, which
is what makes the two stores eventually consistent (§4.5.4).  Failures may
strike between the two merges; merge idempotence (offline full-key dedup,
online latest-wins) guarantees retries converge.

``FaultInjector`` lets tests and benchmarks break the pipeline at the exact
seams the paper discusses: after compute, after the offline merge, after the
online merge.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.assets import FeatureSetSpec
from repro.core.offline_store import OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.scheduler import MaterializationJob
from repro.core.transform import SourceProtocol, compute_feature_window

__all__ = ["FaultInjector", "Materializer", "MaterializationOutcome"]


class FaultInjector:
    """Deterministic failure injection at named seams.

    Two modes, composable: ``arm(seam, n)`` fails the next n passes through
    one seam (targeted tests); ``set_failure_rate(p, seed)`` makes every seam
    fail with probability p from a seeded stream (chaos benchmarks — still
    reproducible)."""

    def __init__(self) -> None:
        self._arm: dict[str, int] = {}
        self._rate = 0.0
        self._rng = None

    def arm(self, seam: str, times: int = 1) -> None:
        self._arm[seam] = self._arm.get(seam, 0) + times

    def set_failure_rate(self, p: float, *, seed: int = 0) -> None:
        import numpy as _np

        self._rate = float(p)
        self._rng = _np.random.default_rng(seed)

    def check(self, seam: str) -> None:
        if self._arm.get(seam, 0) > 0:
            self._arm[seam] -= 1
            raise RuntimeError(f"injected fault at seam {seam!r}")
        if self._rate and self._rng is not None and self._rng.random() < self._rate:
            raise RuntimeError(f"injected fault (p={self._rate}) at seam {seam!r}")


@dataclasses.dataclass
class MaterializationOutcome:
    job_id: int
    rows: int
    offline_merged: bool
    online_merged: bool
    creation_ts: int
    # per-batch Algorithm-2 stats from the online merge plan (tallies +
    # touched-slot count) — the reduced form geo-replication ships
    online_stats: Optional[dict] = None
    # per-batch offline merge tallies (insert/dedup counts + the assigned
    # replication seq) — the offline plane's half of the same shipping story
    offline_stats: Optional[dict] = None


class Materializer:
    def __init__(
        self,
        offline: OfflineStore,
        online: OnlineStore,
        *,
        clock: Callable[[], int],
        faults: Optional[FaultInjector] = None,
        merge_engine: Optional[str] = None,
    ) -> None:
        self.offline = offline
        self.online = online
        self.clock = clock
        self.faults = faults or FaultInjector()
        # None -> each store's own default; "loop"/"vector"/"kernel" forces
        # one write path end-to-end (benchmarks flip old-style vs engine here)
        self.merge_engine = merge_engine
        self.outcomes: list[MaterializationOutcome] = []

    def run_job(
        self,
        job: MaterializationJob,
        spec: FeatureSetSpec,
        source: SourceProtocol,
    ) -> MaterializationOutcome:
        """Execute one job; raises on (injected or real) failure.  The paper's
        merge order — offline first, then online — is fixed, which is one of
        the §4.5.4 reasons the stores are only EVENTUALLY consistent."""
        self.faults.check("before_compute")
        frame = compute_feature_window(spec, source, job.window)
        self.faults.check("after_compute")

        creation_ts = int(self.clock())
        offline_done = online_done = False
        offline_stats = None
        if spec.materialization.offline_enabled:
            # OfflineStore normalizes "kernel" (online-only) to its vector path
            stats = self.offline.merge_with_stats(
                spec, frame, creation_ts, engine=self.merge_engine
            )
            offline_stats = {
                "inserted": stats["inserted"],
                "deduped": stats["deduped"],
                # seq the geo-replication log assigned this batch's offline
                # plane (annotated by the GeoReplicator's offline merge
                # listener; None when unattached or fully deduped)
                "replication_seq": stats.get("replication_seq"),
            }
            offline_done = True
        self.faults.check("between_merges")
        online_stats = None
        if spec.materialization.online_enabled:
            stats = self.online.merge(
                spec, frame, creation_ts, engine=self.merge_engine
            )
            online_stats = {
                "inserts": stats["inserts"],
                "overrides": stats["overrides"],
                "noops": stats["noops"],
                "touched_slots": len(stats["touched_slots"]),
                # seq the geo-replication log assigned this batch (annotated
                # by the GeoReplicator's merge listener; None when no
                # replication is attached or the batch was all no-ops)
                "replication_seq": stats.get("replication_seq"),
            }
            online_done = True
        self.faults.check("after_merges")

        outcome = MaterializationOutcome(
            job.job_id,
            len(frame),
            offline_done,
            online_done,
            creation_ts,
            online_stats=online_stats,
            offline_stats=offline_stats,
        )
        self.outcomes.append(outcome)
        return outcome
