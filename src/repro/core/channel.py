"""Fault-injectable WAN channel for the replication transport (ISSUE 7).

``GeoReplicator._ship_frame`` used to be a perfect in-process call: an
encoded ``core/wire.py`` frame could never drop, duplicate, arrive out of
order, corrupt, or time out, so the delivery state machine above it had
nothing to detect and the standing convergence invariants were only ever
exercised on the happy path.  This module makes the hop pluggable:

  * ``Channel`` — the protocol: ``transmit(src, dst, frame)`` carries one
    encoded ``wire.WireFrame`` toward a replica and returns a ``Delivery``
    describing what actually happened: zero or more ``arrivals`` (the byte
    payloads that reached the destination), the modeled one-way
    ``latency_ms``, and whether the acknowledgement path was lost;
  * ``InProcessChannel`` — today's perfect behavior (exactly one arrival,
    topology-modeled latency, acks always return).  The default, so every
    existing test and benchmark is bit-for-bit unchanged;
  * ``FaultyChannel`` — drops, duplicates, reorders, corrupts, spikes, and
    partitions frames according to a seeded ``FaultPlan``.

Determinism is the design constraint: a chaos run must be reproducible
from one integer seed so CI can gate its retry counts EXACTLY.  The fault
schedule therefore never touches wall-clock time or stateful RNG — every
decision is a pure function of (seed, destination, per-destination event
index) through a splitmix64-style integer hash, and "time" for partition
windows is the per-destination transmit-event counter.  Re-running the
same workload over the same plan replays the same faults, byte for byte.

Fault semantics (what the publisher observes):

  * DROP / PARTITION — no arrival; the publisher sees an ack timeout and
    retries after backoff (the frame's batches stay pending in the log);
  * DUPLICATE — two arrivals; the replica applies both (per-plane
    idempotence makes the second a no-op) and the duplicate is counted;
  * REORDER — the frame is withheld and delivered alongside the NEXT
    transmit to the same destination: the publisher sees a timeout and
    retries, the late copy applies out of order (commutativity) and is
    counted as a redelivery;
  * CORRUPT — the arrival's bytes are flipped; the wire CRC rejects the
    frame on the replica side (``WireFormatError``), no ack returns;
  * LATENCY SPIKE — the frame arrives and applies, but later than the
    publisher's ack timeout: the publisher must retry anyway, and the
    replica-side per-seq dedup absorbs the redelivery;
  * ACK LOSS — same observable outcome as a spike (applied, not acked).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Protocol

from repro.core.regions import GeoTopology

__all__ = [
    "Channel",
    "Delivery",
    "DeliveryError",
    "FaultPlan",
    "FaultyChannel",
    "InProcessChannel",
]

_M64 = (1 << 64) - 1


def mix64(x: int) -> int:
    """splitmix64 finalizer: a deterministic integer hash with good
    avalanche — the only "randomness" the fault plan is allowed."""
    x = (x + 0x9E3779B97F4A7C15) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return (x ^ (x >> 31)) & _M64


def _uniform(seed: int, dst_key: int, event: int, salt: int) -> float:
    """Deterministic u ~ [0, 1) for one (destination, event, fault-kind)
    triple.  Independent salts give independent per-kind draws."""
    return mix64(seed ^ mix64(dst_key ^ mix64((event << 8) | salt))) / 2.0**64


class DeliveryError(RuntimeError):
    """A transfer that must complete (bootstrap chunk, failover replay)
    exhausted its retry budget against the channel."""


@dataclasses.dataclass(frozen=True)
class Delivery:
    """What one ``transmit`` actually did.

    ``arrivals`` holds every byte payload that reached the destination
    (empty = dropped/partitioned, two entries = duplicated; a reordered
    frame arrives inside a LATER transmit's ``arrivals``).  ``ack_lost``
    means the frame applied but the acknowledgement never made it home —
    observationally identical to a latency spike past the ack timeout.

    ``remote`` is set by out-of-process carriers (``core/daemon.py``'s
    ``SocketChannel``): the replica daemon's ``wire.Ack`` receipt — the
    seqs it applied, rows, and status.  For such carriers ``arrivals`` is
    empty (the bytes left the process; nothing arrives locally) and the
    publisher trusts the ack instead of applying anything itself.  Typed
    as ``object`` because ``channel`` sits below ``wire`` in the import
    order."""

    arrivals: tuple[bytes, ...]
    latency_ms: float
    ack_lost: bool = False
    faults: tuple[str, ...] = ()
    remote: Optional[object] = None


class Channel(Protocol):
    """One-way carrier of encoded wire frames toward a replica."""

    def transmit(self, src: str, dst: str, frame) -> Delivery: ...


class InProcessChannel:
    """The perfect channel: exactly one arrival, topology-priced latency,
    acks always return.  This is the pre-ISSUE-7 behavior verbatim — the
    default, so the deterministic shipped-byte gates are untouched."""

    def __init__(self, topology: GeoTopology) -> None:
        self.topology = topology

    def transmit(self, src: str, dst: str, frame) -> Delivery:
        return Delivery(
            arrivals=(frame.data,),
            latency_ms=self.topology.transfer_ms(src, dst, frame.wire_nbytes),
        )


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, deterministic fault schedule for a ``FaultyChannel``.

    Rates are per-transmit probabilities, decided by hashing (seed,
    destination, per-destination event index) — no RNG state, no clock.
    ``partitions`` are half-open windows ``(dst, start_event, end_event)``
    in the destination's own transmit-event count: every frame (including
    probes) transmitted while the window covers its event index is lost.
    An empty plan is exactly the perfect channel."""

    seed: int
    drop_rate: float = 0.0
    dup_rate: float = 0.0
    reorder_rate: float = 0.0
    corrupt_rate: float = 0.0
    ack_loss_rate: float = 0.0
    spike_rate: float = 0.0
    spike_ms: float = 60_000.0
    partitions: tuple[tuple[str, int, int], ...] = ()

    _SALTS = {
        "drop": 0x11,
        "dup": 0x22,
        "reorder": 0x33,
        "corrupt": 0x44,
        "ack_lost": 0x55,
        "spike": 0x66,
    }

    def partitioned(self, dst: str, event: int) -> bool:
        return any(
            d == dst and lo <= event < hi for d, lo, hi in self.partitions
        )

    def decide(self, dst: str, event: int) -> list[str]:
        """The fault kinds striking this (destination, event) — a pure
        function of the plan, so any run is replayable from the seed."""
        if self.partitioned(dst, event):
            return ["partition"]
        dst_key = zlib.crc32(dst.encode())
        rates = (
            ("drop", self.drop_rate),
            ("dup", self.dup_rate),
            ("reorder", self.reorder_rate),
            ("corrupt", self.corrupt_rate),
            ("ack_lost", self.ack_loss_rate),
            ("spike", self.spike_rate),
        )
        return [
            kind
            for kind, rate in rates
            if rate > 0.0
            and _uniform(self.seed, dst_key, event, self._SALTS[kind]) < rate
        ]

    def corrupt(self, dst: str, event: int, data: bytes) -> bytes:
        """Flip one byte at a plan-determined offset — always an actual
        change, so the wire CRC must catch it."""
        if not data:
            return data
        h = mix64(self.seed ^ zlib.crc32(dst.encode()) ^ mix64(event ^ 0xC0))
        pos = h % len(data)
        flip = ((h >> 17) & 0xFF) or 0xA5  # never XOR with 0 (a no-op)
        return data[:pos] + bytes([data[pos] ^ flip]) + data[pos + 1 :]


class FaultyChannel:
    """A WAN that misbehaves on a reproducible schedule.

    Wraps the topology's latency model like ``InProcessChannel`` and then
    applies the plan's faults per transmit.  ``counts`` tallies every
    fault actually injected (the chaos bench gates these exactly), and
    ``events[dst]`` is the per-destination logical clock the partition
    windows are defined over."""

    def __init__(self, plan: FaultPlan, topology: GeoTopology) -> None:
        self.plan = plan
        self.topology = topology
        self.events: dict[str, int] = {}
        self.counts: dict[str, int] = {
            k: 0
            for k in (
                "transmits",
                "dropped",
                "duplicated",
                "reordered",
                "corrupted",
                "ack_lost",
                "spiked",
                "partitioned",
            )
        }
        self._deferred: dict[str, list[bytes]] = {}

    def transmit(self, src: str, dst: str, frame) -> Delivery:
        event = self.events.get(dst, 0)
        self.events[dst] = event + 1
        self.counts["transmits"] += 1
        faults = self.plan.decide(dst, event)
        latency = self.topology.transfer_ms(src, dst, frame.wire_nbytes)
        # anything withheld by an earlier reorder arrives alongside this
        # transmit — it was overtaken, not lost
        late = tuple(self._deferred.pop(dst, ()))
        arrivals: tuple[bytes, ...] = ()
        ack_lost = False
        if "partition" in faults:
            self.counts["partitioned"] += 1
        elif "drop" in faults:
            self.counts["dropped"] += 1
        elif "reorder" in faults:
            self.counts["reordered"] += 1
            self._deferred.setdefault(dst, []).append(frame.data)
        else:
            data = frame.data
            if "corrupt" in faults:
                self.counts["corrupted"] += 1
                data = self.plan.corrupt(dst, event, data)
            arrivals = (data, data) if "dup" in faults else (data,)
            if "dup" in faults:
                self.counts["duplicated"] += 1
            if "spike" in faults:
                self.counts["spiked"] += 1
                latency += self.plan.spike_ms
            if "ack_lost" in faults:
                self.counts["ack_lost"] += 1
                ack_lost = True
        return Delivery(
            arrivals=late + arrivals,
            latency_ms=latency,
            ack_lost=ack_lost,
            faults=tuple(faults),
        )
