"""Online store (paper §3.1.4, §4.5) — the Redis analogue, TPU-hosted.

Semantics reproduced exactly:
  * keeps ONLY the latest record per ID: max(tuple(event_ts, creation_ts));
  * Algorithm 2, online branch:
      - key absent            -> insert
      - new event_ts >  old   -> override
      - new event_ts == old and new creation_ts > old -> override
      - otherwise             -> no-op
  * TTL (§4.5.2 "assuming TTL satisfies"): records expire ``ttl`` ms after
    their creation_timestamp; expired records are invisible to lookups and
    reclaimed by ``sweep``.

Layout: the paper's storage-partitioning scheme applied to device memory —
hash-partitioned (P, C) slot tables whose key planes are exactly what the
kernels/online_lookup Pallas kernel scans, plus (P, C, D) feature values.
Batched GETs run through the kernel; merges are host-side (writes are the
materialization path, reads are the latency path).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.keys import encode_keys
from repro.core.offline_store import CREATION_TS, EVENT_TS
from repro.core.table import Table
from repro.kernels.online_lookup import ops as lookup_ops

__all__ = ["OnlineStore"]


@dataclasses.dataclass
class _PartitionedTable:
    keys_lo: np.ndarray      # (P, C) int32, -1 = empty
    keys_hi: np.ndarray      # (P, C) int32
    keys_full: np.ndarray    # (P, C) int64 (host-side truth)
    event_ts: np.ndarray     # (P, C) int64
    creation_ts: np.ndarray  # (P, C) int64
    values: np.ndarray       # (P, C, D) float32
    fill: np.ndarray         # (P,) int64 next free slot per partition
    slot_of: dict[int, tuple[int, int]]  # id -> (partition, slot)


class OnlineStore:
    def __init__(
        self,
        num_partitions: int = 16,
        initial_capacity: int = 256,
        *,
        interpret: bool = True,
    ):
        self.num_partitions = num_partitions
        self.initial_capacity = initial_capacity
        self.interpret = interpret
        self._tables: dict[tuple[str, int], _PartitionedTable] = {}
        self._specs: dict[tuple[str, int], FeatureSetSpec] = {}
        self.inserts = 0
        self.overrides = 0
        self.noops = 0

    # -- lifecycle ----------------------------------------------------------
    def register(self, spec: FeatureSetSpec) -> None:
        key = spec.key
        if key in self._tables:
            return
        p, c, d = self.num_partitions, self.initial_capacity, len(spec.features)
        self._tables[key] = _PartitionedTable(
            keys_lo=np.full((p, c), -1, np.int32),
            keys_hi=np.full((p, c), -1, np.int32),
            keys_full=np.full((p, c), -1, np.int64),
            event_ts=np.zeros((p, c), np.int64),
            creation_ts=np.zeros((p, c), np.int64),
            values=np.zeros((p, c, d), np.float32),
            fill=np.zeros(p, np.int64),
            slot_of={},
        )
        self._specs[key] = spec

    def has(self, name: str, version: int) -> bool:
        return (name, version) in self._tables

    def _grow(self, key: tuple[str, int]) -> None:
        t = self._tables[key]
        p, c = t.keys_lo.shape
        grow = lambda a, fillv: np.concatenate(
            [a, np.full_like(a, fillv)], axis=1
        )
        t.keys_lo = grow(t.keys_lo, -1)
        t.keys_hi = grow(t.keys_hi, -1)
        t.keys_full = grow(t.keys_full, -1)
        t.event_ts = grow(t.event_ts, 0)
        t.creation_ts = grow(t.creation_ts, 0)
        t.values = np.concatenate([t.values, np.zeros_like(t.values)], axis=1)

    # -- Algorithm 2, online branch -------------------------------------------
    def merge(self, spec: FeatureSetSpec, frame: Table, creation_ts: int) -> None:
        self.register(spec)
        if len(frame) == 0:
            return
        t = self._tables[spec.key]
        ids = encode_keys([frame[c] for c in spec.index_columns])
        event_ts = frame[spec.timestamp_col].astype(np.int64)
        feats = np.stack(
            [frame[f.name].astype(np.float32) for f in spec.features], axis=1
        )
        parts = lookup_ops.partition_of(ids, self.num_partitions)
        for i in range(len(ids)):
            key_i, ev_i, p = int(ids[i]), int(event_ts[i]), int(parts[i])
            existing = t.slot_of.get(key_i)
            if existing is None:
                if t.fill[p] >= t.keys_lo.shape[1]:
                    self._grow(spec.key)
                slot = int(t.fill[p])
                lo, hi = lookup_ops.split_i64(np.asarray([key_i]))
                t.keys_lo[p, slot] = lo[0]
                t.keys_hi[p, slot] = hi[0]
                t.keys_full[p, slot] = key_i
                t.event_ts[p, slot] = ev_i
                t.creation_ts[p, slot] = creation_ts
                t.values[p, slot] = feats[i]
                t.slot_of[key_i] = (p, slot)
                t.fill[p] += 1
                self.inserts += 1
            else:
                pp, slot = existing
                old_ev = int(t.event_ts[pp, slot])
                old_cr = int(t.creation_ts[pp, slot])
                if ev_i > old_ev or (ev_i == old_ev and creation_ts > old_cr):
                    t.event_ts[pp, slot] = ev_i
                    t.creation_ts[pp, slot] = creation_ts
                    t.values[pp, slot] = feats[i]
                    self.overrides += 1
                else:
                    self.noops += 1

    # -- reads ----------------------------------------------------------------
    def lookup(
        self,
        name: str,
        version: int,
        id_columns: list[np.ndarray],
        *,
        now: Optional[int] = None,
        use_kernel: bool = True,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Batched GET.  Returns (values (B, D) float32, found (B,) bool).
        TTL-expired records count as not found."""
        spec = self._specs[(name, version)]
        t = self._tables[(name, version)]
        ids = encode_keys(id_columns)
        if use_kernel:
            vals, found = lookup_ops.route_and_lookup(
                t.keys_lo, t.keys_hi, t.values, ids, interpret=self.interpret
            )
            # TTL + record metadata need the slot: recompute host-side mask.
            if now is not None and spec.materialization.online_ttl is not None:
                ttl = spec.materialization.online_ttl
                for i, k in enumerate(ids):
                    s = t.slot_of.get(int(k))
                    if s is not None and now - int(t.creation_ts[s[0], s[1]]) > ttl:
                        found[i] = False
                        vals[i] = 0.0
            return vals, found
        d = t.values.shape[-1]
        vals = np.zeros((len(ids), d), np.float32)
        found = np.zeros(len(ids), bool)
        ttl = spec.materialization.online_ttl
        for i, k in enumerate(ids):
            s = t.slot_of.get(int(k))
            if s is None:
                continue
            if (
                now is not None
                and ttl is not None
                and now - int(t.creation_ts[s[0], s[1]]) > ttl
            ):
                continue
            vals[i] = t.values[s[0], s[1]]
            found[i] = True
        return vals, found

    def get_record(
        self, name: str, version: int, id_columns: list[np.ndarray]
    ) -> list[Optional[dict]]:
        """Full records (event/creation ts + features) — used by tests and
        the online→offline bootstrap."""
        spec = self._specs[(name, version)]
        t = self._tables[(name, version)]
        ids = encode_keys(id_columns)
        out: list[Optional[dict]] = []
        for k in ids:
            s = t.slot_of.get(int(k))
            if s is None:
                out.append(None)
                continue
            p, slot = s
            out.append(
                {
                    "key": int(k),
                    EVENT_TS: int(t.event_ts[p, slot]),
                    CREATION_TS: int(t.creation_ts[p, slot]),
                    "features": t.values[p, slot].copy(),
                }
            )
        return out

    def dump_all(self, name: str, version: int) -> Table:
        """Everything currently live — the §4.5.5 online→offline bootstrap."""
        spec = self._specs[(name, version)]
        t = self._tables[(name, version)]
        rows_k, rows_ev, rows_cr, rows_v = [], [], [], []
        for k, (p, slot) in sorted(t.slot_of.items()):
            rows_k.append(k)
            rows_ev.append(int(t.event_ts[p, slot]))
            rows_cr.append(int(t.creation_ts[p, slot]))
            rows_v.append(t.values[p, slot])
        cols: dict[str, np.ndarray] = {
            "__key__": np.asarray(rows_k, np.int64).reshape(-1),
            EVENT_TS: np.asarray(rows_ev, np.int64).reshape(-1),
            CREATION_TS: np.asarray(rows_cr, np.int64).reshape(-1),
        }
        vals = (
            np.stack(rows_v, axis=0)
            if rows_v
            else np.zeros((0, len(spec.features)), np.float32)
        )
        for j, f in enumerate(spec.features):
            cols[f.name] = vals[:, j]
        return Table(cols)

    def num_records(self, name: str, version: int) -> int:
        return len(self._tables[(name, version)].slot_of)

    def sweep(self, name: str, version: int, now: int) -> int:
        """Reclaim TTL-expired slots (compaction). Returns #evicted."""
        spec = self._specs[(name, version)]
        ttl = spec.materialization.online_ttl
        if ttl is None:
            return 0
        t = self._tables[(name, version)]
        evict = [
            k
            for k, (p, s) in t.slot_of.items()
            if now - int(t.creation_ts[p, s]) > ttl
        ]
        for k in evict:
            p, s = t.slot_of.pop(k)
            t.keys_lo[p, s] = -1
            t.keys_hi[p, s] = -1
            t.keys_full[p, s] = -1
        return len(evict)

    # device mirror accessors for benchmarks
    def device_tables(self, name: str, version: int):
        t = self._tables[(name, version)]
        return t.keys_lo, t.keys_hi, t.values
