"""Online store (paper §3.1.4, §4.5) — the Redis analogue, TPU-hosted.

Semantics reproduced exactly:
  * keeps ONLY the latest record per ID: max(tuple(event_ts, creation_ts));
  * Algorithm 2, online branch:
      - key absent            -> insert
      - new event_ts >  old   -> override
      - new event_ts == old and new creation_ts > old -> override
      - otherwise             -> no-op
  * TTL (§4.5.2 "assuming TTL satisfies"): records expire ``ttl`` ms after
    their creation_timestamp; expired records are invisible to lookups and
    reclaimed by ``sweep``.

Layout: the paper's storage-partitioning scheme applied to device memory —
hash-partitioned (P, C) slot tables whose key planes are exactly what BOTH
kernels (kernels/online_lookup for GETs, kernels/online_merge for writes)
scan, plus (P, C, D) feature values.  Host-side truth lives in the same
arrays; per-id slot resolution goes through a sorted key index
(searchsorted), not a Python dict.

Write path — three interchangeable engines, byte-identical end states:
  * ``vector`` (default): core.merge_engine pre-reduces the batch to one
    winner per id (lexsort + segment scan), slots resolve in bulk against
    the sorted index, and inserts/overrides land as numpy scatters.  Exact
    Algorithm-2 ``inserts/overrides/noops`` tallies come from the same
    reduction.
  * ``kernel``: identical host bookkeeping, but the latest-wins
    compare-and-update runs through the kernels/online_merge Pallas kernel
    on the device layout (winner records routed per partition).
  * ``loop``: the retained per-row reference implementation — the
    sequential Algorithm-2 semantics the vector engines are proven against
    (parity tests + old-style benchmark baseline).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.keys import encode_keys
from repro.core.merge_engine import (
    INT64_MIN,
    argsort_ids,
    merge_sorted,
    plan_online_batch,
)
from repro.core.offline_store import CREATION_TS, EVENT_TS
from repro.core.table import Table
from repro.kernels.online_lookup import ops as lookup_ops
from repro.kernels.online_merge import ops as merge_ops

__all__ = ["OnlineStore"]


@dataclasses.dataclass
class _PartitionedTable:
    keys_lo: np.ndarray      # (P, C) int32, -1 = empty
    keys_hi: np.ndarray      # (P, C) int32
    keys_full: np.ndarray    # (P, C) int64 (host-side truth)
    event_ts: np.ndarray     # (P, C) int64
    creation_ts: np.ndarray  # (P, C) int64
    values: np.ndarray       # (P, C, D) float32
    fill: np.ndarray         # (P,) int64 next free slot per partition
    # sorted key index: idx_keys ascending; idx_part/idx_slot parallel
    idx_keys: np.ndarray     # (K,) int64
    idx_part: np.ndarray     # (K,) int64
    idx_slot: np.ndarray     # (K,) int64
    # loop-engine slot map, maintained incrementally so the reference
    # baseline pays seed-equivalent O(batch) per merge, not an O(K) rebuild;
    # invalidated whenever a vector/kernel merge or a sweep touches the table
    slot_cache: Optional[dict] = None


class OnlineStore:
    def __init__(
        self,
        num_partitions: int = 16,
        initial_capacity: int = 256,
        *,
        interpret: bool = True,
        merge_engine: str = "vector",
    ):
        if merge_engine not in ("vector", "kernel", "loop"):
            raise ValueError(f"unknown merge engine {merge_engine!r}")
        self.num_partitions = num_partitions
        self.initial_capacity = initial_capacity
        self.interpret = interpret
        self.merge_engine = merge_engine
        self._tables: dict[tuple[str, int], _PartitionedTable] = {}
        self._specs: dict[tuple[str, int], FeatureSetSpec] = {}
        self.inserts = 0
        self.overrides = 0
        self.noops = 0

    # -- lifecycle ----------------------------------------------------------
    def register(self, spec: FeatureSetSpec) -> None:
        key = spec.key
        if key in self._tables:
            return
        p, c, d = self.num_partitions, self.initial_capacity, len(spec.features)
        self._tables[key] = _PartitionedTable(
            keys_lo=np.full((p, c), -1, np.int32),
            keys_hi=np.full((p, c), -1, np.int32),
            keys_full=np.full((p, c), -1, np.int64),
            event_ts=np.zeros((p, c), np.int64),
            creation_ts=np.zeros((p, c), np.int64),
            values=np.zeros((p, c, d), np.float32),
            fill=np.zeros(p, np.int64),
            idx_keys=np.empty(0, np.int64),
            idx_part=np.empty(0, np.int64),
            idx_slot=np.empty(0, np.int64),
        )
        self._specs[key] = spec

    def has(self, name: str, version: int) -> bool:
        return (name, version) in self._tables

    def _grow(self, key: tuple[str, int]) -> None:
        t = self._tables[key]
        grow = lambda a, fillv: np.concatenate(
            [a, np.full_like(a, fillv)], axis=1
        )
        t.keys_lo = grow(t.keys_lo, -1)
        t.keys_hi = grow(t.keys_hi, -1)
        t.keys_full = grow(t.keys_full, -1)
        t.event_ts = grow(t.event_ts, 0)
        t.creation_ts = grow(t.creation_ts, 0)
        t.values = np.concatenate([t.values, np.zeros_like(t.values)], axis=1)

    # -- sorted key index ---------------------------------------------------
    def _index_find(
        self, t: _PartitionedTable, ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """ids (B,) -> (part, slot, found); part/slot are 0 where not found."""
        k = len(t.idx_keys)
        pos = np.searchsorted(t.idx_keys, ids)
        safe = np.minimum(pos, max(k - 1, 0))
        found = (
            (pos < k) & (t.idx_keys[safe] == ids)
            if k
            else np.zeros(len(ids), bool)
        )
        part = np.where(found, t.idx_part[safe] if k else 0, 0)
        slot = np.where(found, t.idx_slot[safe] if k else 0, 0)
        return part, slot, found

    def _index_insert(
        self,
        t: _PartitionedTable,
        new_ids: np.ndarray,
        parts: np.ndarray,
        slots: np.ndarray,
    ) -> None:
        """Bulk-insert (already absent) ids, keeping the index sorted."""
        order = np.argsort(new_ids)  # unique keys: stability irrelevant
        t.idx_keys, t.idx_part, t.idx_slot = merge_sorted(
            [t.idx_keys, t.idx_part, t.idx_slot],
            [new_ids[order], parts[order], slots[order]],
        )

    # -- Algorithm 2, online branch -----------------------------------------
    def merge(
        self,
        spec: FeatureSetSpec,
        frame: Table,
        creation_ts: int,
        *,
        engine: Optional[str] = None,
    ) -> None:
        engine = engine or self.merge_engine
        if engine not in ("vector", "kernel", "loop"):
            raise ValueError(f"unknown merge engine {engine!r}")
        self.register(spec)
        if len(frame) == 0:
            return
        ids = encode_keys([frame[c] for c in spec.index_columns])
        event_ts = frame[spec.timestamp_col].astype(np.int64)
        fnames = [f.name for f in spec.features]
        if engine == "loop":
            feats = frame.column_stack(fnames, np.float32)
            self._merge_loop(spec.key, ids, event_ts, feats, creation_ts)
        else:
            self._merge_vector(
                spec.key, ids, event_ts, frame, fnames, creation_ts,
                use_kernel=(engine == "kernel"),
            )

    def _merge_vector(
        self,
        key: tuple[str, int],
        ids: np.ndarray,
        event_ts: np.ndarray,
        frame: Table,
        fnames: list[str],
        creation_ts: int,
        *,
        use_kernel: bool = False,
    ) -> None:
        t = self._tables[key]
        t.slot_cache = None

        def resolve(uids: np.ndarray):
            part_e, slot_e, found = self._index_find(t, uids)
            resolve.parts, resolve.slots = part_e, slot_e
            return t.event_ts[part_e, slot_e], t.creation_ts[part_e, slot_e], found

        plan = plan_online_batch(ids, event_ts, creation_ts, resolve)
        part_e, slot_e = resolve.parts, resolve.slots
        found = ~plan.is_new
        # only winner rows' features ever reach the store — gather those,
        # not the whole batch
        wfeats = np.stack(
            [np.asarray(frame[n], np.float32)[plan.winner_row] for n in fnames],
            axis=1,
        )
        self.inserts += plan.inserts
        self.overrides += plan.overrides
        self.noops += plan.noops

        g = len(plan.uids)
        gpart = np.empty(g, np.int64)
        gslot = np.empty(g, np.int64)
        gpart[found] = part_e[found]
        gslot[found] = slot_e[found]

        new = plan.is_new
        if new.any():
            # slots assigned in ARRIVAL order of each id's first occurrence
            # (identical to the sequential loop's fill-counter behavior)
            ins_ids = plan.uids[new]
            arrival = np.argsort(plan.first_row[new], kind="stable")
            ins_ids_o = ins_ids[arrival]
            parts_o = lookup_ops.partition_of(ins_ids_o, self.num_partitions)
            counts = np.bincount(parts_o, minlength=self.num_partitions)
            while (t.fill + counts).max() > t.keys_lo.shape[1]:
                self._grow(key)
            po = np.argsort(parts_o, kind="stable")
            parts_sorted = parts_o[po]
            rank = np.arange(len(po)) - np.searchsorted(parts_sorted, parts_sorted)
            slots_o = np.empty(len(po), np.int64)
            slots_o[po] = t.fill[parts_sorted] + rank
            t.fill += counts

            lo, hi = lookup_ops.split_i64(ins_ids_o)
            t.keys_lo[parts_o, slots_o] = lo
            t.keys_hi[parts_o, slots_o] = hi
            t.keys_full[parts_o, slots_o] = ins_ids_o
            self._index_insert(t, ins_ids_o, parts_o, slots_o)
            # map arrival-ordered placements back to unique-id (group) order
            gpart_new = np.empty(len(po), np.int64)
            gslot_new = np.empty(len(po), np.int64)
            gpart_new[arrival] = parts_o
            gslot_new[arrival] = slots_o
            gpart[new] = gpart_new
            gslot[new] = gslot_new
            if use_kernel:
                # fresh slots start at the minimum timestamp so any real
                # record wins the device-side compare-and-update
                t.event_ts[parts_o, slots_o] = INT64_MIN
                t.creation_ts[parts_o, slots_o] = INT64_MIN

        if use_kernel:
            t.event_ts, t.creation_ts, t.values = merge_ops.route_and_merge(
                t.keys_lo, t.keys_hi, t.event_ts, t.creation_ts, t.values,
                plan.uids, plan.winner_ev, wfeats,
                creation_ts, interpret=self.interpret,
            )
        else:
            upd = plan.beat
            p_u, s_u = gpart[upd], gslot[upd]
            t.event_ts[p_u, s_u] = plan.winner_ev[upd]
            t.creation_ts[p_u, s_u] = creation_ts
            t.values[p_u, s_u] = wfeats[upd]

    def _merge_loop(
        self,
        key: tuple[str, int],
        ids: np.ndarray,
        event_ts: np.ndarray,
        feats: np.ndarray,
        creation_ts: int,
    ) -> None:
        """Retained reference: the per-row sequential Algorithm-2 loop.

        Decision semantics are the original row-at-a-time implementation.
        The slot map is cached on the table and maintained incrementally
        (like the seed's persistent dict) so this baseline costs O(batch)
        per merge; only batch-new ids are merged into the sorted index
        afterwards, so end state is byte-identical to the vector engine's."""
        t = self._tables[key]
        slot_of = t.slot_cache
        if slot_of is None:
            slot_of = {
                int(k): (int(p), int(s))
                for k, p, s in zip(t.idx_keys, t.idx_part, t.idx_slot)
            }
            t.slot_cache = slot_of
        new_ids: list[int] = []
        new_parts: list[int] = []
        new_slots: list[int] = []
        parts = lookup_ops.partition_of(ids, self.num_partitions)
        for i in range(len(ids)):
            key_i, ev_i, p = int(ids[i]), int(event_ts[i]), int(parts[i])
            existing = slot_of.get(key_i)
            if existing is None:
                if t.fill[p] >= t.keys_lo.shape[1]:
                    self._grow(key)
                slot = int(t.fill[p])
                lo, hi = lookup_ops.split_i64(np.asarray([key_i]))
                t.keys_lo[p, slot] = lo[0]
                t.keys_hi[p, slot] = hi[0]
                t.keys_full[p, slot] = key_i
                t.event_ts[p, slot] = ev_i
                t.creation_ts[p, slot] = creation_ts
                t.values[p, slot] = feats[i]
                slot_of[key_i] = (p, slot)
                new_ids.append(key_i)
                new_parts.append(p)
                new_slots.append(slot)
                t.fill[p] += 1
                self.inserts += 1
            else:
                pp, slot = existing
                old_ev = int(t.event_ts[pp, slot])
                old_cr = int(t.creation_ts[pp, slot])
                if ev_i > old_ev or (ev_i == old_ev and creation_ts > old_cr):
                    t.event_ts[pp, slot] = ev_i
                    t.creation_ts[pp, slot] = creation_ts
                    t.values[pp, slot] = feats[i]
                    self.overrides += 1
                else:
                    self.noops += 1
        if new_ids:
            self._index_insert(
                t,
                np.asarray(new_ids, np.int64),
                np.asarray(new_parts, np.int64),
                np.asarray(new_slots, np.int64),
            )

    # -- reads ----------------------------------------------------------------
    def lookup(
        self,
        name: str,
        version: int,
        id_columns: list[np.ndarray],
        *,
        now: Optional[int] = None,
        use_kernel: bool = True,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Batched GET.  Returns (values (B, D) float32, found (B,) bool).
        TTL-expired records count as not found."""
        spec = self._specs[(name, version)]
        t = self._tables[(name, version)]
        ids = encode_keys(id_columns)
        if use_kernel:
            vals, found = lookup_ops.route_and_lookup(
                t.keys_lo, t.keys_hi, t.values, ids, interpret=self.interpret
            )
            if now is not None and spec.materialization.online_ttl is not None:
                ttl = spec.materialization.online_ttl
                p, s, hit = self._index_find(t, ids)
                expired = hit & (now - t.creation_ts[p, s] > ttl)
                found[expired] = False
                vals[expired] = 0.0
            return vals, found
        d = t.values.shape[-1]
        vals = np.zeros((len(ids), d), np.float32)
        found = np.zeros(len(ids), bool)
        ttl = spec.materialization.online_ttl
        p, s, hit = self._index_find(t, ids)
        if now is not None and ttl is not None:
            hit = hit & ~(now - t.creation_ts[p, s] > ttl)
        found[hit] = True
        vals[hit] = t.values[p[hit], s[hit]]
        return vals, found

    def get_record(
        self, name: str, version: int, id_columns: list[np.ndarray]
    ) -> list[Optional[dict]]:
        """Full records (event/creation ts + features) — used by tests and
        the online→offline bootstrap."""
        t = self._tables[(name, version)]
        ids = encode_keys(id_columns)
        p, s, hit = self._index_find(t, ids)
        out: list[Optional[dict]] = []
        for i, k in enumerate(ids):
            if not hit[i]:
                out.append(None)
                continue
            out.append(
                {
                    "key": int(k),
                    EVENT_TS: int(t.event_ts[p[i], s[i]]),
                    CREATION_TS: int(t.creation_ts[p[i], s[i]]),
                    "features": t.values[p[i], s[i]].copy(),
                }
            )
        return out

    def dump_all(self, name: str, version: int) -> Table:
        """Everything currently live — the §4.5.5 online→offline bootstrap.
        The sorted key index IS the dump order (ascending id)."""
        spec = self._specs[(name, version)]
        t = self._tables[(name, version)]
        p, s = t.idx_part, t.idx_slot
        cols: dict[str, np.ndarray] = {
            "__key__": t.idx_keys.copy(),
            EVENT_TS: t.event_ts[p, s],
            CREATION_TS: t.creation_ts[p, s],
        }
        vals = (
            t.values[p, s]
            if len(p)
            else np.zeros((0, len(spec.features)), np.float32)
        )
        for j, f in enumerate(spec.features):
            cols[f.name] = vals[:, j]
        return Table(cols)

    def num_records(self, name: str, version: int) -> int:
        return len(self._tables[(name, version)].idx_keys)

    def sweep(self, name: str, version: int, now: int) -> int:
        """Reclaim TTL-expired slots (compaction). Returns #evicted."""
        spec = self._specs[(name, version)]
        ttl = spec.materialization.online_ttl
        if ttl is None:
            return 0
        t = self._tables[(name, version)]
        expired = now - t.creation_ts[t.idx_part, t.idx_slot] > ttl
        if not expired.any():
            return 0
        t.slot_cache = None
        p, s = t.idx_part[expired], t.idx_slot[expired]
        t.keys_lo[p, s] = -1
        t.keys_hi[p, s] = -1
        t.keys_full[p, s] = -1
        t.idx_keys = t.idx_keys[~expired]
        t.idx_part = t.idx_part[~expired]
        t.idx_slot = t.idx_slot[~expired]
        return int(expired.sum())

    # device mirror accessors for benchmarks
    def device_tables(self, name: str, version: int):
        t = self._tables[(name, version)]
        return t.keys_lo, t.keys_hi, t.values
