"""Online store (paper §3.1.4, §4.5) — the Redis analogue, TPU-hosted.

Semantics reproduced exactly:
  * keeps ONLY the latest record per ID: max(tuple(event_ts, creation_ts));
  * Algorithm 2, online branch:
      - key absent            -> insert
      - new event_ts >  old   -> override
      - new event_ts == old and new creation_ts > old -> override
      - otherwise             -> no-op
  * TTL (§4.5.2 "assuming TTL satisfies"): records expire ``ttl`` ms after
    their creation_timestamp; expired records are invisible to lookups and
    reclaimed by ``sweep``, which recycles the freed slots through
    per-partition free lists so partitions stay bounded under TTL churn.

Layout: the paper's storage-partitioning scheme applied to device memory —
hash-partitioned (P, C) slot tables whose key planes are exactly what BOTH
kernels (kernels/online_lookup for GETs, kernels/online_merge for writes)
scan, plus (P, C, D) feature values.

Host-mirror / device-truth protocol
-----------------------------------
The ``kernel`` engine keeps the planes DEVICE-RESIDENT (``DeviceTableState``:
int32 key/timestamp planes + f32 values as jax arrays) and device memory is
the source of truth between kernel merges/lookups:

  * a kernel MERGE plans the batch on host (sorted key index -> slots, exact
    Algorithm-2 tallies from the plan), then applies it with ONE donated
    compare-and-update scatter (``merge_at_slots``) that rewrites the planes
    in their existing device buffers — traffic is O(batch), never O(P·C·D);
  * a kernel GET runs the Pallas lookup kernel against the resident key
    planes and gathers feature rows + creation_ts planes at the resolved
    slots on device (``gather_rows``) — again O(batch) both ways, with TTL
    expiry computed from device truth, not the host mirror;
  * the host numpy planes become a LAZY MIRROR: ``host_stale`` is set by
    every kernel merge, and any host-side consumer (``dump_all``,
    ``get_record``, ``sweep``, host-path lookups, the ``vector``/``loop``
    engines, ``sync_host_mirrors``) first syncs the mirror — one O(P·C·D)
    pull, amortized across arbitrarily many device-side operations;
  * host MUTATIONS (vector/loop merges, ``sweep``, ``_grow``) sync first and
    then DROP the device state (host becomes sole truth again); the next
    kernel operation re-uploads lazily.  Slot assignment, the sorted key
    index, ``keys_full``, and ``fill`` always live on host (inserts resolve
    there), and inserted keys are scattered into the device planes inside
    the same donated update.

``transfers`` tallies every host<->device byte the store moves, so tests and
benchmarks can assert the steady-state cycle is O(batch).

Write path — three interchangeable engines, byte-identical end states:
  * ``vector`` (default): core.merge_engine pre-reduces the batch to one
    winner per id (lexsort + segment scan), slots resolve in bulk against
    the sorted index, and inserts/overrides land as numpy scatters.  Exact
    Algorithm-2 ``inserts/overrides/noops`` tallies come from the same
    reduction.
  * ``kernel``: identical host planning, applied to the device-resident
    planes as described above.
  * ``loop``: the retained per-row reference implementation — the
    sequential Algorithm-2 semantics the vector engines are proven against
    (parity tests + old-style benchmark baseline).

Every ``merge`` returns per-batch stats: the Algorithm-2 tallies plus the
touched-slot coordinates AND the reduced winner rows that landed there
(encoded key, winning event_ts, feature row, shared creation_ts) — exactly
the bytes the async geo-replication path (core/replication.py) ships
cross-region.  ``merge_reduced`` is the matching apply side: it merges such
a reduced batch (already-encoded int64 keys, stacked float32 values) through
the same engines, so a replica replaying a shipped batch runs the identical
latest-wins state machine — re-delivery and out-of-order delivery are safe
because Algorithm 2 is an idempotent, commutative join on
(event_ts, creation_ts).  ``merge_listeners`` fire after every successful
merge with (spec, stats); the replication log subscribes there.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.keys import encode_keys
from repro.core.merge_engine import merge_sorted, plan_online_batch
from repro.core.offline_store import CREATION_TS, EVENT_TS
from repro.core.table import Table
from repro.kernels.online_lookup import ops as lookup_ops
from repro.kernels.online_merge import ops as merge_ops

__all__ = ["DeviceTableState", "MergeStats", "OnlineStore", "o_batch_byte_budget"]

_I32_MAX = np.int32(np.iinfo(np.int32).max)


def o_batch_byte_budget(batch: int, record_bytes: int) -> int:
    """The ONE definition of what 'O(batch)' means for the resident
    protocol's transfer guards (tier-1 bench smoke AND the pytest gate): a
    generous constant multiple of the batch footprint, covering plane
    splits, power-of-two bucket padding, and routing imbalance — while
    staying far below one table round-trip for any real table."""
    return 64 * batch * record_bytes


# the ONE shape-bucketing rule (kernels/online_lookup/ops.pow2_bucket):
# round batch lengths up to a power of two so the jitted device ops see a
# bounded set of shapes instead of retracing per batch size
_bucket = lookup_ops.pow2_bucket


def _nbytes(*arrays) -> int:
    return int(sum(a.size * a.dtype.itemsize for a in arrays))


@dataclasses.dataclass(frozen=True)
class MergeStats:
    """Typed per-batch merge result: exact Algorithm-2 tallies plus the
    reduced winning writes (``touched_*`` parallel arrays, sorted by
    (part, slot)) — the complete reduced batch geo-replication ships.

    Frozen: a merge's outcome is a fact, and several consumers (replication
    listener, serving-cache invalidation, materializer outcome records) read
    the SAME instance.  The one post-hoc annotation — the replication
    listener stamping the log sequence it published under — goes through
    ``annotate_replication_seq`` so the exception is explicit.  Supports
    ``stats["key"]``/``.get`` so dict-era consumers and JSON paths keep
    working, and ``as_dict()`` for bench artifacts."""

    engine: str
    inserts: int
    overrides: int
    noops: int
    creation_ts: int
    touched_parts: np.ndarray
    touched_slots: np.ndarray
    touched_keys: np.ndarray
    touched_event_ts: np.ndarray
    touched_values: np.ndarray
    replication_seq: Optional[int] = None

    def annotate_replication_seq(self, seq: Optional[int]) -> None:
        object.__setattr__(self, "replication_seq", seq)

    def __getitem__(self, key: str):
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def __contains__(self, key) -> bool:
        # without this, `key in stats` falls back to iterating
        # __getitem__(0), which getattr rejects
        return isinstance(key, str) and hasattr(self, key)

    def get(self, key: str, default=None):
        return getattr(self, key, default)

    def as_dict(self) -> dict:
        return {
            "engine": self.engine,
            "inserts": self.inserts,
            "overrides": self.overrides,
            "noops": self.noops,
            "creation_ts": self.creation_ts,
            "touched_rows": int(len(self.touched_keys)),
            "replication_seq": self.replication_seq,
        }


@dataclasses.dataclass
class DeviceTableState:
    """Device-resident truth for one table: the exact plane layout both
    Pallas kernels scan.  int64 keys/timestamps live as (lo, hi) int32
    planes (TPU vector compare is 32-bit native)."""

    keys_lo: jax.Array  # (P, C) int32, -1 = empty
    keys_hi: jax.Array  # (P, C) int32
    ev_lo: jax.Array  # (P, C) int32 event_ts planes
    ev_hi: jax.Array
    cr_lo: jax.Array  # (P, C) int32 creation_ts planes
    cr_hi: jax.Array
    values: jax.Array  # (P, C, D) float32

    def planes(self) -> tuple[jax.Array, ...]:
        return (
            self.keys_lo, self.keys_hi, self.ev_lo, self.ev_hi,
            self.cr_lo, self.cr_hi, self.values,
        )

    def nbytes(self) -> int:
        return sum(int(np.prod(p.shape)) * p.dtype.itemsize for p in self.planes())


@dataclasses.dataclass
class _PartitionedTable:
    keys_lo: np.ndarray  # (P, C) int32, -1 = empty
    keys_hi: np.ndarray  # (P, C) int32
    keys_full: np.ndarray  # (P, C) int64 (host-side truth)
    event_ts: np.ndarray  # (P, C) int64
    creation_ts: np.ndarray  # (P, C) int64
    values: np.ndarray  # (P, C, D) float32
    fill: np.ndarray  # (P,) int64 next fresh slot per partition
    # sorted key index: idx_keys ascending; idx_part/idx_slot parallel
    idx_keys: np.ndarray  # (K,) int64
    idx_part: np.ndarray  # (K,) int64
    idx_slot: np.ndarray  # (K,) int64
    # per-partition FIFO of slots freed by sweep; consumed before fill so
    # TTL churn recycles capacity instead of growing partitions forever
    free: Optional[list] = None
    # loop-engine slot map, maintained incrementally so the reference
    # baseline pays seed-equivalent O(batch) per merge, not an O(K) rebuild;
    # invalidated whenever a vector/kernel merge or a sweep touches the table
    slot_cache: Optional[dict] = None
    # device-resident planes (kernel engine); None = host is sole truth
    device: Optional[DeviceTableState] = None
    # True = device planes have advanced past the host ev/cr/values mirrors
    host_stale: bool = False


class OnlineStore:
    def __init__(
        self,
        num_partitions: int = 16,
        initial_capacity: int = 256,
        *,
        interpret: bool = True,
        merge_engine: str = "vector",
    ):
        if merge_engine not in ("vector", "kernel", "loop"):
            raise ValueError(f"unknown merge engine {merge_engine!r}")
        self.num_partitions = num_partitions
        self.initial_capacity = initial_capacity
        self.interpret = interpret
        self.merge_engine = merge_engine
        self._tables: dict[tuple[str, int], _PartitionedTable] = {}
        self._specs: dict[tuple[str, int], FeatureSetSpec] = {}
        # called as cb(spec, stats) after every merge/merge_reduced that ran;
        # callbacks may annotate ``stats`` (e.g. replication seq numbers)
        self.merge_listeners: list = []
        self.inserts = 0
        self.overrides = 0
        self.noops = 0
        # host<->device traffic ledger (bytes actually moved by the resident
        # protocol; O(batch) in steady state — asserted by tests/benchmarks)
        self.transfers = {
            "h2d_bytes": 0,
            "d2h_bytes": 0,
            "device_uploads": 0,
            "host_syncs": 0,
        }

    # -- lifecycle ----------------------------------------------------------
    def register(self, spec: FeatureSetSpec) -> None:
        key = spec.key
        if key in self._tables:
            return
        p, c, d = self.num_partitions, self.initial_capacity, len(spec.features)
        self._tables[key] = _PartitionedTable(
            keys_lo=np.full((p, c), -1, np.int32),
            keys_hi=np.full((p, c), -1, np.int32),
            keys_full=np.full((p, c), -1, np.int64),
            event_ts=np.zeros((p, c), np.int64),
            creation_ts=np.zeros((p, c), np.int64),
            values=np.zeros((p, c, d), np.float32),
            fill=np.zeros(p, np.int64),
            idx_keys=np.empty(0, np.int64),
            idx_part=np.empty(0, np.int64),
            idx_slot=np.empty(0, np.int64),
            free=[deque() for _ in range(p)],
        )
        self._specs[key] = spec

    def has(self, name: str, version: int) -> bool:
        return (name, version) in self._tables

    def _grow(self, key: tuple[str, int]) -> None:
        t = self._tables[key]
        # capacity changes invalidate the device layout: adopt device truth
        # into the host mirror first, then grow host-side and let the next
        # kernel op re-upload at the new shape
        self._mutate_host(t)
        grow = lambda a, fillv: np.concatenate([a, np.full_like(a, fillv)], axis=1)
        t.keys_lo = grow(t.keys_lo, -1)
        t.keys_hi = grow(t.keys_hi, -1)
        t.keys_full = grow(t.keys_full, -1)
        t.event_ts = grow(t.event_ts, 0)
        t.creation_ts = grow(t.creation_ts, 0)
        t.values = np.concatenate([t.values, np.zeros_like(t.values)], axis=1)

    # -- host-mirror / device-truth protocol --------------------------------
    def _ensure_device(self, t: _PartitionedTable) -> DeviceTableState:
        """Upload the planes once; subsequent kernel ops reuse the resident
        arrays (jnp.asarray of a jax array is free)."""
        if t.device is None:
            elo, ehi = lookup_ops.split_i64(t.event_ts)
            clo, chi = lookup_ops.split_i64(t.creation_ts)
            t.device = DeviceTableState(
                keys_lo=jnp.asarray(t.keys_lo),
                keys_hi=jnp.asarray(t.keys_hi),
                ev_lo=jnp.asarray(elo),
                ev_hi=jnp.asarray(ehi),
                cr_lo=jnp.asarray(clo),
                cr_hi=jnp.asarray(chi),
                values=jnp.asarray(t.values),
            )
            self.transfers["h2d_bytes"] += _nbytes(
                t.keys_lo, t.keys_hi, elo, ehi, clo, chi, t.values
            )
            self.transfers["device_uploads"] += 1
        return t.device

    def _sync_host(self, t: _PartitionedTable) -> None:
        """Refresh the host ev/cr/values mirrors from device truth (lazy:
        no-op unless a kernel merge advanced the device planes).  Key planes
        never need a pull — inserts keep them current on host."""
        if not t.host_stale:
            return
        d = t.device
        elo, ehi, clo, chi = (
            np.asarray(x) for x in (d.ev_lo, d.ev_hi, d.cr_lo, d.cr_hi)
        )
        t.event_ts = lookup_ops.combine_i64(elo, ehi)
        t.creation_ts = lookup_ops.combine_i64(clo, chi)
        t.values = np.array(d.values)  # copy: mirror must stay writable
        self.transfers["d2h_bytes"] += _nbytes(elo, ehi, clo, chi, t.values)
        self.transfers["host_syncs"] += 1
        t.host_stale = False

    def _mutate_host(self, t: _PartitionedTable) -> None:
        """About to write host planes: adopt device truth, then drop the
        device state so host becomes the sole truth."""
        self._sync_host(t)
        t.device = None

    def sync_host_mirrors(self, name: Optional[str] = None,
                          version: Optional[int] = None) -> None:
        """Force host mirrors up to date: all tables, every version of one
        feature set (``name`` only), or one exact table.  Read-only: the
        device state stays resident and remains truth-equal."""
        for (n, v), t in self._tables.items():
            if name is not None and n != name:
                continue
            if version is not None and v != version:
                continue
            self._sync_host(t)

    def transfer_stats(self) -> dict:
        return dict(self.transfers)

    def reset_transfer_stats(self) -> None:
        for k in self.transfers:
            self.transfers[k] = 0

    def device_state(self, name: str, version: int) -> DeviceTableState:
        """The resident planes (uploading them if needed) — benchmark/test
        accessor for the device-truth side of the protocol."""
        return self._ensure_device(self._tables[(name, version)])

    # -- sorted key index ---------------------------------------------------
    def _index_find(
        self, t: _PartitionedTable, ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """ids (B,) -> (part, slot, found); part/slot are 0 where not found."""
        k = len(t.idx_keys)
        pos = np.searchsorted(t.idx_keys, ids)
        safe = np.minimum(pos, max(k - 1, 0))
        found = (
            (pos < k) & (t.idx_keys[safe] == ids)
            if k
            else np.zeros(len(ids), bool)
        )
        part = np.where(found, t.idx_part[safe] if k else 0, 0)
        slot = np.where(found, t.idx_slot[safe] if k else 0, 0)
        return part, slot, found

    def _index_insert(
        self,
        t: _PartitionedTable,
        new_ids: np.ndarray,
        parts: np.ndarray,
        slots: np.ndarray,
    ) -> None:
        """Bulk-insert (already absent) ids, keeping the index sorted."""
        order = np.argsort(new_ids)  # unique keys: stability irrelevant
        t.idx_keys, t.idx_part, t.idx_slot = merge_sorted(
            [t.idx_keys, t.idx_part, t.idx_slot],
            [new_ids[order], parts[order], slots[order]],
        )

    # -- slot assignment (shared by all engines) ----------------------------
    def _assign_slots(self, key: tuple[str, int], parts_o: np.ndarray) -> np.ndarray:
        """Assign a slot to each to-insert id (``parts_o``: partitions in
        ARRIVAL order).  Per partition, sweep-freed slots are consumed FIFO
        before the fill counter advances — identical to the loop engine's
        per-row pop — growing capacity only for the overflow."""
        t = self._tables[key]
        counts = np.bincount(parts_o, minlength=self.num_partitions)
        nfree = np.array([len(f) for f in t.free], np.int64)
        while (t.fill + np.maximum(counts - nfree, 0)).max() > t.keys_lo.shape[1]:
            self._grow(key)
        po = np.argsort(parts_o, kind="stable")
        parts_sorted = parts_o[po]
        rank = np.arange(len(po)) - np.searchsorted(parts_sorted, parts_sorted)
        slots_sorted = np.empty(len(po), np.int64)
        use_free = rank < nfree[parts_sorted]
        consumed = np.minimum(counts, nfree)
        if use_free.any():
            # pop exactly the FIFO prefix each partition consumes — one pass,
            # O(batch), not O(total freed capacity)
            free_flat = np.array(
                [f.popleft() for f, k in zip(t.free, consumed)
                 for _ in range(int(k))],
                np.int64,
            )
            off = np.cumsum(consumed) - consumed
            src = off[parts_sorted[use_free]] + rank[use_free]
            slots_sorted[use_free] = free_flat[src]
        over = ~use_free
        if over.any():
            ps = parts_sorted[over]
            slots_sorted[over] = t.fill[ps] + rank[over] - nfree[ps]
        slots_o = np.empty(len(po), np.int64)
        slots_o[po] = slots_sorted
        t.fill += counts - consumed
        return slots_o

    # -- Algorithm 2, online branch -----------------------------------------
    def merge(
        self,
        spec: FeatureSetSpec,
        frame: Table,
        creation_ts: int,
        *,
        engine: Optional[str] = None,
    ) -> MergeStats:
        """Merge one materialization frame.  Returns per-batch stats: exact
        Algorithm-2 tallies plus the touched-slot coordinates and the reduced
        winner rows that landed there (sorted by (part, slot)) — the reduced
        batch form geo-replication ships."""
        engine = engine or self.merge_engine
        if engine not in ("vector", "kernel", "loop"):
            raise ValueError(f"unknown merge engine {engine!r}")
        self.register(spec)
        if len(frame) == 0:
            return self._empty_stats(engine, len(spec.features), creation_ts)
        ids = encode_keys([frame[c] for c in spec.index_columns])
        event_ts = frame[spec.timestamp_col].astype(np.int64)
        fnames = [f.name for f in spec.features]
        if engine == "loop":
            feats = frame.column_stack(fnames, np.float32)
            stats = self._merge_loop(spec.key, ids, event_ts, feats, creation_ts)
        else:
            stats = self._merge_vector(
                spec.key, ids, event_ts, frame, fnames, creation_ts,
                use_kernel=(engine == "kernel"),
            )
        for cb in self.merge_listeners:
            cb(spec, stats)
        return stats

    def merge_reduced(
        self,
        spec: FeatureSetSpec,
        keys: np.ndarray,
        event_ts: np.ndarray,
        values: np.ndarray,
        creation_ts: int,
        *,
        engine: Optional[str] = None,
    ) -> MergeStats:
        """Apply an already-reduced batch keyed by ENCODED int64 ids — the
        geo-replication apply path (and snapshot-bootstrap path) a replica
        store runs on a shipped ``ReplicatedBatch``.

        ``keys`` are non-negative encoded entity keys exactly as a home
        store's ``merge`` produced them (``touched_keys`` in its stats);
        ``values`` is the (B, len(spec.features)) float32 winner plane.  The
        batch goes through the SAME Algorithm-2 engines as ``merge``, so
        re-delivered or out-of-order batches converge: latest-wins on
        (event_ts, creation_ts) is an idempotent, commutative join."""
        engine = engine or self.merge_engine
        if engine not in ("vector", "kernel", "loop"):
            raise ValueError(f"unknown merge engine {engine!r}")
        self.register(spec)
        keys = np.asarray(keys, np.int64)
        event_ts = np.asarray(event_ts, np.int64)
        values = np.asarray(values, np.float32)
        if values.shape != (len(keys), len(spec.features)):
            raise ValueError(
                f"values plane {values.shape} does not match "
                f"({len(keys)}, {len(spec.features)})"
            )
        if len(keys) and keys.min() < 0:
            raise ValueError("reduced-batch keys must be encoded (non-negative)")
        if len(keys) == 0:
            return self._empty_stats(engine, len(spec.features), creation_ts)
        if engine == "loop":
            stats = self._merge_loop(spec.key, keys, event_ts, values, creation_ts)
        else:
            fnames = [f.name for f in spec.features]
            frame = {n: values[:, j] for j, n in enumerate(fnames)}
            stats = self._merge_vector(
                spec.key, keys, event_ts, frame, fnames, creation_ts,
                use_kernel=(engine == "kernel"),
            )
        for cb in self.merge_listeners:
            cb(spec, stats)
        return stats

    @staticmethod
    def _empty_stats(engine: str, d: int, creation_ts: int) -> MergeStats:
        return MergeStats(
            engine=engine, inserts=0, overrides=0, noops=0,
            creation_ts=int(creation_ts),
            touched_parts=np.empty(0, np.int64),
            touched_slots=np.empty(0, np.int64),
            touched_keys=np.empty(0, np.int64),
            touched_event_ts=np.empty(0, np.int64),
            touched_values=np.zeros((0, d), np.float32),
        )

    def _merge_vector(
        self,
        key: tuple[str, int],
        ids: np.ndarray,
        event_ts: np.ndarray,
        frame: Table,
        fnames: list[str],
        creation_ts: int,
        *,
        use_kernel: bool = False,
    ) -> MergeStats:
        t = self._tables[key]
        t.slot_cache = None
        if use_kernel:
            dev = self._ensure_device(t)
        else:
            # host engine writes host planes: adopt device truth, drop device
            self._mutate_host(t)
            dev = None

        def resolve(uids: np.ndarray):
            part_e, slot_e, found = self._index_find(t, uids)
            resolve.parts, resolve.slots = part_e, slot_e
            if t.host_stale:
                # host mirror is behind device truth: O(batch) coord gather
                g = len(uids)
                gb = _bucket(g)
                p32 = np.zeros(gb, np.int32)
                s32 = np.zeros(gb, np.int32)
                p32[:g] = part_e
                s32[:g] = slot_e
                planes = merge_ops.gather_slot_ts(
                    dev.ev_lo, dev.ev_hi, dev.cr_lo, dev.cr_hi,
                    jnp.asarray(p32), jnp.asarray(s32),
                )
                elo, ehi, clo, chi = (np.asarray(x)[:g] for x in planes)
                self.transfers["h2d_bytes"] += 2 * gb * 4
                self.transfers["d2h_bytes"] += 4 * gb * 4
                return (
                    lookup_ops.combine_i64(elo, ehi),
                    lookup_ops.combine_i64(clo, chi),
                    found,
                )
            return t.event_ts[part_e, slot_e], t.creation_ts[part_e, slot_e], found

        plan = plan_online_batch(ids, event_ts, creation_ts, resolve)
        part_e, slot_e = resolve.parts, resolve.slots
        found = ~plan.is_new
        # only winner rows' features ever reach the store — gather those,
        # not the whole batch
        wfeats = np.stack(
            [np.asarray(frame[n], np.float32)[plan.winner_row] for n in fnames],
            axis=1,
        )
        self.inserts += plan.inserts
        self.overrides += plan.overrides
        self.noops += plan.noops

        g = len(plan.uids)
        gpart = np.empty(g, np.int64)
        gslot = np.empty(g, np.int64)
        gpart[found] = part_e[found]
        gslot[found] = slot_e[found]

        new = plan.is_new
        if new.any():
            # slots assigned in ARRIVAL order of each id's first occurrence
            # (identical to the sequential loop's fill-counter behavior)
            ins_ids = plan.uids[new]
            arrival = np.argsort(plan.first_row[new], kind="stable")
            ins_ids_o = ins_ids[arrival]
            parts_o = lookup_ops.partition_of(ins_ids_o, self.num_partitions)
            slots_o = self._assign_slots(key, parts_o)
            lo, hi = lookup_ops.split_i64(ins_ids_o)
            t.keys_lo[parts_o, slots_o] = lo
            t.keys_hi[parts_o, slots_o] = hi
            t.keys_full[parts_o, slots_o] = ins_ids_o
            self._index_insert(t, ins_ids_o, parts_o, slots_o)
            # map arrival-ordered placements back to unique-id (group) order
            gpart_new = np.empty(len(parts_o), np.int64)
            gslot_new = np.empty(len(parts_o), np.int64)
            gpart_new[arrival] = parts_o
            gslot_new[arrival] = slots_o
            gpart[new] = gpart_new
            gslot[new] = gslot_new

        if use_kernel:
            # a grow inside _assign_slots dropped the device state; re-ensure
            # (fresh upload already carries the just-inserted keys)
            dev = self._ensure_device(t)
            gb = _bucket(g)
            p32 = np.zeros(gb, np.int32)
            # pad coords out of bounds: XLA drops OOB scatter updates, so
            # padding can never collide with a live slot
            s32 = np.full(gb, _I32_MAX, np.int32)
            p32[:g] = gpart
            s32[:g] = gslot
            klo = np.zeros(gb, np.int32)
            khi = np.zeros(gb, np.int32)
            klo[:g], khi[:g] = lookup_ops.split_i64(plan.uids)
            isnew = np.zeros(gb, bool)
            isnew[:g] = new
            welo = np.zeros(gb, np.int32)
            wehi = np.zeros(gb, np.int32)
            welo[:g], wehi[:g] = lookup_ops.split_i64(plan.winner_ev)
            wf = np.zeros((gb, wfeats.shape[1]), np.float32)
            wf[:g] = wfeats
            cr_planes = np.asarray(
                np.concatenate(
                    lookup_ops.split_i64(np.asarray([creation_ts]))
                ),
                np.int32,
            )
            out = merge_ops.merge_at_slots(
                *dev.planes(),
                jnp.asarray(p32), jnp.asarray(s32),
                jnp.asarray(klo), jnp.asarray(khi), jnp.asarray(isnew),
                jnp.asarray(welo), jnp.asarray(wehi),
                jnp.asarray(cr_planes), jnp.asarray(wf),
            )
            t.device = DeviceTableState(*out)
            t.host_stale = True
            self.transfers["h2d_bytes"] += _nbytes(
                p32, s32, klo, khi, isnew, welo, wehi, cr_planes, wf
            )
        else:
            upd = plan.beat
            p_u, s_u = gpart[upd], gslot[upd]
            t.event_ts[p_u, s_u] = plan.winner_ev[upd]
            t.creation_ts[p_u, s_u] = creation_ts
            t.values[p_u, s_u] = wfeats[upd]

        return self._batch_stats(
            plan.inserts, plan.overrides, plan.noops,
            gpart[plan.beat], gslot[plan.beat],
            plan.uids[plan.beat], plan.winner_ev[plan.beat], wfeats[plan.beat],
            creation_ts, engine="kernel" if use_kernel else "vector",
        )

    @staticmethod
    def _batch_stats(
        ins, ovr, nop, tparts, tslots, tkeys, tev, tvals, creation_ts, *, engine
    ) -> MergeStats:
        """Per-batch stats: Algorithm-2 tallies + the reduced winning writes,
        sorted by (part, slot) — see ``MergeStats``."""
        order = np.lexsort((tslots, tparts))
        return MergeStats(
            engine=engine,
            inserts=int(ins),
            overrides=int(ovr),
            noops=int(nop),
            creation_ts=int(creation_ts),
            touched_parts=np.asarray(tparts, np.int64)[order],
            touched_slots=np.asarray(tslots, np.int64)[order],
            touched_keys=np.asarray(tkeys, np.int64)[order],
            touched_event_ts=np.asarray(tev, np.int64)[order],
            touched_values=np.asarray(tvals, np.float32)[order],
        )

    def _merge_loop(
        self,
        key: tuple[str, int],
        ids: np.ndarray,
        event_ts: np.ndarray,
        feats: np.ndarray,
        creation_ts: int,
    ) -> MergeStats:
        """Retained reference: the per-row sequential Algorithm-2 loop.

        Decision semantics are the original row-at-a-time implementation.
        The slot map is cached on the table and maintained incrementally
        (like the seed's persistent dict) so this baseline costs O(batch)
        per merge; only batch-new ids are merged into the sorted index
        afterwards, so end state is byte-identical to the vector engine's."""
        t = self._tables[key]
        self._mutate_host(t)
        slot_of = t.slot_cache
        if slot_of is None:
            slot_of = {
                int(k): (int(p), int(s))
                for k, p, s in zip(t.idx_keys, t.idx_part, t.idx_slot)
            }
            t.slot_cache = slot_of
        new_ids: list[int] = []
        new_parts: list[int] = []
        new_slots: list[int] = []
        touched: set = set()
        ins = ovr = nop = 0
        parts = lookup_ops.partition_of(ids, self.num_partitions)
        for i in range(len(ids)):
            key_i, ev_i, p = int(ids[i]), int(event_ts[i]), int(parts[i])
            existing = slot_of.get(key_i)
            if existing is None:
                if t.free[p]:
                    slot = int(t.free[p].popleft())
                else:
                    if t.fill[p] >= t.keys_lo.shape[1]:
                        self._grow(key)
                    slot = int(t.fill[p])
                    t.fill[p] += 1
                lo, hi = lookup_ops.split_i64(np.asarray([key_i]))
                t.keys_lo[p, slot] = lo[0]
                t.keys_hi[p, slot] = hi[0]
                t.keys_full[p, slot] = key_i
                t.event_ts[p, slot] = ev_i
                t.creation_ts[p, slot] = creation_ts
                t.values[p, slot] = feats[i]
                slot_of[key_i] = (p, slot)
                new_ids.append(key_i)
                new_parts.append(p)
                new_slots.append(slot)
                touched.add((p, slot))
                ins += 1
            else:
                pp, slot = existing
                old_ev = int(t.event_ts[pp, slot])
                old_cr = int(t.creation_ts[pp, slot])
                if ev_i > old_ev or (ev_i == old_ev and creation_ts > old_cr):
                    t.event_ts[pp, slot] = ev_i
                    t.creation_ts[pp, slot] = creation_ts
                    t.values[pp, slot] = feats[i]
                    touched.add((pp, slot))
                    ovr += 1
                else:
                    nop += 1
        if new_ids:
            self._index_insert(
                t,
                np.asarray(new_ids, np.int64),
                np.asarray(new_parts, np.int64),
                np.asarray(new_slots, np.int64),
            )
        self.inserts += ins
        self.overrides += ovr
        self.noops += nop
        tp = np.array([c[0] for c in touched], np.int64)
        ts = np.array([c[1] for c in touched], np.int64)
        # host planes are truth after a loop merge: the rows at the touched
        # coords ARE the reduced winners this batch wrote
        return self._batch_stats(
            ins, ovr, nop, tp, ts,
            t.keys_full[tp, ts], t.event_ts[tp, ts], t.values[tp, ts],
            creation_ts, engine="loop",
        )

    # -- reads ----------------------------------------------------------------
    def spec(self, name: str, version: int) -> FeatureSetSpec:
        """The registered spec for one table (KeyError if unknown) — the
        serving front resolves feature width/TTL through this."""
        return self._specs[(name, version)]

    def lookup(
        self,
        name: str,
        version: int,
        id_columns: list[np.ndarray],
        *,
        now: Optional[int] = None,
        use_kernel: bool = True,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Batched GET.  Returns (values (B, D) float32, found (B,) bool).
        TTL-expired records count as not found.

        ``use_kernel=True`` serves entirely from device truth (resident key
        scan + on-device row gather, O(batch) traffic); ``use_kernel=False``
        serves from the host mirror, syncing it first if a kernel merge left
        it stale — both paths return byte-identical answers."""
        return self.lookup_encoded(
            name, version, encode_keys(id_columns), now=now, use_kernel=use_kernel
        )[:2]

    def lookup_encoded(
        self,
        name: str,
        version: int,
        ids: np.ndarray,
        *,
        now: Optional[int] = None,
        use_kernel: bool = True,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``lookup`` over ALREADY-ENCODED int64 keys — the serving front's
        dispatch path (it encodes once at admission and coalesces encoded
        keys across callers).  Returns (values (B, D) float32, found (B,)
        bool, creation_ts (B,) int64); ``creation_ts`` is the matched row's
        creation timestamp where found and 0 elsewhere (misses AND
        TTL-expired rows), so a caller caching decoded rows can re-check TTL
        later without another store read.  Both engines return byte-identical
        triples."""
        spec = self._specs[(name, version)]
        t = self._tables[(name, version)]
        ids = np.asarray(ids, np.int64)
        b = len(ids)
        d = t.values.shape[-1]
        if b == 0:
            return (
                np.zeros((0, d), np.float32),
                np.zeros(0, bool),
                np.zeros(0, np.int64),
            )
        ttl = spec.materialization.online_ttl
        if use_kernel:
            dev = self._ensure_device(t)
            q_lo, q_hi, part, pos = lookup_ops.route_queries(self.num_partitions, ids)
            slots = np.asarray(
                lookup_ops.lookup(
                    dev.keys_lo, dev.keys_hi,
                    jnp.asarray(q_lo), jnp.asarray(q_hi),
                    interpret=self.interpret,
                )
            )
            self.transfers["h2d_bytes"] += _nbytes(q_lo, q_hi)
            self.transfers["d2h_bytes"] += _nbytes(slots)
            got = slots[part, pos]
            found = got >= 0
            bb = _bucket(b)
            p32 = np.zeros(bb, np.int32)
            s32 = np.zeros(bb, np.int32)
            p32[:b] = part
            s32[:b] = np.maximum(got, 0)  # clamp misses; masked below
            vals_d, crlo_d, crhi_d = lookup_ops.gather_rows(
                dev.values, dev.cr_lo, dev.cr_hi,
                jnp.asarray(p32), jnp.asarray(s32),
            )
            self.transfers["h2d_bytes"] += 2 * bb * 4
            self.transfers["d2h_bytes"] += bb * (d * 4 + 8)
            vals = np.array(vals_d)[:b]
            vals[~found] = 0.0
            cr = lookup_ops.combine_i64(
                np.asarray(crlo_d)[:b], np.asarray(crhi_d)[:b]
            )
            if now is not None and ttl is not None:
                expired = found & (now - cr > ttl)
                found = found & ~expired
                vals[expired] = 0.0
            return vals, found, np.where(found, cr, 0)
        self._sync_host(t)
        vals = np.zeros((b, d), np.float32)
        found = np.zeros(b, bool)
        p, s, hit = self._index_find(t, ids)
        cr = t.creation_ts[p, s]
        if now is not None and ttl is not None:
            hit = hit & ~(now - cr > ttl)
        found[hit] = True
        vals[hit] = t.values[p[hit], s[hit]]
        return vals, found, np.where(found, cr, 0)

    def get_record(
        self, name: str, version: int, id_columns: list[np.ndarray]
    ) -> list[Optional[dict]]:
        """Full records (event/creation ts + features) — used by tests and
        the online→offline bootstrap.  Served from the (synced) host mirror."""
        t = self._tables[(name, version)]
        self._sync_host(t)
        ids = encode_keys(id_columns)
        p, s, hit = self._index_find(t, ids)
        out: list[Optional[dict]] = []
        for i, k in enumerate(ids):
            if not hit[i]:
                out.append(None)
                continue
            out.append(
                {
                    "key": int(k),
                    EVENT_TS: int(t.event_ts[p[i], s[i]]),
                    CREATION_TS: int(t.creation_ts[p[i], s[i]]),
                    "features": t.values[p[i], s[i]].copy(),
                }
            )
        return out

    def dump_all(self, name: str, version: int) -> Table:
        """Everything currently live — the §4.5.5 online→offline bootstrap.
        The sorted key index IS the dump order (ascending id).  Syncs the
        host mirror first: a dump is the one read that genuinely needs every
        plane on host."""
        spec = self._specs[(name, version)]
        t = self._tables[(name, version)]
        self._sync_host(t)
        p, s = t.idx_part, t.idx_slot
        cols: dict[str, np.ndarray] = {
            "__key__": t.idx_keys.copy(),
            EVENT_TS: t.event_ts[p, s],
            CREATION_TS: t.creation_ts[p, s],
        }
        vals = (
            t.values[p, s]
            if len(p)
            else np.zeros((0, len(spec.features)), np.float32)
        )
        for j, f in enumerate(spec.features):
            cols[f.name] = vals[:, j]
        return Table(cols)

    def num_records(self, name: str, version: int) -> int:
        return len(self._tables[(name, version)].idx_keys)

    def sweep(self, name: str, version: int, now: int) -> int:
        """Reclaim TTL-expired slots.  Returns #evicted.  Freed slots are
        tombstoned (keys = -1) AND pushed onto per-partition free lists so
        subsequent inserts recycle them — partitions stay bounded under TTL
        churn instead of leaking capacity."""
        spec = self._specs[(name, version)]
        ttl = spec.materialization.online_ttl
        if ttl is None:
            return 0
        t = self._tables[(name, version)]
        k = len(t.idx_keys)
        if k == 0:
            return 0
        if t.host_stale:
            # expiry probe against device truth at index coords — O(live
            # records) of timestamp planes, NOT a full O(P·C·D) mirror pull;
            # the expensive sync happens only when something actually expires
            kb = _bucket(k)
            p32 = np.zeros(kb, np.int32)
            s32 = np.zeros(kb, np.int32)
            p32[:k] = t.idx_part
            s32[:k] = t.idx_slot
            planes = merge_ops.gather_slot_ts(
                t.device.ev_lo, t.device.ev_hi,
                t.device.cr_lo, t.device.cr_hi,
                jnp.asarray(p32), jnp.asarray(s32),
            )
            self.transfers["h2d_bytes"] += 2 * kb * 4
            self.transfers["d2h_bytes"] += 2 * kb * 4
            cr = lookup_ops.combine_i64(
                np.asarray(planes[2])[:k], np.asarray(planes[3])[:k]
            )
        else:
            cr = t.creation_ts[t.idx_part, t.idx_slot]
        expired = now - cr > ttl
        if not expired.any():
            return 0
        self._mutate_host(t)
        t.slot_cache = None
        p, s = t.idx_part[expired], t.idx_slot[expired]
        t.keys_lo[p, s] = -1
        t.keys_hi[p, s] = -1
        t.keys_full[p, s] = -1
        order = np.lexsort((s, p))  # deterministic FIFO: ascending (part, slot)
        for pi, si in zip(p[order], s[order]):
            t.free[pi].append(int(si))
        t.idx_keys = t.idx_keys[~expired]
        t.idx_part = t.idx_part[~expired]
        t.idx_slot = t.idx_slot[~expired]
        return int(expired.sum())
