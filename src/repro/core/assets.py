"""Feature-store asset model (paper §2.2, §3.2, §4.1).

Assets are *versioned*: immutable properties (schema, transformation code,
source binding) can only change by incrementing the version; mutable
properties (description, tags, materialization schedule) may be updated in
place.  The registry (registry.py) enforces this contract.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional

import numpy as np

from repro.core.table import Table

__all__ = [
    "Entity",
    "Feature",
    "MaterializationSettings",
    "FeatureSetSpec",
    "TransformProtocol",
    "validate_feature_frame",
]


TIMESTAMP_DTYPE = np.int64  # epoch milliseconds everywhere in the system
ID_DTYPE = np.int64


@dataclasses.dataclass(frozen=True)
class Entity:
    """Index/key columns for feature lookup and join (paper §2.2).

    Entities are created once and reused across feature sets; they also
    organize feature sets in the registry.
    """

    name: str
    join_keys: tuple[str, ...]
    description: str = ""
    tags: tuple[str, ...] = ()

    def __post_init__(self) -> None:
        if not self.join_keys:
            raise ValueError(f"entity {self.name!r} needs at least one join key")


@dataclasses.dataclass(frozen=True)
class Feature:
    name: str
    dtype: str = "float32"
    description: str = ""

    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)


class StoreKind(enum.Enum):
    OFFLINE = "offline"
    ONLINE = "online"


@dataclasses.dataclass
class MaterializationSettings:
    """Managed materialization policy (paper §2.2, §4.3).

    ``schedule_interval`` is the cadence of scheduled incremental jobs in
    timestamp units (ms).  ``online_ttl`` models the Redis TTL assumption in
    §4.5.2: online records older than the TTL may be evicted.
    """

    offline_enabled: bool = True
    online_enabled: bool = False
    schedule_interval: Optional[int] = None
    online_ttl: Optional[int] = None
    # Context-aware partitioning scheme (§3.1.1): the unit feature-window size
    # a single materialization job should cover; backfills are split/coalesced
    # into units of this size.  Optionally customer-provided.
    partition_window: Optional[int] = None

    def __post_init__(self) -> None:
        if self.schedule_interval is not None and self.schedule_interval <= 0:
            raise ValueError("schedule_interval must be positive")


class TransformProtocol:
    """A transformation: udf(source_df, context) -> feature_df (paper §4.2).

    Two flavours exist (paper §3.1.6):
      * ``UDFTransform`` — arbitrary user code; a black box to the platform.
      * ``DslTransform`` — declarative rolling-window aggregations that the
        platform lowers to optimized (Pallas) execution.
    Both live in transform.py / dsl.py; this base class only pins the
    interface so FeatureSetSpec can treat them uniformly.
    """

    #: set by subclasses; DSL transforms are optimizable by the query engine.
    is_dsl: bool = False

    def __call__(self, source_df: Table, context: dict[str, Any]) -> Table:
        raise NotImplementedError

    def code_fingerprint(self) -> str:
        """Identity of the transformation logic — an *immutable* property."""
        raise NotImplementedError


@dataclasses.dataclass
class FeatureSetSpec:
    """A feature set: source + transform + schema + materialization (§2.2)."""

    name: str
    version: int
    entity: Entity
    features: tuple[Feature, ...]
    source_name: str
    transform: TransformProtocol
    timestamp_col: str = "ts"
    #: Algorithm 1's source_lookback: how far before the feature window the
    #: source read must start (rolling windows need history).
    source_lookback: int = 0
    materialization: MaterializationSettings = dataclasses.field(
        default_factory=MaterializationSettings
    )
    description: str = ""
    tags: tuple[str, ...] = ()
    #: expected availability delay of source/feature data, honoured by the
    #: point-in-time query subsystem (§4.4).
    expected_delay: int = 0

    def __post_init__(self) -> None:
        if self.version < 1:
            raise ValueError("versions start at 1")
        if self.source_lookback < 0 or self.expected_delay < 0:
            raise ValueError("lookback/delay must be >= 0")
        names = [f.name for f in self.features]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate feature names in {self.name}")
        overlap = set(names) & set(self.entity.join_keys) | (
            {self.timestamp_col} & set(names)
        )
        if overlap:
            raise ValueError(f"feature names collide with keys/ts: {overlap}")

    # -- identity ----------------------------------------------------------
    @property
    def key(self) -> tuple[str, int]:
        return (self.name, self.version)

    @property
    def feature_names(self) -> tuple[str, ...]:
        return tuple(f.name for f in self.features)

    @property
    def index_columns(self) -> tuple[str, ...]:
        return self.entity.join_keys

    def full_feature_names(self) -> tuple[str, ...]:
        """Globally unique names, e.g. ``transactions:v2:sum_30d``."""
        return tuple(f"{self.name}:v{self.version}:{f.name}" for f in self.features)

    # -- immutability contract (§4.1) ---------------------------------------
    def immutable_fingerprint(self) -> tuple:
        """Properties that may never change within a version."""
        return (
            self.name,
            self.version,
            self.entity,
            self.features,
            self.source_name,
            self.timestamp_col,
            self.source_lookback,
            self.transform.code_fingerprint(),
        )


def validate_feature_frame(spec: FeatureSetSpec, frame: Table) -> Table:
    """Enforce the §4.2 output contract: index columns + timestamp column +
    all feature columns declared by the feature set schema."""
    required = (*spec.index_columns, spec.timestamp_col, *spec.feature_names)
    missing = [c for c in required if c not in frame]
    if missing:
        raise ValueError(
            f"feature frame for {spec.name}:v{spec.version} is missing "
            f"required columns {missing}; transform output must contain "
            f"index columns, the timestamp column, and every declared feature"
        )
    return frame.select(required)
