"""Asset metadata management, versioning, and hub-and-spoke sharing
(paper §4.1, §4.1.1, §4.1.2, §3.2).

* Versioning contract: IMMUTABLE properties (schema, source binding,
  transformation code — ``FeatureSetSpec.immutable_fingerprint()``) may only
  change via a new version; MUTABLE properties (description, tags,
  materialization policy) update in place.
* Hub-and-spoke: the feature store (hub) owns assets; consuming ML
  workspaces (spokes) attach to hubs — possibly across subscriptions and
  regions — instead of peer-to-peer workspace pairing.
* Cross-region access control: an asset is readable from another region iff
  the hub grants access (our implemented mechanism, matching the paper's
  current choice) — geo-replication is the alternative mechanism handled by
  regions.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.assets import Entity, FeatureSetSpec

__all__ = ["AssetRegistry", "Workspace", "RegistryError"]


class RegistryError(RuntimeError):
    pass


@dataclasses.dataclass
class Workspace:
    """A consuming ML workspace (spoke)."""

    name: str
    subscription: str
    region: str


class AssetRegistry:
    """The metadata store of one feature store (hub)."""

    def __init__(self, store_name: str, region: str, subscription: str):
        self.store_name = store_name
        self.region = region
        self.subscription = subscription
        self._entities: dict[str, Entity] = {}
        self._feature_sets: dict[tuple[str, int], FeatureSetSpec] = {}
        self._archived: set[tuple[str, int]] = set()
        self._spokes: dict[str, Workspace] = {}
        # cross-region ACL: workspace name -> set of asset names (or "*")
        self._grants: dict[str, set[str]] = {}

    # -- entities -------------------------------------------------------------
    def create_entity(self, entity: Entity) -> Entity:
        if entity.name in self._entities:
            existing = self._entities[entity.name]
            if existing.join_keys != entity.join_keys:
                raise RegistryError(
                    f"entity {entity.name!r} exists with different join keys "
                    f"{existing.join_keys}; entities are created once and "
                    f"reused (§2.2)"
                )
            return existing
        self._entities[entity.name] = entity
        return entity

    def get_entity(self, name: str) -> Entity:
        return self._entities[name]

    # -- feature sets -----------------------------------------------------------
    def create_feature_set(self, spec: FeatureSetSpec) -> FeatureSetSpec:
        key = spec.key
        if key in self._feature_sets:
            existing = self._feature_sets[key]
            if existing.immutable_fingerprint() != spec.immutable_fingerprint():
                raise RegistryError(
                    f"{spec.name}:v{spec.version} exists with different "
                    f"immutable properties; increment the version instead (§4.1)"
                )
            raise RegistryError(f"{spec.name}:v{spec.version} already exists")
        if spec.entity.name not in self._entities:
            self.create_entity(spec.entity)
        self._feature_sets[key] = spec
        return spec

    def update_mutable(
        self,
        name: str,
        version: int,
        *,
        description: Optional[str] = None,
        tags: Optional[tuple[str, ...]] = None,
        materialization=None,
    ) -> FeatureSetSpec:
        spec = self.get_feature_set(name, version)
        if description is not None:
            spec.description = description
        if tags is not None:
            spec.tags = tags
        if materialization is not None:
            spec.materialization = materialization
        return spec

    def next_version(self, name: str) -> int:
        versions = [v for (n, v) in self._feature_sets if n == name]
        return max(versions, default=0) + 1

    def get_feature_set(self, name: str, version: int) -> FeatureSetSpec:
        key = (name, version)
        if key in self._archived:
            raise RegistryError(f"{name}:v{version} is archived")
        if key not in self._feature_sets:
            raise RegistryError(f"unknown feature set {name}:v{version}")
        return self._feature_sets[key]

    def latest_version(self, name: str) -> FeatureSetSpec:
        versions = [
            v
            for (n, v) in self._feature_sets
            if n == name and (n, v) not in self._archived
        ]
        if not versions:
            raise RegistryError(f"unknown feature set {name}")
        return self._feature_sets[(name, max(versions))]

    def archive(self, name: str, version: int) -> None:
        if (name, version) not in self._feature_sets:
            raise RegistryError(f"unknown feature set {name}:v{version}")
        self._archived.add((name, version))

    # -- search & discovery (§1: search and reuse) -------------------------------
    def search(
        self, text: str = "", *, tag: Optional[str] = None
    ) -> list[FeatureSetSpec]:
        out = []
        for key, spec in sorted(self._feature_sets.items()):
            if key in self._archived:
                continue
            blob = " ".join(
                [
                    spec.name,
                    spec.description,
                    *(f.name for f in spec.features),
                    *(f.description for f in spec.features),
                ]
            ).lower()
            if text.lower() in blob and (tag is None or tag in spec.tags):
                out.append(spec)
        return out

    def list_feature_sets(self) -> list[tuple[str, int]]:
        return sorted(k for k in self._feature_sets if k not in self._archived)

    # -- hub-and-spoke sharing (§4.1.1) --------------------------------------------
    def attach_workspace(self, ws: Workspace) -> None:
        self._spokes[ws.name] = ws

    def grant_access(self, workspace: str, asset: str = "*") -> None:
        self._grants.setdefault(workspace, set()).add(asset)

    def resolve_for_workspace(
        self, ws_name: str, name: str, version: int
    ) -> tuple[FeatureSetSpec, str]:
        """Spoke-side resolution.  Returns (spec, access_mode) where mode is
        'local' (same region) or 'cross-region' (ACL-gated, §4.1.2)."""
        if ws_name not in self._spokes:
            raise RegistryError(
                f"workspace {ws_name!r} is not attached to hub "
                f"{self.store_name!r} (hub-and-spoke required, §4.1.1)"
            )
        ws = self._spokes[ws_name]
        spec = self.get_feature_set(name, version)
        if ws.region == self.region:
            return spec, "local"
        grants = self._grants.get(ws_name, set())
        if "*" in grants or name in grants:
            return spec, "cross-region"
        raise RegistryError(
            f"workspace {ws_name!r} in region {ws.region!r} has no "
            f"cross-region grant for asset {name!r} (§4.1.2)"
        )
