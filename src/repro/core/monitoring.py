"""Health/monitoring subsystem (paper §3.1.2, §2.1 SLAs).

Built-in (system) metrics plus custom (user-defined) metrics, and the
paper's headline SLA metric: DATA STALENESS/FRESHNESS — how fresh the
feature data computed by the platform is.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Optional

__all__ = ["Metrics", "HealthMonitor"]


@dataclasses.dataclass
class _Histogram:
    values: list[float] = dataclasses.field(default_factory=list)

    def observe(self, v: float) -> None:
        self.values.append(v)

    def percentile(self, p: float) -> float:
        if not self.values:
            return float("nan")
        xs = sorted(self.values)
        i = min(len(xs) - 1, int(p / 100.0 * len(xs)))
        return xs[i]


class Metrics:
    def __init__(self) -> None:
        self.counters: dict[str, float] = defaultdict(float)
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, _Histogram] = defaultdict(_Histogram)

    def inc(self, name: str, by: float = 1.0) -> None:
        self.counters[name] += by

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        self.histograms[name].observe(value)

    def snapshot(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {
                k: {
                    "p50": h.percentile(50),
                    "p99": h.percentile(99),
                    "n": len(h.values),
                }
                for k, h in self.histograms.items()
            },
        }


class HealthMonitor:
    """System + custom metrics, alerting, and staleness tracking."""

    def __init__(self, alert_hook: Optional[Callable[[str], None]] = None):
        self.system = Metrics()
        self.custom = Metrics()
        self.alerts: list[str] = []
        self._alert_hook = alert_hook

    def alert(self, message: str) -> None:
        self.alerts.append(message)
        if self._alert_hook:
            self._alert_hook(message)

    # -- built-in signal helpers ------------------------------------------------
    def record_job(self, success: bool, retried: bool = False) -> None:
        self.system.inc("jobs_succeeded" if success else "jobs_failed")
        if retried:
            self.system.inc("jobs_retried")

    def record_staleness(
        self, feature_set: str, version: int, ms: Optional[int]
    ) -> None:
        if ms is not None:
            self.system.set_gauge(f"staleness_ms/{feature_set}:v{version}", float(ms))

    def record_lookup_latency(self, us: float) -> None:
        self.system.observe("online_lookup_us", us)

    def record_replication_lag(
        self,
        replica: str,
        *,
        batches: int,
        rows: int,
        staleness_ms: int,
        planes: Optional[dict] = None,
    ) -> None:
        """Per-replica geo-replication lag (§4.1.2 road-map mechanism): how
        many un-acked merge batches/rows the replica is behind, and how old
        the oldest pending batch is in clock units.  ``planes`` optionally
        breaks the counts down per store plane (online serving vs offline
        history), so an offline-only backlog is visible on its own gauge."""
        self.system.set_gauge(f"replication/lag_batches/{replica}", float(batches))
        self.system.set_gauge(f"replication/lag_rows/{replica}", float(rows))
        self.system.set_gauge(
            f"replication/staleness_ms/{replica}", float(staleness_ms)
        )
        for plane, d in (planes or {}).items():
            self.system.set_gauge(
                f"replication/lag_batches/{plane}/{replica}", float(d["batches"])
            )
            self.system.set_gauge(
                f"replication/lag_rows/{plane}/{replica}", float(d["rows"])
            )

    def record_replication_ship(
        self,
        rows: int,
        *,
        raw_nbytes: int,
        wire_nbytes: int,
        batches: int = 1,
        plane: Optional[str] = None,
    ) -> None:
        """One wire frame shipped to a replica.  Both byte counters are
        MEASURED off the encoded frame (core/wire.py), not estimated from
        array sizes: ``shipped_bytes`` is the post-compression wire size
        that actually crosses the WAN, ``shipped_raw_bytes`` the serialized
        payload before compression.  A coalesced frame carries several
        batches, so ``batches`` rides along explicitly."""
        self.system.inc("replication/shipped_frames")
        self.system.inc("replication/shipped_batches", batches)
        self.system.inc("replication/shipped_rows", rows)
        self.system.inc("replication/shipped_bytes", wire_nbytes)
        self.system.inc("replication/shipped_raw_bytes", raw_nbytes)
        if plane is not None:
            self.system.inc(f"replication/shipped_frames/{plane}")
            self.system.inc(f"replication/shipped_batches/{plane}", batches)
            self.system.inc(f"replication/shipped_rows/{plane}", rows)
            self.system.inc(f"replication/shipped_bytes/{plane}", wire_nbytes)
            self.system.inc(f"replication/shipped_raw_bytes/{plane}", raw_nbytes)

    def clear_replica_gauges(self, replica: str) -> None:
        """Drop every per-replica replication gauge when the replica leaves
        the serving set (drop, failover promotion, dead ex-home).  Gauges
        are last-value-wins: without this, a departed region keeps
        reporting its final lag/staleness forever, which reads as a live
        replica that stopped draining."""
        suffix = f"/{replica}"
        gauges = self.system.gauges
        for key in [
            k
            for k in gauges
            if k.startswith("replication/") and k.endswith(suffix)
        ]:
            del gauges[key]

    def healthy(self) -> bool:
        failed = self.system.counters.get("jobs_failed", 0)
        ok = self.system.counters.get("jobs_succeeded", 0)
        return failed == 0 or ok / max(ok + failed, 1) > 0.95
