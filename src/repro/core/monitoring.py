"""Health/monitoring subsystem (paper §3.1.2, §2.1 SLAs).

Built-in (system) metrics plus custom (user-defined) metrics, and the
paper's headline SLA metric: DATA STALENESS/FRESHNESS — how fresh the
feature data computed by the platform is.

Latency distributions are tracked by ``BoundedHistogram``: geometric
buckets of fixed relative width, so the serving front can observe every
request's stage latencies forever (p50/p99/p999) in O(1) memory instead of
accumulating one float per sample.
"""

from __future__ import annotations

import math
from collections import defaultdict
from typing import Callable, Optional

import numpy as np

__all__ = ["BoundedHistogram", "Metrics", "HealthMonitor"]


class BoundedHistogram:
    """Quantile sketch in O(1) memory: geometric buckets of relative width
    ``resolution`` spanning [lo, hi); values clamp into the edge buckets.

    A reported quantile is the geometric midpoint of the bucket holding the
    rank (clamped to the observed min/max), so it lands within ~resolution
    of the exact sample quantile — unit-tested against numpy on known
    distributions — while storage stays one fixed int64 bucket array
    (~500 entries at the defaults) no matter how many samples arrive.
    Default bounds cover 10 ns .. 1000 s in microsecond units, i.e. any
    latency this system can observe."""

    __slots__ = ("lo", "growth", "counts", "n", "total", "vmin", "vmax")

    def __init__(
        self, lo: float = 1e-2, hi: float = 1e9, resolution: float = 0.05
    ) -> None:
        self.lo = float(lo)
        self.growth = math.log1p(resolution)
        nbuckets = int(math.ceil(math.log(hi / lo) / self.growth)) + 1
        self.counts = np.zeros(nbuckets, np.int64)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _index(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = 1 + int(math.log(v / self.lo) / self.growth)
        return min(i, len(self.counts) - 1)

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[self._index(v)] += 1
        self.n += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def observe_batch(self, values) -> None:
        """Vectorized ``observe`` — one bincount instead of a Python loop
        (the serving front records per-ticket queue waits this way)."""
        values = np.asarray(values, np.float64)
        if values.size == 0:
            return
        idx = np.zeros(values.shape, np.int64)
        above = values > self.lo
        idx[above] = 1 + (np.log(values[above] / self.lo) / self.growth).astype(
            np.int64
        )
        np.clip(idx, 0, len(self.counts) - 1, out=idx)
        self.counts += np.bincount(idx, minlength=len(self.counts))
        self.n += values.size
        self.total += float(values.sum())
        self.vmin = min(self.vmin, float(values.min()))
        self.vmax = max(self.vmax, float(values.max()))

    def quantile(self, q: float) -> float:
        if self.n == 0:
            return float("nan")
        rank = min(max(int(math.ceil(q * self.n)), 1), self.n)
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank))
        # geometric midpoint of bucket i, clamped to the observed range; the
        # underflow bucket (everything <= lo) reports the observed min
        mid = self.lo * math.exp((i - 0.5) * self.growth) if i else self.vmin
        return min(max(mid, self.vmin), self.vmax)

    def percentile(self, p: float) -> float:
        return self.quantile(p / 100.0)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else float("nan")


class Metrics:
    def __init__(self) -> None:
        self.counters: dict[str, float] = defaultdict(float)
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, BoundedHistogram] = defaultdict(BoundedHistogram)

    def inc(self, name: str, by: float = 1.0) -> None:
        self.counters[name] += by

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        self.histograms[name].observe(value)

    def observe_batch(self, name: str, values) -> None:
        self.histograms[name].observe_batch(values)

    def snapshot(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {
                k: {
                    "p50": h.percentile(50),
                    "p99": h.percentile(99),
                    "p999": h.percentile(99.9),
                    "max": h.vmax,
                    "n": h.n,
                }
                for k, h in self.histograms.items()
            },
        }


class HealthMonitor:
    """System + custom metrics, alerting, and staleness tracking."""

    def __init__(self, alert_hook: Optional[Callable[[str], None]] = None):
        self.system = Metrics()
        self.custom = Metrics()
        self.alerts: list[str] = []
        self._alert_hook = alert_hook

    def alert(self, message: str) -> None:
        self.alerts.append(message)
        if self._alert_hook:
            self._alert_hook(message)

    # -- built-in signal helpers ------------------------------------------------
    def record_job(self, success: bool, retried: bool = False) -> None:
        self.system.inc("jobs_succeeded" if success else "jobs_failed")
        if retried:
            self.system.inc("jobs_retried")

    def record_staleness(
        self, feature_set: str, version: int, ms: Optional[int]
    ) -> None:
        if ms is not None:
            self.system.set_gauge(f"staleness_ms/{feature_set}:v{version}", float(ms))

    def record_lookup_latency(self, us: float) -> None:
        self.system.observe("online_lookup_us", us)

    def record_serving_stage(self, stage: str, us: float) -> None:
        """One serving-front pipeline stage (queue_wait / assembly / kernel /
        decode / request) for one dispatch — p50/p99/p999 per stage ride the
        bounded histograms, so the front can observe every request."""
        self.system.observe(f"serving/{stage}_us", us)

    def record_serving_stale_age(self, ms: float) -> None:
        """Age (logical ms since the cached row was superseded) of one
        degraded bounded-staleness serve — the serving front's overload
        escape hatch; the configured bound is asserted over this
        histogram's max."""
        self.system.observe("serving/stale_age_ms", ms)

    def record_replication_lag(self, replica: str, lag) -> None:
        """Per-replica geo-replication lag (§4.1.2 road-map mechanism): how
        many un-acked merge batches/rows the replica is behind, and how old
        the oldest pending batch is in clock units.  ``lag`` is a
        ``replication.LagStats`` (duck-typed here so monitoring stays
        import-free of the data plane); the per-plane breakdown (online
        serving vs offline history) gets its own gauges, so an offline-only
        backlog is visible rather than averaged away."""
        self.system.set_gauge(f"replication/lag_batches/{replica}", float(lag.batches))
        self.system.set_gauge(f"replication/lag_rows/{replica}", float(lag.rows))
        self.system.set_gauge(
            f"replication/staleness_ms/{replica}", float(lag.staleness_ms)
        )
        for plane, d in lag.planes.items():
            self.system.set_gauge(
                f"replication/lag_batches/{plane}/{replica}", float(d.batches)
            )
            self.system.set_gauge(
                f"replication/lag_rows/{plane}/{replica}", float(d.rows)
            )

    def record_shard_lag(
        self, replica: str, shard: int, *, batches: int, rows: int
    ) -> None:
        """Un-acked backlog of ONE shard-home's log toward one replica —
        the multi-home breakdown of ``record_replication_lag``.  The
        replica name sits MID-PATH (the shard id is the trailing segment),
        which is exactly the shape the old suffix-only
        ``clear_replica_gauges`` missed."""
        self.system.set_gauge(
            f"replication/shard_lag_batches/{replica}/{shard}", float(batches)
        )
        self.system.set_gauge(
            f"replication/shard_lag_rows/{replica}/{shard}", float(rows)
        )

    def record_shard_ownership(self, owners) -> None:
        """Current ShardMap assignment: per-shard owner index plus per-region
        owned-range counts, refreshed wholesale after any cutover."""
        regions = sorted(set(owners))
        for sid, region in enumerate(owners):
            self.system.set_gauge(
                f"shards/owner_index/{sid}", float(regions.index(region))
            )
        for region in regions:
            self.system.set_gauge(
                f"shards/owned/{region}",
                float(sum(1 for o in owners if o == region)),
            )

    def record_forwarded_write(self, src: str, dst: str, rows: int) -> None:
        """Rows a multi-home write split out of ``src``'s batch and routed
        to shard-home ``dst`` — the cross-region write-forwarding cost the
        multi-home bench gates as a fraction of total written rows."""
        self.system.inc("multihome/forwarded_rows", rows)
        self.system.inc(f"multihome/forwarded_rows/{src}/{dst}", rows)

    def record_replication_ship(
        self,
        rows: int,
        *,
        raw_nbytes: int,
        wire_nbytes: int,
        batches: int = 1,
        plane: Optional[str] = None,
    ) -> None:
        """One wire frame shipped to a replica.  Both byte counters are
        MEASURED off the encoded frame (core/wire.py), not estimated from
        array sizes: ``shipped_bytes`` is the post-compression wire size
        that actually crosses the WAN, ``shipped_raw_bytes`` the serialized
        payload before compression.  A coalesced frame carries several
        batches, so ``batches`` rides along explicitly."""
        self.system.inc("replication/shipped_frames")
        self.system.inc("replication/shipped_batches", batches)
        self.system.inc("replication/shipped_rows", rows)
        self.system.inc("replication/shipped_bytes", wire_nbytes)
        self.system.inc("replication/shipped_raw_bytes", raw_nbytes)
        if plane is not None:
            self.system.inc(f"replication/shipped_frames/{plane}")
            self.system.inc(f"replication/shipped_batches/{plane}", batches)
            self.system.inc(f"replication/shipped_rows/{plane}", rows)
            self.system.inc(f"replication/shipped_bytes/{plane}", wire_nbytes)
            self.system.inc(f"replication/shipped_raw_bytes/{plane}", raw_nbytes)

    def record_delivery_state(self, replica: str, state: str, code: int) -> None:
        """The delivery state machine's verdict on one replica link:
        HEALTHY(0) / SUSPECT(1) / DEAD(2).  The gauge is the current state
        code; the counter tallies transitions so a flapping link is visible
        even when the gauge reads healthy at scrape time."""
        self.system.set_gauge(f"replication/state/{replica}", float(code))
        self.system.inc(f"replication/state_transitions/{replica}")

    def record_delivery_retry(self, replica: str, batches: int) -> None:
        """Batches re-shipped to a replica after an earlier transmit went
        un-acked (timeout, drop, corruption) — the at-least-once transport's
        redundancy cost, a.k.a. retry amplification."""
        self.system.inc("replication/retries", batches)
        self.system.inc(f"replication/retries/{replica}", batches)

    def record_delivery_fault(self, replica: str, kind: str, n: int = 1) -> None:
        """One detected delivery fault on a replica link: ``timeout`` (no
        ack back in time), ``corrupt_frame`` (wire CRC rejected an arrival),
        or ``redelivered`` (an already-acked batch arrived again and was
        absorbed by per-seq dedup)."""
        self.system.inc(f"replication/{kind}", n)
        self.system.inc(f"replication/{kind}/{replica}", n)

    def clear_replica_gauges(self, replica: str) -> None:
        """Drop every per-replica replication gauge when the replica leaves
        the serving set (drop, failover promotion, dead ex-home).  Gauges
        are last-value-wins: without this, a departed region keeps
        reporting its final lag/staleness forever, which reads as a live
        replica that stopped draining.

        The match is on the replica as a FULL path segment ANYWHERE in the
        key, not just the suffix: per-shard gauges
        (``replication/shard_lag_batches/{replica}/{shard}``) put the
        replica mid-path, and the old suffix-only match left those behind —
        a rejoined region resurrected its pre-eviction per-shard lag
        readings."""
        gauges = self.system.gauges
        for key in [
            k
            for k in gauges
            if k.startswith("replication/") and replica in k.split("/")
        ]:
            del gauges[key]

    def healthy(self) -> bool:
        failed = self.system.counters.get("jobs_failed", 0)
        ok = self.system.counters.get("jobs_succeeded", 0)
        return failed == 0 or ok / max(ok + failed, 1) > 0.95
