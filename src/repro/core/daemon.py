"""Replica daemon: the real-socket carrier for geo-replication (ISSUE 8).

Everything above this module — the seq-ordered ``ReplicationLog``, the
``DeliveryState`` machine, the v2 ``core/wire.py`` frame codec — is
transport-agnostic; until now the one hop between publisher and replica
was an in-process function call (``InProcessChannel``).  This module
implements the hop for real: a **replica daemon** runs an
``OnlineStore`` + ``OfflineStore`` pair in a child process, receives
length-prefixed wire frames over a localhost TCP socket, applies them,
and acks the applied seqs back; a **``SocketChannel``** speaks the same
protocol from the publisher side, implementing the ``Channel.transmit``
seam (plus a pipelined ``post``/``collect`` interface the bounded
in-flight ``GeoReplicator`` drain window uses so encode, socket
transfer, and replica apply overlap instead of serializing).

Socket carrier protocol
-----------------------
One TCP connection carries a full-duplex stream of length-prefixed
messages in both directions (framing and codecs in ``core/wire.py``'s
stream-framing section)::

    u32 payload_len (little-endian) | payload

The payload's first two bytes name its kind:

``"FW"`` — a wire frame
    Exactly the bytes ``wire.encode_run`` produced (self-checksummed v2
    header + records).  Publisher -> daemon: a coalesced run of
    replicated batches, a bootstrap chunk (seq == ``BOOTSTRAP_SEQ``), or
    a zero-batch probe.  Daemon -> publisher: dump chunks streamed in
    reply to a ``dump`` control request.

``"FC"`` — a control message
    ``"FC" | u32 crc32(body) | body``, body UTF-8 JSON, always a dict
    with a ``"cmd"`` key.  Request/reply in FIFO order on the
    connection.  Verbs::

        hello     -> {ok, region, proto, pid, engine, offline}
        register  {schema}          -> {ok, table}   (idempotent)
        dump      {table, plane, chunk_rows} -> {ok, frames, rows},
                  then exactly ``frames`` "FW" messages of BOOTSTRAP_SEQ
                  batches (online: grouped by creation_ts; offline:
                  per-row creation_ts rides as a wire column)
        ledger    -> {ok, ledger}   (apply + stream-health counters)
        shutdown  -> {ok}, then the daemon closes every connection and
                  exits its serve loop (exit code 0)

``"FA"`` — an ack
    ``"FA" | u32 crc32(body) | body`` where body is ``u8 status |
    u32 msg_crc | i64 rows | u32 n_seqs | i64 seqs[n]``.  The daemon
    acks EVERY "FW" message it can attribute: ``msg_crc`` echoes crc32
    of the exact message payload bytes received — the publisher's
    correlation token (retried frames re-encode to identical bytes, so
    a late ack resolves the retry; the log's per-seq dedup makes that
    safe).  ``status`` is ``ACK_OK``, ``ACK_CORRUPT`` (checksum or
    structure rejected — nothing applied; the publisher counts a
    crc-reject and retries), or ``ACK_APPLY_ERROR`` (``seqs`` holds the
    applied prefix, so partial progress is never un-acked).

Handshake is implicit: connect, optionally ``hello``, then ship.  Table
schemas travel once per table as a ``register`` control (specs carry
arbitrary user transform code, which never crosses the wire — only the
JSON-serializable schema subset the apply path needs: entity join keys,
feature names/dtypes, plane enablement).  Shutdown is either a
``shutdown`` control or just closing the socket; the daemon also exits
after ``--idle-timeout`` seconds without traffic, so an orphaned child
whose parent died without cleanup reaps itself.

Fault-injecting proxy mode: give ``SocketChannel`` a seeded
``channel.FaultPlan`` and it perturbs its OWN sends deterministically —
drops (frame never hits the socket), duplicates (sent twice; the
daemon's idempotent apply absorbs the second), corruption (one byte
flipped inside the frame payload, envelope intact, so the daemon NACKs
with ``ACK_CORRUPT``), lost acks (the ack is awaited, then discarded),
and latency spikes (the measured RTT is inflated past the publisher's
ack timeout).  The ``DeliveryState`` machine above sees exactly the
failure surface it was chaos-tested against, now over a real socket.
"""

from __future__ import annotations

import argparse
import atexit
import collections
import dataclasses
import os
import select
import selectors
import socket
import subprocess
import sys
import time
import zlib
from typing import Optional, Sequence

import numpy as np

from repro.core import wire
from repro.core.assets import (
    Entity,
    Feature,
    FeatureSetSpec,
    MaterializationSettings,
)
from repro.core.channel import Delivery, FaultPlan
from repro.core.dsl import UDFTransform
from repro.core.offline_store import CREATION_TS, EVENT_TS, OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.regions import GeoTopology
from repro.core.replication import ReplicatedBatch

__all__ = [
    "DaemonHandle",
    "ReplicaDaemon",
    "SocketChannel",
    "schema_from_spec",
    "spec_from_schema",
    "spawn_replica_daemon",
]

PROTO_VERSION = 1
_BANNER = "REPLICA_DAEMON_LISTENING"
_RECV_CHUNK = 1 << 16


# -- schema transfer ----------------------------------------------------------
#
# FeatureSetSpec carries a transform (arbitrary user code — lambdas,
# closures); the replica apply path (merge_reduced / apply_chunks) never
# runs it, so only the schema subset crosses the wire and the daemon
# rebuilds a spec around an identity placeholder.


def schema_from_spec(spec: FeatureSetSpec) -> dict:
    """The JSON-serializable subset of a spec the replica apply path needs."""
    return {
        "name": spec.name,
        "version": spec.version,
        "entity": spec.entity.name,
        "join_keys": list(spec.entity.join_keys),
        "features": [[f.name, f.dtype] for f in spec.features],
        "online": bool(spec.materialization.online_enabled),
        "offline": bool(spec.materialization.offline_enabled),
    }


def spec_from_schema(schema: dict) -> FeatureSetSpec:
    """Rebuild an apply-side spec from a shipped schema dict."""
    return FeatureSetSpec(
        name=schema["name"],
        version=int(schema["version"]),
        entity=Entity(schema.get("entity", "entity"), tuple(schema["join_keys"])),
        features=tuple(Feature(n, d) for n, d in schema["features"]),
        source_name="__replicated__",
        transform=UDFTransform(lambda df, ctx: df, name="identity"),
        materialization=MaterializationSettings(
            offline_enabled=bool(schema.get("offline", True)),
            online_enabled=bool(schema.get("online", True)),
        ),
    )


# -- daemon (replica side) ----------------------------------------------------


class _Shutdown(Exception):
    """Raised inside the serve loop when a shutdown control arrives."""


class ReplicaDaemon:
    """A replica's store pair plus the socket protocol around it.

    Single-threaded event loop over a listening socket: any number of
    concurrent connections (the publisher's data connection plus
    control-only connections, e.g. the spawn helper's shutdown), each
    with its own ``StreamDecoder``, messages handled in arrival order.
    All apply-side semantics are exactly ``GeoReplicator._apply_decoded``:
    ``merge_reduced`` online (latest-wins, idempotent), ``apply_chunks``
    offline (full-key dedup), so redelivery and out-of-order frames
    converge here the same way they do in-process."""

    def __init__(
        self,
        *,
        region: str = "replica",
        merge_engine: str = "vector",
        offline: bool = True,
        num_partitions: int = 16,
        initial_capacity: int = 256,
        offline_shards: int = 4,
    ) -> None:
        self.region = region
        self.merge_engine = merge_engine
        self.online = OnlineStore(
            num_partitions, initial_capacity, merge_engine=merge_engine
        )
        self.offline: Optional[OfflineStore] = (
            OfflineStore(offline_shards, merge_engine=merge_engine)
            if offline
            else None
        )
        self._specs: dict[tuple[str, int], FeatureSetSpec] = {}
        #: shipped-frame ledger — what the transport smoke logs for
        #: debuggability and tests assert against
        self.ledger: dict[str, int] = {
            "messages": 0,
            "frames": 0,
            "probes": 0,
            "batches_applied": 0,
            "rows_applied": 0,
            "controls": 0,
            "dump_frames": 0,
            "nacks": 0,
            "apply_errors": 0,
        }
        self._stream_base = {"corrupt_messages": 0, "resyncs": 0, "skipped_bytes": 0}
        self._decoders: dict[int, wire.StreamDecoder] = {}

    # -- apply ----------------------------------------------------------------
    def _register(self, schema: dict) -> FeatureSetSpec:
        key = (schema["name"], int(schema["version"]))
        spec = self._specs.get(key)
        if spec is None:
            spec = spec_from_schema(schema)
            self._specs[key] = spec
        if spec.materialization.online_enabled:
            self.online.register(spec)
        if self.offline is not None and spec.materialization.offline_enabled:
            self.offline.register(spec)
        return spec

    def _apply(self, batch: ReplicatedBatch) -> dict:
        spec = self._specs[batch.table]  # unannounced table -> apply error
        if batch.plane == "offline":
            if self.offline is None:
                raise RuntimeError("daemon runs without an offline plane")
            cols = dict(batch.columns or {})
            creation = cols.pop(CREATION_TS, batch.creation_ts)
            return self.offline.apply_chunks(
                spec, batch.keys, batch.event_ts, creation, cols
            )
        return self.online.merge_reduced(
            spec, batch.keys, batch.event_ts, batch.values, batch.creation_ts
        )

    def _handle_frame(self, ev: wire.StreamEvent) -> bytes:
        """Apply one decoded frame's batches; return the ack payload."""
        self.ledger["frames"] += 1
        if not ev.batches:
            self.ledger["probes"] += 1
        status = wire.ACK_OK
        seqs: list[int] = []
        rows = 0
        for b in ev.batches or ():
            try:
                self._apply(b)
            except Exception:
                # ack the applied prefix rather than losing it; the
                # publisher treats APPLY_ERROR as a delivery failure
                status = wire.ACK_APPLY_ERROR
                self.ledger["apply_errors"] += 1
                break
            seqs.append(b.seq)
            rows += b.rows
        self.ledger["batches_applied"] += len(seqs)
        self.ledger["rows_applied"] += rows
        return wire.encode_ack(status, ev.msg_crc, rows, seqs)

    # -- dump (promotion adoption / verification) ------------------------------
    def _dump_frames(
        self, table: tuple[str, int], plane: str, chunk_rows: int
    ) -> list[wire.WireFrame]:
        """The daemon-side mirror of ``bootstrap_delta``'s chunking: the
        replica's current state for one (table, plane) as BOOTSTRAP_SEQ
        wire frames, bounded at ``chunk_rows`` rows apiece."""
        spec = self._specs.get(table)
        frames: list[wire.WireFrame] = []
        if spec is None:
            return frames
        name, version = table
        if plane == "online" and self.online.has(name, version):
            dump = self.online.dump_all(name, version)
            if len(dump):
                keys = dump["__key__"]
                event_ts = dump[EVENT_TS]
                creation_ts = dump[CREATION_TS]
                values = dump.column_stack(
                    [f.name for f in spec.features], np.float32
                )
                for cr in np.unique(creation_ts):
                    idx = np.flatnonzero(creation_ts == cr)
                    for lo in range(0, len(idx), chunk_rows):
                        sl = idx[lo : lo + chunk_rows]
                        frames.append(
                            wire.encode_batch(
                                ReplicatedBatch(
                                    seq=wire.BOOTSTRAP_SEQ,
                                    table=table,
                                    creation_ts=int(cr),
                                    keys=keys[sl],
                                    event_ts=event_ts[sl],
                                    values=values[sl],
                                )
                            )
                        )
        elif (
            plane == "offline"
            and self.offline is not None
            and self.offline.has(name, version)
        ):
            for chunk in self.offline.export_chunks(
                name, version, max_rows=chunk_rows
            ):
                if len(chunk) == 0:
                    continue
                cols = {
                    k: chunk[k]
                    for k in chunk.names
                    if k not in ("__key__", EVENT_TS)
                }
                frames.append(
                    wire.encode_batch(
                        ReplicatedBatch(
                            seq=wire.BOOTSTRAP_SEQ,
                            table=table,
                            creation_ts=int(chunk[CREATION_TS][0]),
                            keys=chunk["__key__"],
                            event_ts=chunk[EVENT_TS],
                            values=np.empty((len(chunk), 0), np.float32),
                            plane="offline",
                            columns=cols,
                        )
                    )
                )
        return frames

    # -- control --------------------------------------------------------------
    def _stream_counters(self) -> dict:
        out = dict(self._stream_base)
        for dec in self._decoders.values():
            out["corrupt_messages"] += dec.corrupt_messages
            out["resyncs"] += dec.resyncs
            out["skipped_bytes"] += dec.skipped_bytes
        return out

    def _handle_control(self, msg: dict) -> list[bytes]:
        """Execute one control verb; return the reply messages (already
        length-prefixed).  Raises ``_Shutdown`` after a shutdown reply."""
        self.ledger["controls"] += 1
        cmd = msg.get("cmd")
        if cmd == "hello":
            reply = {
                "ok": True,
                "cmd": "hello",
                "proto": PROTO_VERSION,
                "region": self.region,
                "pid": os.getpid(),
                "engine": self.merge_engine,
                "offline": self.offline is not None,
            }
            return [wire.frame_message(wire.encode_control(reply))]
        if cmd == "register":
            spec = self._register(msg["schema"])
            reply = {"ok": True, "cmd": "register", "table": list(spec.key)}
            return [wire.frame_message(wire.encode_control(reply))]
        if cmd == "dump":
            table = tuple(msg["table"])
            frames = self._dump_frames(
                table, msg.get("plane", "online"), int(msg.get("chunk_rows", 65_536))
            )
            self.ledger["dump_frames"] += len(frames)
            reply = {
                "ok": True,
                "cmd": "dump",
                "frames": len(frames),
                "rows": sum(f.rows for f in frames),
            }
            out = [wire.frame_message(wire.encode_control(reply))]
            out += [wire.frame_message(f.data) for f in frames]
            return out
        if cmd == "ledger":
            ledger = dict(self.ledger)
            ledger.update(self._stream_counters())
            return [
                wire.frame_message(
                    wire.encode_control({"ok": True, "cmd": "ledger", "ledger": ledger})
                )
            ]
        if cmd == "shutdown":
            raise _Shutdown()
        reply = {"ok": False, "cmd": cmd, "error": f"unknown control verb {cmd!r}"}
        return [wire.frame_message(wire.encode_control(reply))]

    # -- event loop ------------------------------------------------------------
    def _handle_events(
        self, conn: socket.socket, events: list[wire.StreamEvent]
    ) -> None:
        for ev in events:
            self.ledger["messages"] += 1
            if ev.kind == "frame":
                conn.sendall(wire.frame_message(self._handle_frame(ev)))
            elif ev.kind == "corrupt":
                # intact envelope, rejected payload: NACK it by content
                # crc so the publisher's crc-reject path fires promptly
                # instead of waiting out the ack timeout
                self.ledger["nacks"] += 1
                conn.sendall(
                    wire.frame_message(
                        wire.encode_ack(wire.ACK_CORRUPT, ev.msg_crc, 0, ())
                    )
                )
            elif ev.kind == "control":
                try:
                    for reply in self._handle_control(ev.control):
                        conn.sendall(reply)
                except _Shutdown:
                    conn.sendall(
                        wire.frame_message(
                            wire.encode_control({"ok": True, "cmd": "shutdown"})
                        )
                    )
                    raise
            # stray acks are ignored: the daemon never sends frames that
            # expect acknowledgement

    def serve_forever(
        self, sock: socket.socket, *, idle_timeout: Optional[float] = None
    ) -> None:
        """Serve until a shutdown control arrives or the stream has been
        idle for ``idle_timeout`` seconds (orphan self-reaping)."""
        sel = selectors.DefaultSelector()
        sock.setblocking(False)
        sel.register(sock, selectors.EVENT_READ, data="listener")
        last_traffic = time.monotonic()
        try:
            while True:
                ready = sel.select(timeout=1.0)
                if (
                    idle_timeout is not None
                    and time.monotonic() - last_traffic > idle_timeout
                ):
                    return
                for key, _ in ready:
                    if key.data == "listener":
                        conn, _addr = sock.accept()
                        conn.setblocking(True)
                        conn.setsockopt(
                            socket.IPPROTO_TCP, socket.TCP_NODELAY, 1
                        )
                        self._decoders[conn.fileno()] = wire.StreamDecoder()
                        sel.register(conn, selectors.EVENT_READ, data="conn")
                        continue
                    conn = key.fileobj
                    fd = conn.fileno()
                    data = b""
                    try:
                        data = conn.recv(_RECV_CHUNK)
                    except (ConnectionResetError, OSError):
                        pass
                    if not data:
                        dec = self._decoders.pop(fd, None)
                        if dec is not None:
                            for k in self._stream_base:
                                self._stream_base[k] += getattr(dec, k)
                        sel.unregister(conn)
                        conn.close()
                        continue
                    last_traffic = time.monotonic()
                    try:
                        self._handle_events(conn, self._decoders[fd].feed(data))
                    except _Shutdown:
                        return
                    except (BrokenPipeError, ConnectionResetError):
                        dec = self._decoders.pop(fd, None)
                        if dec is not None:
                            for k in self._stream_base:
                                self._stream_base[k] += getattr(dec, k)
                        sel.unregister(conn)
                        conn.close()
        finally:
            for key in list(sel.get_map().values()):
                if key.data == "conn":
                    key.fileobj.close()
            sel.close()
            self._decoders.clear()


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="replica daemon: apply wire frames from a socket"
    )
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0, help="0 = ephemeral")
    ap.add_argument("--region", default="replica")
    ap.add_argument("--engine", default="vector",
                    choices=("vector", "kernel", "loop"))
    ap.add_argument("--no-offline", action="store_true")
    ap.add_argument("--partitions", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument(
        "--idle-timeout",
        type=float,
        default=900.0,
        help="exit after this many silent seconds (orphan self-reaping); "
        "<= 0 disables",
    )
    args = ap.parse_args(argv)
    daemon = ReplicaDaemon(
        region=args.region,
        merge_engine=args.engine,
        offline=not args.no_offline,
        num_partitions=args.partitions,
        initial_capacity=args.capacity,
    )
    sock = socket.create_server((args.host, args.port))
    # the banner is the spawn contract: parents block on this line to
    # learn the ephemeral port, so it must be the first stdout output
    print(f"{_BANNER} {sock.getsockname()[1]}", flush=True)
    try:
        daemon.serve_forever(
            sock,
            idle_timeout=args.idle_timeout if args.idle_timeout > 0 else None,
        )
    finally:
        sock.close()
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    raise SystemExit(main())


# -- spawn helper (publisher side) --------------------------------------------


class DaemonHandle:
    """A spawned replica daemon child: its port, its process, and a
    teardown that cannot orphan it (shutdown control -> wait -> terminate
    -> kill, also registered via ``atexit``)."""

    def __init__(self, proc: subprocess.Popen, host: str, port: int) -> None:
        self.proc = proc
        self.host = host
        self.port = port
        self._closed = False
        atexit.register(self.close)

    def connect(self, timeout: float = 10.0) -> socket.socket:
        sock = socket.create_connection((self.host, self.port), timeout=timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def control(self, msg: dict, *, timeout: float = 10.0) -> Optional[dict]:
        """One-shot control request over a fresh connection."""
        with self.connect(timeout=timeout) as sock:
            sock.sendall(wire.frame_message(wire.encode_control(msg)))
            sock.settimeout(timeout)
            dec = wire.StreamDecoder()
            deadline = time.monotonic() + timeout
            while time.monotonic() < deadline:
                try:
                    data = sock.recv(_RECV_CHUNK)
                except (socket.timeout, OSError):
                    return None
                if not data:
                    return None
                for ev in dec.feed(data):
                    if ev.kind == "control":
                        return ev.control
        return None

    def close(self, timeout: float = 5.0) -> None:
        """Guaranteed teardown: polite shutdown first, escalate to
        terminate/kill — never leaves an orphan, green run or red."""
        if self._closed:
            return
        self._closed = True
        if self.proc.poll() is None:
            try:
                self.control({"cmd": "shutdown"}, timeout=2.0)
            except OSError:
                pass
            try:
                self.proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                self.proc.terminate()
                try:
                    self.proc.wait(timeout=timeout)
                except subprocess.TimeoutExpired:
                    self.proc.kill()
                    self.proc.wait()
        if self.proc.stdout is not None:
            self.proc.stdout.close()

    def __enter__(self) -> "DaemonHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def spawn_replica_daemon(
    *,
    region: str = "replica",
    merge_engine: str = "vector",
    offline: bool = True,
    num_partitions: int = 16,
    initial_capacity: int = 256,
    idle_timeout: float = 900.0,
    startup_timeout: float = 120.0,
) -> DaemonHandle:
    """Launch ``python -m repro.core.daemon`` as a child process and block
    until it announces its ephemeral port on stdout."""
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    cmd = [
        sys.executable,
        "-m",
        "repro.core.daemon",
        "--region", region,
        "--engine", merge_engine,
        "--partitions", str(num_partitions),
        "--capacity", str(initial_capacity),
        "--idle-timeout", str(idle_timeout),
    ]
    if not offline:
        cmd.append("--no-offline")
    proc = subprocess.Popen(
        cmd, stdout=subprocess.PIPE, env=env, text=True, bufsize=1
    )
    deadline = time.monotonic() + startup_timeout
    port: Optional[int] = None
    assert proc.stdout is not None
    while time.monotonic() < deadline:
        line = proc.stdout.readline()
        if not line:
            break  # child died before announcing
        if line.startswith(_BANNER):
            port = int(line.split()[1])
            break
    if port is None:
        proc.kill()
        proc.wait()
        raise RuntimeError(
            f"replica daemon for {region} failed to announce a port within "
            f"{startup_timeout:.0f}s"
        )
    return DaemonHandle(proc, "127.0.0.1", port)


# -- publisher-side channel ---------------------------------------------------


@dataclasses.dataclass
class _Send:
    """One posted frame awaiting its ack — the pipelined in-flight unit."""

    crc: int
    frame: object
    t0: float
    faults: tuple[str, ...] = ()
    ack_lost: bool = False
    extra_ms: float = 0.0
    delivery: Optional[Delivery] = None
    #: emulated-link maturity: the completion is not released to the
    #: caller before this monotonic instant (see ``min_rtt_ms``)
    ready_at: float = 0.0


class SocketChannel:
    """``Channel.transmit`` over a real socket to a replica daemon.

    Synchronous ``transmit`` posts one frame and blocks for its ack (or
    the timeout) — the drop-in carrier for the unchanged ``DeliveryState``
    machine.  The pipelined interface the bounded-window drain uses::

        token = ch.post(frame)      # None = injector ate it
        done  = ch.collect(ms)      # [(token, Delivery), ...] as acks land
        ch.forget(token)            # abandon an expired in-flight send

    Acks correlate to sends by content crc (see the module docstring), so
    a late ack from a timed-out transmit resolves the identical retry —
    at-least-once delivery with the log's per-seq dedup on top, exactly
    the in-process contract.

    ``fault_plan`` enables proxy mode: the plan's seeded schedule perturbs
    this channel's own sends (drop / dup / corrupt / ack_loss / spike;
    reorder is meaningless on one TCP stream and ignored).  ``counts``
    tallies injected faults like ``FaultyChannel.counts``.

    ``min_rtt_ms`` is netem-style link emulation: an ack is withheld from
    the caller until at least that long after its frame was posted, as if
    the bytes had crossed a WAN with that round-trip.  Localhost acks
    return in microseconds, which hides exactly the stall the pipelined
    window exists to absorb — with an emulated RTT the serialized path
    honestly pays one round-trip per frame while the windowed path keeps
    the link full.  The daemon still receives and applies frames at
    socket speed; only completion release is delayed (0 = off)."""

    is_remote = True

    def __init__(
        self,
        sock: socket.socket,
        *,
        src: str = "home",
        dst: str = "replica",
        topology: Optional[GeoTopology] = None,
        fault_plan: Optional[FaultPlan] = None,
        ack_timeout_ms: float = 5_000.0,
        min_rtt_ms: float = 0.0,
    ) -> None:
        self.sock = sock
        self.src = src
        self.dst = dst
        self.topology = topology
        self.plan = fault_plan
        self.ack_timeout_ms = float(ack_timeout_ms)
        self.min_rtt_ms = float(min_rtt_ms)
        self.sock.setblocking(True)
        try:
            self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        self._dec = wire.StreamDecoder()
        self._inflight: collections.deque[_Send] = collections.deque()
        self._completed: collections.deque[_Send] = collections.deque()
        self._ctrl_replies: collections.deque[dict] = collections.deque()
        self._dump_sink: Optional[list] = None
        self._tables: set[tuple[str, int]] = set()
        self.events: dict[str, int] = {}
        self.counts: dict[str, int] = {
            k: 0
            for k in (
                "transmits",
                "dropped",
                "duplicated",
                "corrupted",
                "ack_lost",
                "spiked",
                "partitioned",
                "stray_acks",
            )
        }

    # -- schema announcement ---------------------------------------------------
    def ensure_table(self, spec: FeatureSetSpec) -> None:
        """Announce one table's schema to the daemon (once per table)."""
        if spec.key in self._tables:
            return
        reply = self.request(
            {"cmd": "register", "schema": schema_from_spec(spec)}
        )
        if not (reply and reply.get("ok")):
            raise ConnectionError(f"replica daemon rejected schema: {reply}")
        self._tables.add(spec.key)

    # -- control request/reply -------------------------------------------------
    def request(
        self, msg: dict, *, timeout_ms: Optional[float] = None
    ) -> Optional[dict]:
        """Synchronous control round-trip (FIFO with any in-flight acks)."""
        self.sock.sendall(wire.frame_message(wire.encode_control(msg)))
        deadline = time.monotonic() + (
            timeout_ms if timeout_ms is not None else self.ack_timeout_ms
        ) / 1000.0
        while not self._ctrl_replies and time.monotonic() < deadline:
            self._pump(deadline)
        return self._ctrl_replies.popleft() if self._ctrl_replies else None

    def fetch_dump(
        self,
        spec: FeatureSetSpec,
        plane: str,
        *,
        chunk_rows: int = 65_536,
        timeout_ms: float = 60_000.0,
    ) -> list[ReplicatedBatch]:
        """Pull the daemon's current state for one (table, plane) as
        decoded BOOTSTRAP_SEQ batches — promotion adoption and the
        convergence checks read replica state through this."""
        sink: list[ReplicatedBatch] = []
        self._dump_sink = sink
        try:
            reply = self.request(
                {
                    "cmd": "dump",
                    "table": list(spec.key),
                    "plane": plane,
                    "chunk_rows": chunk_rows,
                },
                timeout_ms=timeout_ms,
            )
            if not (reply and reply.get("ok")):
                raise ConnectionError(f"dump of {spec.key} failed: {reply}")
            want = int(reply["frames"])
            deadline = time.monotonic() + timeout_ms / 1000.0
            while len(sink) < want and time.monotonic() < deadline:
                self._pump(deadline)
            if len(sink) < want:
                raise ConnectionError(
                    f"dump of {spec.key} truncated: {len(sink)}/{want} frames"
                )
        finally:
            self._dump_sink = None
        out: list[ReplicatedBatch] = []
        for batches in sink:
            out.extend(batches)
        return out

    def ledger(self) -> Optional[dict]:
        reply = self.request({"cmd": "ledger"})
        return reply.get("ledger") if reply else None

    # -- pipelined sends ---------------------------------------------------------
    def post(self, frame) -> Optional[_Send]:
        """Send one frame without waiting; returns the in-flight token, or
        None when the fault injector dropped the send entirely."""
        event = self.events.get(self.dst, 0)
        self.events[self.dst] = event + 1
        self.counts["transmits"] += 1
        faults: list[str] = self.plan.decide(self.dst, event) if self.plan else []
        if "partition" in faults:
            self.counts["partitioned"] += 1
            return None
        if "drop" in faults:
            self.counts["dropped"] += 1
            return None
        data = frame.data
        if "corrupt" in faults:
            self.counts["corrupted"] += 1
            data = self.plan.corrupt(self.dst, event, data)
        msg = wire.frame_message(data)
        self.sock.sendall(msg)
        if "dup" in faults:
            self.counts["duplicated"] += 1
            self.sock.sendall(msg)
        extra_ms = 0.0
        if "spike" in faults:
            self.counts["spiked"] += 1
            extra_ms = self.plan.spike_ms
        ack_lost = "ack_lost" in faults
        if ack_lost:
            self.counts["ack_lost"] += 1
        entry = _Send(
            crc=zlib.crc32(data),
            frame=frame,
            t0=time.monotonic(),
            faults=tuple(faults),
            ack_lost=ack_lost,
            extra_ms=extra_ms,
        )
        self._inflight.append(entry)
        return entry

    def _release_matured(self) -> list[tuple[_Send, Delivery]]:
        """Completions whose emulated-link maturity has passed.  Uniform
        ``min_rtt_ms`` keeps the completed deque ordered by ``ready_at``,
        so releasing is a prefix pop."""
        now = time.monotonic()
        out = []
        while self._completed and self._completed[0].ready_at <= now:
            entry = self._completed.popleft()
            out.append((entry, entry.delivery))
        return out

    def collect(self, timeout_ms: float) -> list[tuple[_Send, Delivery]]:
        """Wait up to ``timeout_ms`` for at least one in-flight completion
        to mature; drain and return everything matured so far."""
        deadline = time.monotonic() + timeout_ms / 1000.0
        while True:
            out = self._release_matured()
            if out:
                return out
            if not self._inflight and not self._completed:
                return []
            if time.monotonic() >= deadline:
                return []
            # wake at the earlier of the caller's deadline and the first
            # held completion's maturity instant
            wake = deadline
            if self._completed:
                wake = min(wake, self._completed[0].ready_at)
            if not self._pump(wake) and not self._completed:
                return []  # EOF (or deadline) with nothing held back

    def forget(self, token: _Send) -> None:
        """Abandon an expired in-flight send; its late ack (if any) will
        count as a stray or resolve a future identical retry."""
        try:
            self._inflight.remove(token)
        except ValueError:
            pass

    def transmit(self, src: str, dst: str, frame) -> Delivery:
        """The serialized ``Channel`` contract: post, await the ack."""
        token = self.post(frame)
        if token is None:
            return Delivery(
                arrivals=(),
                latency_ms=self.ack_timeout_ms,
                faults=("partition",) if self._partitioned_last() else ("drop",),
            )
        deadline = time.monotonic() + self.ack_timeout_ms / 1000.0
        while token.delivery is None and time.monotonic() < deadline:
            if not self._pump(deadline):
                break
        if token.delivery is None:
            self.forget(token)
            return Delivery(
                arrivals=(),
                latency_ms=self.ack_timeout_ms,
                faults=token.faults + ("timeout",),
            )
        # honor the emulated link: block until the ack would have arrived
        wait = token.ready_at - time.monotonic()
        if wait > 0:
            time.sleep(wait)
        try:
            self._completed.remove(token)
        except ValueError:
            pass
        return token.delivery

    def _partitioned_last(self) -> bool:
        plan, event = self.plan, self.events.get(self.dst, 1) - 1
        return bool(plan) and plan.partitioned(self.dst, event)

    # -- socket pump -------------------------------------------------------------
    def _pump(self, deadline: float) -> bool:
        """Read whatever the daemon sent (acks, control replies, dump
        frames) and route it.  Returns False on timeout/EOF."""
        wait = deadline - time.monotonic()
        if wait <= 0:
            return False
        ready, _, _ = select.select([self.sock], [], [], min(wait, 0.2))
        if not ready:
            return True  # keep waiting until the caller's deadline
        data = self.sock.recv(_RECV_CHUNK)
        if not data:
            return False
        for ev in self._dec.feed(data):
            if ev.kind == "ack":
                self._resolve(ev.ack)
            elif ev.kind == "control":
                self._ctrl_replies.append(ev.control)
            elif ev.kind == "frame":
                if self._dump_sink is not None:
                    self._dump_sink.append(ev.batches)
            # corrupt events on the return path are dropped: the
            # publisher-side retry machinery covers lost acks already
        return True

    def _resolve(self, ack: wire.Ack) -> None:
        rtt_ms = None
        for entry in self._inflight:
            if entry.crc == ack.msg_crc:
                rtt_ms = max(
                    (time.monotonic() - entry.t0) * 1e3, self.min_rtt_ms
                )
                entry.delivery = Delivery(
                    arrivals=(),
                    latency_ms=rtt_ms + entry.extra_ms,
                    ack_lost=entry.ack_lost,
                    faults=entry.faults,
                    remote=ack,
                )
                entry.ready_at = entry.t0 + self.min_rtt_ms / 1000.0
                self._inflight.remove(entry)
                self._completed.append(entry)
                break
        if rtt_ms is None:
            self.counts["stray_acks"] += 1
        elif self.topology is not None:
            self.topology.observe_rtt(self.src, self.dst, rtt_ms)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass
