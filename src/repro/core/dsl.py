"""Feature-transformation DSL (paper §3.1.6).

When customers define features through a UDF, the platform must treat the
transformation as a black box.  When they use the DSL — "a common case is
rolling window aggregation" — the query engine can optimize execution.  Our
optimizer does exactly what the paper sketches ("optimize the aggregation
based on join results"):

  * the (entity, timestamp) sort and per-row window-start index are computed
    ONCE and shared by every aggregation over the same window length;
  * aggregations over the same source column share the loaded column;
  * sum-family aggregations lower to the Pallas rolling-sum kernel
    (kernels/rolling_agg) — O(N) prefix work instead of O(N·W);
  * count is closed-form from the shared window indices (zero data reads).

``UDFTransform`` is the black-box path: an arbitrary
``udf(source_df, context) -> feature_df`` per §4.2.
"""

from __future__ import annotations

import dataclasses
import hashlib
import inspect
from typing import Any, Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.assets import TransformProtocol
from repro.core.table import Table
from repro.kernels.rolling_agg import ops as rolling_ops

__all__ = ["RollingAgg", "DslTransform", "UDFTransform", "SUPPORTED_AGGS"]

SUPPORTED_AGGS = ("sum", "mean", "count", "min", "max")


@dataclasses.dataclass(frozen=True)
class RollingAgg:
    """``<output> = <agg>(<source_col>) over trailing <window> ms per entity``."""

    output: str
    source_col: str
    window: int
    agg: str

    def __post_init__(self) -> None:
        if self.agg not in SUPPORTED_AGGS:
            raise ValueError(f"agg must be one of {SUPPORTED_AGGS}, got {self.agg!r}")
        if self.window <= 0:
            raise ValueError("window must be positive")


class DslTransform(TransformProtocol):
    """Declarative rolling-window aggregation plan, platform-optimizable."""

    is_dsl = True

    def __init__(
        self,
        entity_col: str | Sequence[str],
        timestamp_col: str,
        aggs: Sequence[RollingAgg],
        *,
        interpret: bool = True,
        use_kernel: bool = True,
    ) -> None:
        if not aggs:
            raise ValueError("DslTransform needs at least one aggregation")
        self.entity_cols = (
            (entity_col,) if isinstance(entity_col, str) else tuple(entity_col)
        )
        self.timestamp_col = timestamp_col
        self.aggs = tuple(aggs)
        self.interpret = interpret
        self.use_kernel = use_kernel
        outs = [a.output for a in self.aggs]
        if len(set(outs)) != len(outs):
            raise ValueError(f"duplicate DSL outputs: {outs}")

    # -- identity (immutable property of the feature set version) ----------
    def code_fingerprint(self) -> str:
        desc = repr(
            (self.entity_cols, self.timestamp_col,
             tuple((a.output, a.source_col, a.window, a.agg) for a in self.aggs))
        )
        return "dsl:" + hashlib.sha256(desc.encode()).hexdigest()[:16]

    @property
    def max_lookback(self) -> int:
        """What Algorithm 1 must use as ``source_lookback``."""
        return max(a.window for a in self.aggs)

    # -- optimized execution -------------------------------------------------
    def __call__(self, source_df: Table, context: dict[str, Any]) -> Table:
        n = len(source_df)
        # Shared sort by (entity..., ts): done once for the whole plan.
        sort_cols = (*self.entity_cols, self.timestamp_col)
        order = np.lexsort(tuple(source_df[c] for c in reversed(sort_cols)))
        sorted_df = source_df.take(order)
        ts = sorted_df[self.timestamp_col].astype(np.int64)
        seg = self._segment_ids(sorted_df)

        # Shared window-start indices per distinct window length.
        starts_by_window: dict[int, np.ndarray] = {}
        for a in self.aggs:
            if a.window not in starts_by_window:
                starts_by_window[a.window] = (
                    rolling_ops.window_starts(seg, ts, a.window)
                    if n
                    else np.zeros((0,), np.int32)
                )

        # Group sum/mean aggs that share a window so one kernel launch
        # covers all their source columns (columns stacked on the lane dim).
        out_cols: dict[str, np.ndarray] = {
            c: sorted_df[c] for c in (*self.entity_cols, self.timestamp_col)
        }
        kernel_groups: dict[int, list[RollingAgg]] = {}
        for a in self.aggs:
            if a.agg in ("sum", "mean") and n:
                kernel_groups.setdefault(a.window, []).append(a)

        for window, group in kernel_groups.items():
            cols = sorted(set(a.source_col for a in group))
            mat = np.stack([sorted_df[c].astype(np.float32) for c in cols], axis=1)
            sums = np.asarray(
                rolling_ops.rolling_agg(
                    jnp.asarray(mat), starts_by_window[window], "sum",
                    interpret=self.interpret,
                    backend="pallas" if self.use_kernel else "xla",
                )
            )
            counts = np.arange(n) + 1 - starts_by_window[window]
            for a in group:
                col = sums[:, cols.index(a.source_col)]
                if a.agg == "mean":
                    col = col / np.maximum(counts, 1)
                out_cols[a.output] = col.astype(np.float32)

        for a in self.aggs:
            if a.output in out_cols:
                continue
            starts = starts_by_window[a.window]
            if a.agg == "count":
                out_cols[a.output] = (np.arange(n) + 1 - starts).astype(np.float32)
            elif n == 0:
                out_cols[a.output] = np.zeros((0,), np.float32)
            else:
                vals = sorted_df[a.source_col].astype(np.float32)[:, None]
                out_cols[a.output] = np.asarray(
                    rolling_ops.rolling_agg(
                        jnp.asarray(vals), starts, a.agg, interpret=self.interpret
                    )
                )[:, 0].astype(np.float32)

        return Table(out_cols)

    def _segment_ids(self, sorted_df: Table) -> np.ndarray:
        n = len(sorted_df)
        if n == 0:
            return np.zeros((0,), np.int64)
        change = np.zeros(n, dtype=bool)
        for c in self.entity_cols:
            col = sorted_df[c]
            change[1:] |= col[1:] != col[:-1]
        return np.cumsum(change).astype(np.int64)


class UDFTransform(TransformProtocol):
    """Black-box user code: ``udf(source_df, context) -> feature_df`` (§4.2)."""

    is_dsl = False

    def __init__(self, fn: Callable[[Table, dict[str, Any]], Table], name: str = ""):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "udf")

    def code_fingerprint(self) -> str:
        try:
            src = inspect.getsource(self.fn)
        except (OSError, TypeError):
            src = repr(self.fn)
        return "udf:" + hashlib.sha256(src.encode()).hexdigest()[:16]

    def __call__(self, source_df: Table, context: dict[str, Any]) -> Table:
        return self.fn(source_df, context)
