"""Columnar table: the dataframe stand-in for the feature plane.

The paper's Algorithm 1 is a filter -> transform -> filter dataflow over Spark
dataframes.  On a TPU stack there is no Spark; the equivalent substrate is a
columnar batch of host arrays (numpy for mutation-friendly store state) that
compute layers lift to jnp.  A ``Table`` is a thin, schema-checked mapping of
column name -> 1-D (or 2-D for vector features) numpy array with the
relational verbs the feature store needs: filter, sort, concat, take, group.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Mapping, Sequence

import numpy as np

__all__ = ["Table", "concat_tables"]


@dataclasses.dataclass
class Table:
    """An immutable-by-convention columnar table."""

    columns: dict[str, np.ndarray]

    def __post_init__(self) -> None:
        if not self.columns:
            raise ValueError("Table requires at least one column")
        lengths = {k: len(v) for k, v in self.columns.items()}
        if len(set(lengths.values())) > 1:
            raise ValueError(f"ragged columns: {lengths}")
        # Normalize to numpy arrays without copying when possible.
        self.columns = {k: np.asarray(v) for k, v in self.columns.items()}

    # -- basic protocol ----------------------------------------------------
    def __len__(self) -> int:
        return len(next(iter(self.columns.values())))

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    def __getitem__(self, name: str) -> np.ndarray:
        return self.columns[name]

    def __iter__(self) -> Iterator[str]:
        return iter(self.columns)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self.columns)

    def num_rows(self) -> int:
        return len(self)

    # -- relational verbs --------------------------------------------------
    def select(self, names: Sequence[str]) -> "Table":
        return Table({n: self.columns[n] for n in names})

    def with_column(self, name: str, values: np.ndarray) -> "Table":
        cols = dict(self.columns)
        cols[name] = np.asarray(values)
        return Table(cols)

    def drop(self, names: Sequence[str]) -> "Table":
        return Table({k: v for k, v in self.columns.items() if k not in set(names)})

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        return Table({mapping.get(k, k): v for k, v in self.columns.items()})

    def filter(self, mask: np.ndarray) -> "Table":
        mask = np.asarray(mask, dtype=bool)
        return Table({k: v[mask] for k, v in self.columns.items()})

    def take(self, indices: np.ndarray) -> "Table":
        return Table({k: v[indices] for k, v in self.columns.items()})

    def sort_by(self, names: Sequence[str]) -> "Table":
        """Stable lexicographic sort; last key in ``names`` is most significant
        to np.lexsort, so reverse to get natural left-to-right priority."""
        keys = tuple(self.columns[n] for n in reversed(names))
        order = np.lexsort(keys)
        return self.take(order)

    def head(self, n: int) -> "Table":
        return Table({k: v[:n] for k, v in self.columns.items()})

    def filter_time_range(self, ts_col: str, start: int, end: int) -> "Table":
        """Rows with start <= ts < end (the paper's half-open feature window)."""
        ts = self.columns[ts_col]
        return self.filter((ts >= start) & (ts < end))

    def group_indices(self, names: Sequence[str]) -> dict[tuple, np.ndarray]:
        """Row indices per distinct key tuple (host-side; used by stores)."""
        keys = [self.columns[n] for n in names]
        out: dict[tuple, list[int]] = {}
        for i in range(len(self)):
            k = tuple(x[i].item() if hasattr(x[i], "item") else x[i] for x in keys)
            out.setdefault(k, []).append(i)
        return {k: np.asarray(v) for k, v in out.items()}

    def map_column(self, name: str, fn: Callable[[np.ndarray], np.ndarray]) -> "Table":
        return self.with_column(name, fn(self.columns[name]))

    def column_stack(
        self, names: Sequence[str], dtype: np.dtype = np.float32
    ) -> np.ndarray:
        """(N, len(names)) matrix of the named columns — the store-facing
        feature plane (one row per record, one column per feature)."""
        if not names:
            return np.zeros((len(self), 0), dtype)
        # np.stack copies anyway; asarray avoids a second copy per column
        # when the dtype already matches
        return np.stack([np.asarray(self.columns[n], dtype) for n in names], axis=1)

    def copy(self) -> "Table":
        return Table({k: v.copy() for k, v in self.columns.items()})

    def to_dict(self) -> dict[str, np.ndarray]:
        return dict(self.columns)

    def equals(self, other: "Table") -> bool:
        if set(self.names) != set(other.names) or len(self) != len(other):
            return False
        return all(np.array_equal(self[k], other[k]) for k in self.names)

    @staticmethod
    def empty(schema: Mapping[str, np.dtype]) -> "Table":
        return Table({k: np.empty((0,), dtype=d) for k, d in schema.items()})


def concat_tables(tables: Sequence[Table]) -> Table:
    tables = [t for t in tables if len(t) > 0] or list(tables[:1])
    if not tables:
        raise ValueError("concat of zero tables")
    names = tables[0].names
    for t in tables[1:]:
        if set(t.names) != set(names):
            raise ValueError(f"schema mismatch: {t.names} vs {names}")
    return Table({n: np.concatenate([t[n] for t in tables]) for n in names})
