"""Record-key codec shared by both stores.

The paper keys records by "ID(s)" — one or more index columns (§4.5.1).  The
stores operate on a single int64 surrogate key: a single integer join key maps
identically (so tests/debugging stay transparent); composite keys are mixed
into 64 bits (splitmix64) — a documented collision assumption at ~2^-64 per
pair, the standard trade for fixed-width device-side key tables.
Live keys are forced non-negative so the online store's -1 sentinel is safe.
"""

from __future__ import annotations

import numpy as np

__all__ = ["encode_keys"]

_C1 = np.uint64(0xBF58476D1CE4E5B9)
_C2 = np.uint64(0x94D049BB133111EB)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    z = x + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * _C1
    z = (z ^ (z >> np.uint64(27))) * _C2
    return z ^ (z >> np.uint64(31))


def encode_keys(columns: list[np.ndarray]) -> np.ndarray:
    """Combine one or more ID columns into non-negative int64 keys."""
    if len(columns) == 1 and np.issubdtype(np.asarray(columns[0]).dtype, np.integer):
        vals = np.asarray(columns[0], dtype=np.int64)
        if (vals >= 0).all():
            return vals
    acc = np.zeros(len(columns[0]), dtype=np.uint64)
    with np.errstate(over="ignore"):
        for col in columns:
            col = np.asarray(col)
            if np.issubdtype(col.dtype, np.integer):
                h = _splitmix64(col.astype(np.int64).view(np.uint64))
            else:
                h = np.asarray(
                    [np.uint64(hash(str(v)) & 0x7FFFFFFFFFFFFFFF) for v in col]
                )
                h = _splitmix64(h)
            acc = _splitmix64(acc ^ h)
    return (acc >> np.uint64(1)).view(np.int64)  # clear sign bit
