"""Record-key codec shared by both stores.

The paper keys records by "ID(s)" — one or more index columns (§4.5.1).  The
stores operate on a single int64 surrogate key: a single integer join key maps
identically (so tests/debugging stay transparent); composite keys are mixed
into 64 bits (splitmix64) — a documented collision assumption at ~2^-64 per
pair, the standard trade for fixed-width device-side key tables.
Live keys are forced non-negative so the online store's -1 sentinel is safe.

Multi-home sharding (``regions.ShardMap``) needs a UNIFORM coordinate over
``[0, 2**KEY_SPACE_BITS)`` so contiguous hash ranges split load evenly with
no per-key placement table.  Encoded keys are NOT that coordinate: the
single-integer transparency path above passes raw ids through unmixed, so
small id universes would all land in the first range.  ``shard_coordinate``
is: one more splitmix64 round over the encoded key, sign bit cleared —
uniform regardless of which encode path produced the key, and the SAME
mapping on every writer, so routing and the rebalance range filter agree.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "KEY_SPACE_BITS",
    "encode_keys",
    "encode_full_keys",
    "shard_coordinate",
]

#: Width of the shard-placement keyspace: ``shard_coordinate`` maps every
#: encoded key uniformly into [0, 2**63).  ShardMap range bounds live in
#: the same interval.
KEY_SPACE_BITS = 63

_C1 = np.uint64(0xBF58476D1CE4E5B9)
_C2 = np.uint64(0x94D049BB133111EB)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    z = x + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * _C1
    z = (z ^ (z >> np.uint64(27))) * _C2
    return z ^ (z >> np.uint64(31))


def encode_keys(columns: list[np.ndarray]) -> np.ndarray:
    """Combine one or more ID columns into non-negative int64 keys."""
    if len(columns) == 1 and np.issubdtype(np.asarray(columns[0]).dtype, np.integer):
        vals = np.asarray(columns[0], dtype=np.int64)
        if (vals >= 0).all():
            return vals
    acc = np.zeros(len(columns[0]), dtype=np.uint64)
    with np.errstate(over="ignore"):
        for col in columns:
            col = np.asarray(col)
            if np.issubdtype(col.dtype, np.integer):
                h = _splitmix64(col.astype(np.int64).view(np.uint64))
            else:
                h = _hash_object_column(col)
            acc = _splitmix64(acc ^ h)
    return (acc >> np.uint64(1)).view(np.int64)  # clear sign bit


def shard_coordinate(keys: np.ndarray) -> np.ndarray:
    """Uniform placement coordinate of ALREADY-ENCODED entity keys:
    uint64 in ``[0, 2**KEY_SPACE_BITS)``.

    One splitmix64 round over the encoded key, sign bit cleared.  This —
    not the raw encoded key — is what ``regions.ShardMap`` range-partitions
    and what the delta-bootstrap ``key_range`` filter masks on: the
    single-integer encode path is an identity mapping (transparency for
    tests/debugging), so raw keys cluster at the bottom of the keyspace
    whenever ids are small, while this coordinate is uniform for every
    encode path.  Pure per-key function, so every region computes the same
    routing with no coordination."""
    with np.errstate(over="ignore"):
        h = _splitmix64(np.asarray(keys, np.int64).view(np.uint64))
    return h >> np.uint64(1)


def encode_full_keys(ids: np.ndarray, event_ts: np.ndarray, creation_ts) -> np.ndarray:
    """Mix the offline store's FULL record key (id, event_ts, creation_ts)
    into one int64 — the §4.5 idempotence check key.

    Same splitmix64 composition (and the same documented ~2^-64 collision
    assumption) as composite entity keys above; collapsing the triple to a
    fixed-width integer is what lets full-key dedup run as a single sorted
    int64 ``searchsorted`` instead of tuple-set membership.
    """
    with np.errstate(over="ignore"):
        ev = np.asarray(event_ts, np.int64).view(np.uint64)
        cr = np.asarray(creation_ts, np.int64).view(np.uint64)
        # two mix rounds: ids and event_ts are decorrelated by the first,
        # creation_ts (constant per batch) folds into the second — one
        # fewer full-array pass than mixing each field separately
        h = _splitmix64(
            np.asarray(ids, np.int64).view(np.uint64) ^ (ev << np.uint64(1))
        )
        h = _splitmix64(h ^ ev ^ cr)
    # non-negative so signed and unsigned sort orders coincide (radix sort)
    return (h >> np.uint64(1)).view(np.int64)


def _hash_object_column(col: np.ndarray) -> np.ndarray:
    """Vectorized, process-stable hash of a non-integer id column.

    Values are rendered to a fixed-width unicode array, viewed as a
    (N, W) codepoint matrix, and folded one splitmix round per character
    column — O(W) vector ops instead of a per-row Python ``hash(str(v))``
    (which was also salted per process and therefore unusable for any
    persisted or cross-process key comparison).
    """
    s = col if col.dtype.kind == "U" else col.astype(np.str_)
    n = len(s)
    lengths = np.char.str_len(s).astype(np.uint64)
    width = s.dtype.itemsize // 4  # UCS4 codepoints per cell (array max)
    with np.errstate(over="ignore"):
        # Seed with the TRUE per-string length and only fold codepoints
        # inside it, so a value hashes identically regardless of the fixed
        # width of the array it happens to arrive in (write/read batches
        # rarely share a max width).
        h = _splitmix64(lengths)
        if width == 0:
            return h
        codes = np.ascontiguousarray(s).view(np.uint32).reshape(n, width)
        for j in range(width):
            active = j < lengths
            h = np.where(active, _splitmix64(h ^ codes[:, j].astype(np.uint64)), h)
    return h
