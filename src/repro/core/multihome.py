"""Active-active multi-home writes over sharded key ranges (§4.1.2 endgame).

``GeoFeatureStore`` is a single-home router: every write lands in one
region and fans out.  ``MultiHomeGeoStore`` makes EVERY region a write
home for part of the keyspace instead:

- A ``ShardMap`` (core/regions.py) hash-partitions the encoded entity
  keyspace into contiguous ranges, each owned by one home region.
  Ownership is a pure function of the key, so every entry region splits a
  write batch identically with no coordination.
- Each region is a full two-plane cell (OnlineStore + OfflineStore) AND a
  publisher: one ``GeoReplicator`` + ``ReplicationLog`` per region, with
  every other region as a replica.  A write entering region R splits by
  owning shard; the R-owned slice applies locally, foreign slices forward
  to their shard-homes (modeled one-way WAN charge, counted by the
  forwarded-write gauges).  Each home's merge listeners then publish ONLY
  its owned slice (``GeoReplicator._owned_slice``), which is what keeps
  the full mesh echo-free: a replica applying another home's batch
  publishes nothing.
- Reads split the query ids by range and route each range independently
  to the nearest IN-SYNC replica of that range's home (the home itself is
  always in sync); the modeled latency of the GET is the max over ranges,
  as the fan-out legs run concurrently.
- ``failover(region)`` is PER-SHARD: only the lost region's ranges move —
  ``GeoReplicator.promote`` replays the un-acked suffix into the nearest
  in-sync replica, the ShardMap reassigns just those ranges, and every
  other home keeps serving its own ranges untouched.  The promoted
  replicator is RETIRED (its publish listeners detach — the new owner's
  own replicator publishes for the reassigned ranges now) and kept only
  until its residual suffix drains to the surviving replicas.
- ``rejoin``/``join_region`` admit a (re)joining region by streaming each
  home's owned ranges over the delta-bootstrap path
  (``bootstrap_delta(key_range=...)``); ``rebalance`` moves one range:
  drain the source log DRY (so no in-flight batch published under the old
  ownership races the cutover), stream the moving range, cut the ShardMap
  over.  Convergence after any of this is the usual property: drained
  online stores are byte-identical, offline stores chunk-set-identical,
  at every region.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.channel import Channel, DeliveryError
from repro.core.keys import encode_keys
from repro.core.monitoring import HealthMonitor
from repro.core.offline_store import OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.regions import (
    GeoTopology,
    Region,
    RegionDownError,
    ShardMap,
)
from repro.core.replication import (
    DEFAULT_COMPRESS_LEVEL,
    DeliveryPolicy,
    GeoReplicator,
    LagStats,
    ReplicationLog,
)
from repro.core.table import Table

__all__ = ["MultiHomeGeoStore"]


class MultiHomeGeoStore:
    """Unified store front (``facade.StoreFacade``) over an active-active
    mesh of per-region cells.  Writes enter at ANY region and split by
    owning shard; reads compose per-range in-sync routing; failover and
    rebalance move individual ranges, not whole stores."""

    def __init__(
        self,
        name: str,
        *,
        topology: GeoTopology,
        regions: Sequence[str],
        shard_map: Optional[ShardMap] = None,
        num_shards: Optional[int] = None,
        max_lag_batches: int = 0,
        log_capacity: int = 1024,
        auto_drain: bool = False,
        compress_level: Optional[int] = DEFAULT_COMPRESS_LEVEL,
        channel: Optional[Channel] = None,
        delivery_policy: Optional[DeliveryPolicy] = None,
        offline_shards: int = 4,
        online_partitions: int = 16,
        interpret: bool = True,
        merge_engine: str = "vector",
        clock: Optional[Callable[[], int]] = None,
    ) -> None:
        regions = list(regions)
        if len(regions) < 2:
            raise ValueError("multi-home needs at least two regions")
        self.name = name
        self.topology = topology
        for r in regions:
            topology.regions.setdefault(r, Region(r))
        self.shard_map = (
            shard_map
            if shard_map is not None
            else ShardMap.even(regions, num_shards)
        )
        unknown = set(self.shard_map.owners) - set(regions)
        if unknown:
            raise ValueError(f"shard map owners {sorted(unknown)} not in regions")
        self.max_lag_batches = max_lag_batches
        self.auto_drain = auto_drain
        self.monitor = HealthMonitor()
        self._now = 0
        self.clock = clock or (lambda: self._now)
        self._store_cfg = {
            "online_partitions": online_partitions,
            "offline_shards": offline_shards,
            "interpret": interpret,
            "merge_engine": merge_engine,
        }
        self._log_capacity = log_capacity
        self._compress_level = compress_level
        self._channel = channel
        self._policy = delivery_policy
        self._specs: dict[tuple[str, int], FeatureSetSpec] = {}
        self.online: dict[str, OnlineStore] = {}
        self.offline: dict[str, OfflineStore] = {}
        #: one publisher per home; its log carries ONLY that home's owned
        #: slices (the listeners' shard filter), so per-home-log accounting
        #: (ship ledgers, lag) IS per-shard-group accounting
        self.replicators: dict[str, GeoReplicator] = {}
        #: failed-over publishers still draining their residual suffix to
        #: the surviving replicas; entries are {"label": dead_region,
        #: "rep": GeoReplicator} and drop off once dry
        self.retired: list[dict] = []
        #: running write-entry accounting (forwarded fraction is the
        #: multi-home bench gate)
        self.write_log = {"rows": 0, "local_rows": 0, "forwarded_rows": 0}
        for r in regions:
            self._new_cell(r)
        for h in regions:
            rep = self.replicators[h]
            for r in regions:
                if r != h:
                    rep.add_replica(r, self.online[r], self.offline[r])
        self.monitor.record_shard_ownership(self.shard_map.owners)

    # -- cell plumbing -------------------------------------------------------
    def _new_stores(self) -> tuple[OnlineStore, OfflineStore]:
        cfg = self._store_cfg
        online = OnlineStore(
            num_partitions=cfg["online_partitions"],
            interpret=cfg["interpret"],
            merge_engine=cfg["merge_engine"],
        )
        offline = OfflineStore(
            num_shards=cfg["offline_shards"],
            merge_engine=cfg["merge_engine"],
        )
        return online, offline

    def _new_cell(self, region: str) -> None:
        online, offline = self._new_stores()
        for spec in self._specs.values():
            if spec.materialization.online_enabled:
                online.register(spec)
            if spec.materialization.offline_enabled:
                offline.register(spec)
        self.online[region] = online
        self.offline[region] = offline
        self._new_cell_replicator(region)

    def _all_replicators(self) -> list[GeoReplicator]:
        return list(self.replicators.values()) + [
            entry["rep"] for entry in self.retired
        ]

    # -- clock / assets ------------------------------------------------------
    def advance_clock(self, to: int) -> None:
        self._now = max(self._now, to)

    def regions(self) -> list[str]:
        """Active home regions, construction order."""
        return list(self.replicators)

    def create_feature_set(self, spec: FeatureSetSpec) -> FeatureSetSpec:
        """Register one feature set on every cell — both planes — so any
        region can apply local slices and serve relaxed reads immediately."""
        self._specs[spec.key] = spec
        for r in self.replicators:
            if spec.materialization.online_enabled:
                self.online[r].register(spec)
            if spec.materialization.offline_enabled:
                self.offline[r].register(spec)
        return spec

    # -- writes (any region) -------------------------------------------------
    def write_batch(
        self,
        name: str,
        version: int,
        frame: Table,
        *,
        creation_ts: Optional[int] = None,
        region: Optional[str] = None,
    ) -> dict:
        """Multi-home ingest: the batch enters at ``region`` (default: the
        first home), splits by owning shard, applies the locally-owned
        slice in place and forwards each foreign slice to its shard-home
        (modeled one-way WAN hop, gauged).  Every slice lands at its OWN
        home, so each home's replication log carries it out to the mesh —
        no write ever applies first at a non-owner."""
        spec = self._specs[(name, version)]
        if region is None:
            region = next(iter(self.replicators))
        if region not in self.replicators:
            raise RegionDownError(f"region {region!r} is not an active home")
        creation = int(self.clock()) if creation_ts is None else int(creation_ts)
        ids = encode_keys([frame[c] for c in spec.index_columns])
        split = self.shard_map.split_by_owner(ids)
        out: dict = {
            "rows": len(frame),
            "creation_ts": creation,
            "region": region,
            "slices": {},
            "forwarded_rows": 0,
        }
        for owner in sorted(split):
            idx = split[owner]
            sub = frame if len(idx) == len(frame) else frame.take(idx)
            if spec.materialization.offline_enabled:
                self.offline[owner].merge_with_stats(spec, sub, creation)
            if spec.materialization.online_enabled:
                self.online[owner].merge(spec, sub, creation)
            out["slices"][owner] = int(len(idx))
            if owner != region:
                out["forwarded_rows"] += int(len(idx))
                self.monitor.record_forwarded_write(region, owner, int(len(idx)))
                self.monitor.system.observe(
                    "multihome/forward_ms", self.topology.latency(region, owner)
                )
        self.write_log["rows"] += len(frame)
        self.write_log["local_rows"] += out["slices"].get(region, 0)
        self.write_log["forwarded_rows"] += out["forwarded_rows"]
        if self.auto_drain:
            self.drain()
        return out

    # -- replication ---------------------------------------------------------
    def drain(self, region: Optional[str] = None) -> dict:
        """One drain pass of EVERY publisher (active homes + retired
        failover leftovers) toward all replicas, or just toward ``region``.
        Retired publishers drop off the moment their residual suffix is
        fully acked.  Returns per-publisher drain stats keyed by home
        (retired ones under ``retired:<dead-region>``)."""
        out: dict = {}
        for h, rep in list(self.replicators.items()):
            if region is None:
                out[h] = rep.drain()
            elif region in rep.delivery:
                out[h] = rep.drain(region)
        for entry in list(self.retired):
            rep = entry["rep"]
            if region is None:
                out[f"retired:{entry['label']}"] = rep.drain()
            elif region in rep.delivery:
                out[f"retired:{entry['label']}"] = rep.drain(region)
            if all(
                rep.log.pending_count(r) == 0 for r in rep.replica_regions()
            ):
                self.retired.remove(entry)
        self._refresh_lag_gauges()
        return out

    def pending_batches(self) -> int:
        """Total un-acked batches across every publisher — 0 means the mesh
        is fully converged (the chaos suite's drain-to-dry condition)."""
        return sum(
            rep.log.pending_count(r)
            for rep in self._all_replicators()
            for r in rep.replica_regions()
        )

    def converge(self, max_rounds: int = 64) -> int:
        """Drain until nothing is pending anywhere; returns the number of
        passes taken.  Raises ``DeliveryError`` if the mesh won't settle
        (a dead link that was never failed over)."""
        for i in range(max_rounds):
            if self.pending_batches() == 0:
                return i
            self.drain()
        raise DeliveryError(
            f"multi-home mesh did not converge within {max_rounds} drains"
        )

    def lag(self, region: str) -> LagStats:
        """How far ``region`` trails the REST OF THE MESH: the sum of every
        other publisher's un-acked backlog toward it (``LagStats.__add__``;
        staleness is the max across publishers).  Zero only when the
        region holds every other home's slices."""
        total = LagStats()
        for rep in self._all_replicators():
            if region != rep.home_region and region in rep.delivery:
                total = total + rep.lag(region)
        return total

    def _refresh_lag_gauges(self) -> None:
        for r in self.replicators:
            self.monitor.record_replication_lag(r, self.lag(r))
        # per-shard breakdown: a shard's lag gauge is its home-log backlog
        # toward the replica (exact when each home owns one range — the
        # bench topology; shared across a home's ranges otherwise)
        for h, rep in self.replicators.items():
            for sid in self.shard_map.owned_shards(h):
                for r in rep.replica_regions():
                    if r not in self.replicators:
                        continue
                    raw = rep.log.lag(r)
                    self.monitor.record_shard_lag(
                        r, sid, batches=raw.batches, rows=raw.rows
                    )

    # -- reads (per-range in-sync routing) -----------------------------------
    def route_shard_read(
        self,
        consumer_region: str,
        shard: int,
        *,
        max_lag_batches: Optional[int] = None,
    ) -> tuple[str, float]:
        """Serving region for one shard's key range: the consumer's own
        cell when it is healthy and in sync with the range's HOME log,
        else the nearest such region (the home itself is always in
        sync).  Returns (region, modeled one-way latency ms)."""
        max_lag = (
            self.max_lag_batches if max_lag_batches is None else max_lag_batches
        )
        home = self.shard_map.owner_of(shard)
        rep = self.replicators[home]
        candidates = [
            r
            for r in self.replicators
            if self.topology.regions[r].healthy
            and (
                r == home
                or (r in rep.delivery and rep.lag_batches(r) <= max_lag)
            )
        ]
        if not candidates:
            raise RegionDownError(
                f"no healthy in-sync replica of shard {shard} (home {home})"
            )
        if consumer_region in candidates:
            serving = consumer_region
        else:
            serving = min(
                candidates,
                key=lambda r: (self.topology.latency(consumer_region, r), r),
            )
        return serving, self.topology.latency(consumer_region, serving)

    def get_online_features(
        self,
        name: str,
        version: int,
        id_columns: list[np.ndarray],
        *,
        consumer_region: Optional[str] = None,
        use_kernel: bool = True,
        max_lag_batches: Optional[int] = None,
    ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Cross-shard online GET: ids split by owning range, each range
        routed independently (``route_shard_read``), results scattered
        back in request order.  ``route`` records the per-range serving
        choice; ``modeled_ms`` is the max over ranges — the legs fan out
        concurrently, so the slowest range bounds the GET."""
        spec = self._specs[(name, version)]
        consumer = consumer_region or next(iter(self.replicators))
        ids = encode_keys(list(id_columns))
        n = len(ids)
        vals = np.zeros((n, len(spec.features)), np.float32)
        found = np.zeros(n, bool)
        route: dict = {"consumer": consumer, "per_range": {}, "modeled_ms": 0.0}
        now = self.clock()
        shards = self.shard_map.shard_of(ids)
        for sid in np.unique(shards):
            serving, ms = self.route_shard_read(
                consumer, int(sid), max_lag_batches=max_lag_batches
            )
            idx = np.flatnonzero(shards == sid)
            v, f, _ = self.online[serving].lookup_encoded(
                name, version, ids[idx], now=now, use_kernel=use_kernel
            )
            vals[idx] = v
            found[idx] = f
            route["per_range"][int(sid)] = {"region": serving, "modeled_ms": ms}
            route["modeled_ms"] = max(route["modeled_ms"], ms)
        self.monitor.system.observe("geo/read_modeled_ms", route["modeled_ms"])
        return vals, found, route

    # -- failure handling ----------------------------------------------------
    def mark_down(self, region: str) -> None:
        self.topology.mark_down(region)

    def mark_up(self, region: str) -> None:
        self.topology.mark_up(region)

    def failover(self, region: Optional[str] = None) -> Optional[dict]:
        """PER-SHARD failover: promote ONLY the lost region's ranges to the
        nearest in-sync replica of its log (``GeoReplicator.promote``
        replays the un-acked suffix there first, so nothing acked to the
        dead home is lost), reassign those ranges in the ShardMap, and
        drop the dead cell from every surviving publisher.  Every other
        home keeps its ranges — the blast radius is one region's slice of
        the keyspace, not the whole store.

        The promoted replicator's publish listeners are DETACHED: once the
        ShardMap reassigns the ranges, the new owner's OWN replicator
        publishes for them — leaving the promoted listeners attached would
        double-publish every new write at the promoted home.  The old log
        is retired, kept only until its residual suffix (batches the dead
        home had published but not every replica had acked) drains dry.

        ``region`` defaults to the first unhealthy active home; returns
        None when nothing is down."""
        if region is None:
            region = next(
                (
                    r
                    for r in self.replicators
                    if not self.topology.regions[r].healthy
                ),
                None,
            )
            if region is None:
                return None
        if region not in self.replicators:
            raise ValueError(f"region {region!r} is not an active home")
        if self.topology.regions[region].healthy:
            return None
        rep = self.replicators.pop(region)
        lost = self.shard_map.owned_shards(region)
        promoted = None
        replay = {"replayed_batches": 0, "replayed_rows": 0}
        if lost:
            healthy = [
                r
                for r in rep.replica_regions()
                if r in self.replicators and self.topology.regions[r].healthy
            ]
            if not healthy:
                raise RegionDownError(
                    f"no healthy replica to take {region}'s ranges"
                )
            in_sync = [
                r for r in healthy if rep.lag_batches(r) <= self.max_lag_batches
            ]
            pool = in_sync or healthy
            promoted = min(
                pool, key=lambda r: (self.topology.latency(region, r), r)
            )
            replay = rep.promote(promoted)
            self.online[promoted].merge_listeners.remove(rep._on_home_merge)
            self.offline[promoted].merge_listeners.remove(
                rep._on_home_offline_merge
            )
            for sid in lost:
                self.shard_map.assign(sid, promoted)
        for other in self.replicators.values():
            if region in other.delivery:
                other.evict_replica(region)
        for entry in self.retired:
            if region in entry["rep"].delivery:
                entry["rep"].evict_replica(region)
        if lost and any(
            rep.log.pending_count(r) for r in rep.replica_regions()
        ):
            self.retired.append({"label": region, "rep": rep})
        self.online.pop(region, None)
        self.offline.pop(region, None)
        self.monitor.clear_replica_gauges(region)
        self.monitor.record_shard_ownership(self.shard_map.owners)
        return {"promoted": promoted, "shards": lost, **replay}

    # -- membership (join/leave/rebalance) -----------------------------------
    def rejoin(self, region: str, *, chunk_rows: int = 65_536) -> dict:
        """Re-admit a recovered region: fresh two-plane cell, then each
        active home streams its OWNED ranges over the delta-bootstrap path
        (snapshot cut + catch-up from the registered cursor) — the union
        of owned ranges covers the whole keyspace, so the cell comes back
        complete, each range from its authoritative home.  The region
        returns with ZERO owned ranges (its old ones were promoted away);
        ``rebalance`` hands ranges back explicitly."""
        if region not in self.topology.regions:
            raise ValueError(f"unknown region {region}")
        if not self.topology.regions[region].healthy:
            raise RegionDownError(f"region {region} is still down; mark_up first")
        if region in self.replicators:
            raise ValueError(f"region {region} is already in the serving set")
        return {"rejoined": region, **self._admit(region, chunk_rows=chunk_rows)}

    def join_region(
        self,
        region: str,
        *,
        take_shards: Sequence[int] = (),
        chunk_rows: int = 65_536,
    ) -> dict:
        """Admit a brand-new region and optionally hand it ranges: admit
        (full per-home owned-range bootstrap), then ``rebalance`` each of
        ``take_shards`` onto it."""
        self.topology.regions.setdefault(region, Region(region))
        if region in self.replicators:
            raise ValueError(f"region {region} is already in the serving set")
        stats = self._admit(region, chunk_rows=chunk_rows)
        moves = [
            self.rebalance(int(sid), region, chunk_rows=chunk_rows)
            for sid in take_shards
        ]
        return {"joined": region, "moves": moves, **stats}

    def leave_region(self, region: str, *, chunk_rows: int = 65_536) -> dict:
        """Graceful leave: hand each owned range to the nearest surviving
        home (full ``rebalance`` per range — drain dry, stream, cut over),
        then retire the cell from every publisher."""
        if region not in self.replicators:
            raise ValueError(f"region {region!r} is not an active home")
        if len(self.replicators) < 3:
            raise ValueError("leaving would drop the mesh below two homes")
        moves = []
        for sid in list(self.shard_map.owned_shards(region)):
            dst = min(
                (r for r in self.replicators if r != region),
                key=lambda r: (self.topology.latency(region, r), r),
            )
            moves.append(self.rebalance(sid, dst, chunk_rows=chunk_rows))
        rep = self.replicators.pop(region)
        for _ in range(rep.policy.promote_rounds):
            if all(
                rep.log.pending_count(r) == 0 for r in rep.replica_regions()
            ):
                break
            rep.drain(force=True)
        else:
            raise DeliveryError(f"{region}'s log would not drain dry on leave")
        for other in self.replicators.values():
            if region in other.delivery:
                other.evict_replica(region)
        for entry in self.retired:
            if region in entry["rep"].delivery:
                entry["rep"].evict_replica(region)
        self.online.pop(region)
        self.offline.pop(region)
        self.monitor.clear_replica_gauges(region)
        self.monitor.record_shard_ownership(self.shard_map.owners)
        return {"left": region, "moves": moves}

    def rebalance(
        self, shard: int, to_region: str, *, chunk_rows: int = 65_536
    ) -> dict:
        """Move ONE range to a new home in three steps: (1) drain the
        current owner's log DRY, so every batch published under the old
        ownership lands everywhere before the cutover (an in-flight batch
        applied at the new owner AFTER it takes ownership would re-publish
        — a bounded echo the drain avoids entirely); (2) stream the moving
        range over ``bootstrap_delta(key_range=...)`` — idempotent top-up,
        a long-standing replica already holds it from normal replication;
        (3) cut the ShardMap over.  New writes for the range route to
        ``to_region`` from the moment ``assign`` bumps the version."""
        frm = self.shard_map.owner_of(shard)
        if to_region == frm:
            return {"shard": shard, "from": frm, "to": to_region, "moved": False}
        if to_region not in self.replicators:
            raise ValueError(
                f"{to_region!r} is not an active home; join_region first"
            )
        src = self.replicators[frm]
        for _ in range(src.policy.promote_rounds):
            if all(
                src.log.pending_count(r) == 0 for r in src.replica_regions()
            ):
                break
            src.drain(force=True)
        else:
            raise DeliveryError(
                f"shard {shard} rebalance: {frm}'s log would not drain dry"
            )
        lo, hi = self.shard_map.shard_range(shard)
        streamed = {"online_rows": 0, "offline_rows": 0, "chunks": 0}
        for spec in self._specs.values():
            got = src.bootstrap_delta(
                to_region, spec, chunk_rows=chunk_rows, key_range=(lo, hi)
            )
            for k in streamed:
                streamed[k] += got[k]
        self.shard_map.assign(shard, to_region)
        self.monitor.system.inc("shards/rebalances")
        self.monitor.record_shard_ownership(self.shard_map.owners)
        return {
            "shard": shard,
            "from": frm,
            "to": to_region,
            "moved": True,
            **streamed,
        }

    def _admit(self, region: str, *, chunk_rows: int) -> dict:
        """Shared join/rejoin data path: fresh cell, replica-of-everyone
        (each home streams its owned ranges), publisher-of-nothing (a
        fresh replicator with an empty log and no owned shards — its
        listeners' shard filter publishes nothing until ``rebalance``
        assigns it a range)."""
        online, offline = self._new_stores()
        for spec in self._specs.values():
            if spec.materialization.online_enabled:
                online.register(spec)
            if spec.materialization.offline_enabled:
                offline.register(spec)
        self.online[region] = online
        self.offline[region] = offline
        totals = {"online_rows": 0, "offline_rows": 0, "chunks": 0}
        for h, rep in self.replicators.items():
            rep.add_replica(region, online, offline)
            for sid in self.shard_map.owned_shards(h):
                key_range = self.shard_map.shard_range(sid)
                for spec in self._specs.values():
                    got = rep.bootstrap_delta(
                        region, spec, chunk_rows=chunk_rows, key_range=key_range
                    )
                    for k in totals:
                        totals[k] += got[k]
        peers = list(self.replicators)
        self._new_cell_replicator(region)
        for r in peers:
            self.replicators[region].add_replica(
                r, self.online[r], self.offline[r]
            )
        self.monitor.record_shard_ownership(self.shard_map.owners)
        return totals

    def _new_cell_replicator(self, region: str) -> None:
        self.replicators[region] = GeoReplicator(
            self.online[region],
            topology=self.topology,
            home_region=region,
            home_offline=self.offline[region],
            log=ReplicationLog(capacity=self._log_capacity),
            clock=self.clock,
            monitor=self.monitor,
            compress_level=self._compress_level,
            channel=self._channel,
            policy=self._policy,
            shard_map=self.shard_map,
        )
