"""The ONE store surface (paper §2.1's functional facade, made explicit).

``FeatureStore`` (single region), ``GeoFeatureStore`` (single-home
geo-replicated), and ``MultiHomeGeoStore`` (active-active sharded) grew up
separately; serving code, examples, and benchmarks used to program against
whichever concrete surface they were handed — including an implicit
``__getattr__`` passthrough on ``GeoFeatureStore`` that made the real API
invisible.  ``StoreFacade`` names the shared contract instead: asset
registration, batch writes, online GET, replication lag, failover/rejoin,
drain.  All three stores satisfy it (asserted by ``isinstance`` in the
facade tests — the protocol is runtime-checkable), and anything driving "a
store" should take a ``StoreFacade``, not a concrete class.

The degenerate cases are explicit rather than papered over: a single-region
``FeatureStore`` reports zero lag, has nothing to fail over, and raises on
``rejoin`` — the honest answers, not missing attributes.
"""

from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.table import Table

__all__ = ["StoreFacade"]


@runtime_checkable
class StoreFacade(Protocol):
    """What every store front answers for: writes, online reads, lag,
    failover/rejoin, drain.  ``runtime_checkable`` — tests assert each
    concrete store satisfies it (method presence; signatures are enforced
    by the shared facade test exercising each method for real)."""

    def create_feature_set(self, spec: FeatureSetSpec) -> FeatureSetSpec:
        """Register one feature set (every region/plane that serves it)."""
        ...

    def write_batch(
        self,
        name: str,
        version: int,
        frame: Table,
        *,
        creation_ts: Optional[int] = None,
        region: Optional[str] = None,
    ) -> dict:
        """Ingest one frame.  ``region`` is where the write LANDS: ignored
        by single-region stores, the home region for single-home geo
        (writes always land there), and the entry region for multi-home
        (the batch splits by owning shard from there)."""
        ...

    def get_online_features(
        self, name: str, version: int, id_columns: list[np.ndarray], **kwargs
    ) -> tuple:
        """Online GET: (values, found[, route]) — geo stores append the
        routing record."""
        ...

    def lag(self, region: str):
        """Replication lag of one region as a ``replication.LagStats``
        (all-zeros for the home / a single-region store)."""
        ...

    def drain(self, region: Optional[str] = None) -> dict:
        """Ship pending replication (no-op dict for single-region)."""
        ...

    def failover(self, region: Optional[str] = None):
        """React to a lost region: promote its range(s)/store to the
        nearest in-sync replica.  None when there is nothing to do."""
        ...

    def rejoin(self, region: str, **kwargs) -> dict:
        """Re-admit a recovered region via delta bootstrap."""
        ...
