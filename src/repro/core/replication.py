"""Async geo-replication of BOTH store planes (paper §2.1, §4.1.2 road map).

The paper's implemented mechanism keeps an asset in its creation region and
pays WAN latency on every remote read; its road-map mechanism replicates the
asset into consumer regions so reads are local.  This module is that road-map
mechanism made concrete for both materialization targets: the paper's store
is only a feature store because the SAME data lands offline (training) and
online (inferencing), so a failover that recovers one plane but not the
other reintroduces exactly the online–offline skew the architecture exists
to prevent.  Both planes ship through one log:

  * ONLINE plane — every ``OnlineStore.merge`` reduces a materialization
    frame to the winning writes it actually applied (encoded key, winning
    event_ts, feature row, one shared creation_ts) and reports them in its
    stats (PR 2's shipping unit);
  * OFFLINE plane — every ``OfflineStore.merge`` reports the rows it
    actually INSERTED (post full-key dedup, arrival order): encoded entity
    keys + event_ts flat arrays plus the index/feature columns in native
    dtypes.  Replica-side ``OfflineStore.apply_chunks`` re-runs the same
    full-key dedup, so a replica's shard-chunk set converges to the home's.

Two-plane ``ReplicatedBatch`` protocol
--------------------------------------
A batch tags ``plane="online"|"offline"`` over one shared sequence: the
``ReplicationLog`` is ONE totally-ordered log per home store, and each
replica owns ONE cursor covering both planes — per-replica cursor semantics,
out-of-order ack handling, truncation, and backpressure are plane-agnostic.
``keys``/``event_ts``/``values`` are flat planes for both variants; offline
batches add ``columns`` (index + native-dtype feature arrays, the record-
schema remainder) and leave ``values`` empty.  ``ReplicationLog.lag``
reports a per-plane breakdown on top of the combined counts.

Wire transport (core/wire.py)
-----------------------------
Replica-bound batches do NOT travel as in-process references: every batch a
replica receives — drain, out-of-order ``apply_batch``, delta bootstrap,
failover replay — is serialized into a contiguous wire frame (fixed header
+ length-prefixed dtype-tagged arrays, optional zlib), shipped over the
modeled WAN, and DECODED on the replica side; the replica applies read-only
views of the received buffer, so it can never alias or corrupt publisher
memory.  The log itself stores frozen private copies on ``append`` for the
same reason (an un-shipped batch must survive later in-place mutation of
the publisher's buffers).  ``drain`` coalesces runs of adjacent same-plane
same-table pending batches into one frame per run (one header, one shared
compression stream), while acking each constituent batch by its own seq.
Shipping accounting (``GeoReplicator.shipped``, the monitor's
``replication/shipped_*`` counters) records MEASURED bytes — serialized
raw payload and post-compression wire size — and ``topology.transfer_ms``
prices the wire size, making the per-plane shipped-bytes benchmarks true
transport measurements rather than array-size estimates.

Failure model (delivery state machine, core/channel.py)
-------------------------------------------------------
The hop under ``_ship_frame`` is a pluggable ``Channel``:
``InProcessChannel`` (the default) is perfect and keeps every
deterministic gate unchanged; ``FaultyChannel`` drops, duplicates,
reorders, corrupts, delays, and partitions frames on a seeded
deterministic schedule.  Against either, delivery is AT-LEAST-ONCE:

  * a frame's batches are acked per-seq only after the replica decodes
    (wire CRC verified) and applies them AND the ack path returns inside
    ``DeliveryPolicy.ack_timeout_ms`` — anything else (drop, partition,
    corruption, lost/late ack) leaves them pending for redelivery;
  * redelivery is EXACTLY-ONCE IN EFFECT: the online plane's latest-wins
    merge on (event_ts, creation_ts) and the offline plane's full-key
    insert-if-absent make re-applying a batch a no-op, and
    ``ReplicationLog.is_acked`` per-seq dedup counts (never re-acks) a
    batch that arrives again;
  * each replica link runs a per-replica ``DeliveryState``: after a
    failed drain the link backs off for ``min(cap, base << n-1)`` drain
    ticks plus deterministic per-(replica, n) jitter; after
    ``suspect_after`` consecutive failures the link is SUSPECT, after
    ``dead_after`` it is DEAD — which drives ``topology.mark_down``, so
    read routing and ``failover()`` react to DETECTED failure, not
    manual flips;
  * a DEAD link is re-probed every ``probe_interval`` ticks with a
    zero-batch probe frame; the first success flips it back HEALTHY
    (``topology.mark_up``) and normal draining resumes — or, past
    ``evict_after`` failures, the replica is evicted entirely and
    re-admitted later through the ``rejoin``/delta-bootstrap path
    (``GeoFeatureStore.drain`` auto-probes evicted regions);
  * transfers that MUST complete (bootstrap chunks, promotion replay)
    retry against the channel a bounded number of times and raise
    ``DeliveryError`` when the budget is exhausted — never silent loss.

Log / cursor / replay protocol
------------------------------
``ReplicationLog`` is a bounded, totally-ordered sequence of reduced
batches, appended by listeners on the home stores' ``merge_listeners``.
Each replica owns a CURSOR: the lowest sequence number it has not yet
acknowledged.  The async applier (``GeoReplicator.drain``) ships pending
batches over the modeled WAN link and applies them to the replica stores —
``OnlineStore.merge_reduced`` (the same Algorithm-2 engines the home store
runs) or ``OfflineStore.apply_chunks`` by plane.  Acknowledgements may
arrive out of order (``apply_batch``); the cursor only advances over the
contiguous acknowledged prefix, so lag accounting never under-reports.
``truncate`` drops exactly the prefix below EVERY cursor — an un-acked
batch is never dropped; when the log is full and no prefix is fully
acknowledged, ``append`` raises ``ReplicationLogFull`` (backpressure)
instead of losing data.  The PUBLISHER must never lose a batch either (the
home store has already applied it when the listener fires), so under
backpressure the replicator first degrades to a synchronous drain of every
healthy replica — a drain applies BOTH planes, so mixed-plane tails are
fully accounted before concluding a replica pins the log — and only if a
dead replica still pins the tail does it force-append past capacity —
bounded growth plus a monitor counter, never divergence.

Replay safety is per plane: the online plane relies on Algorithm 2 being an
idempotent, commutative, latest-wins join on (event_ts, creation_ts); the
offline plane relies on full-key (id, event_ts, creation_ts) insert-if-
absent idempotence.  Re-delivering a batch is a no-op, reordered batches
converge, and replaying a suffix that partially overlaps already-applied
writes is safe.  That is what makes fail-over exactly-once in EFFECT with
at-least-once DELIVERY: ``GeoPlacement.failover`` picks the nearest healthy
replica (regions.py), then ``GeoReplicator.promote`` replays that replica's
un-acked suffix, leaving its online store byte-identical and its offline
store chunk-set-identical to the home's pre-failure state.

Delta bootstrap + rejoin lifecycle
----------------------------------
A replica added after data exists bootstraps via ``bootstrap_delta``: its
cursor registers at the CURRENT log head (the snapshot-cut sequence
number), then the home state as of that cut streams over in bounded chunks
(``chunk_rows`` at a time — offline via ``OfflineStore.export_chunks``,
online via creation_ts-grouped slices of the dump), and normal draining
from the cut cursor catches it up.  Batches appended DURING the stream
overlap the snapshot harmlessly (idempotence again), and an interrupted
stream can simply be retried — no chunk is ever applied twice.  The same
path re-admits a recovered ex-home: ``GeoFeatureStore.rejoin(region)`` =
fresh stores + delta bootstrap of both planes + cursor at the cut, so a
region whose stores were lost at promotion rejoins as a first-class
replica instead of being dropped forever.

Multi-home write path & rebalance (active-active)
-------------------------------------------------
``MultiHomeGeoStore`` (core/multihome.py) runs this machinery
ACTIVE-ACTIVE: a ``regions.ShardMap`` hash-partitions the encoded keyspace
into ranges, each range homed in one region, and every region runs its OWN
``GeoReplicator`` + ``ReplicationLog`` with all other regions as replicas.
A write landing anywhere splits by owning range — owned slices merge
locally, foreign slices FORWARD to the range's home — so each row is
published by exactly one log and the delivery machinery above applies per
shard-home log unchanged.

The echo hazard is the new failure mode: every region is simultaneously a
publisher (its own log) and a replica (everyone else's), and replica-side
``merge_reduced`` fires the same ``merge_listeners`` a home merge does.
The shard filter in ``_on_home_merge``/``_on_home_offline_merge`` breaks
the loop: a replicator with a ``shard_map`` publishes ONLY the key slice
its home region owns, so applying another home's batch publishes nothing.
Convergence follows from the same per-plane idempotence as above — all
regions drain to byte-identical online and chunk-set-identical offline
state no matter where the writes landed.

Failover is PER-RANGE: losing a region promotes only its owned ranges —
the dead home's log replays its un-acked suffix into the nearest in-sync
replica (``promote``), the ShardMap reassigns just those ranges, and the
drained-dry log retires; every other range's home is untouched.  Rebalance
(region join/leave) reuses the delta-bootstrap path range-filtered
(``bootstrap_delta(key_range=...)``): drain the source log dry, stream the
moving range, cut the ShardMap over, converge.  The cutover window admits
one bounded echo (an in-flight moved-range batch re-published by the new
owner) — idempotence absorbs it; draining the source dry first makes it
not happen at all.

``GeoFeatureStore`` is the SINGLE-HOME read/write router on top (one home
region, ``shard_map=None``, no write splitting): writes (materialization
ticks, backfills) go to the home region's ``FeatureStore``; online reads
are served by the nearest IN-SYNC replica (replication lag at most
``max_lag_batches``), falling back to the home store; per-replica and
per-plane lag / staleness land in the health monitor.  ``failover()``
re-points BOTH of the home ``FeatureStore``'s planes at the promoted
region's stores, so materialization and training reads resume against the
new primary without skew.  Geo-fenced home regions refuse replication
(``ComplianceError``, §4.1.2) exactly as placement does.  Both routers
implement the one ``facade.StoreFacade`` surface serving, examples, and
benchmarks program against.
"""

from __future__ import annotations

import dataclasses
import zlib
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.channel import Channel, DeliveryError, InProcessChannel, mix64
from repro.core.featurestore import FeatureStore
from repro.core.offline_store import CREATION_TS, EVENT_TS, OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.keys import shard_coordinate
from repro.core.regions import (
    GeoTopology,
    RegionDownError,
    ReplicationPolicy,
    ShardMap,
)

__all__ = [
    "DEFAULT_COMPRESS_LEVEL",
    "STATE_CODES",
    "DeliveryError",
    "DeliveryPolicy",
    "DeliveryState",
    "GeoFeatureStore",
    "GeoReplicator",
    "LagStats",
    "PlaneLag",
    "PlaneShip",
    "ReplicatedBatch",
    "ReplicationLog",
    "ReplicationLogFull",
    "ShipLedger",
]

#: default zlib level for the wire codec (core/wire.py re-exports it); the
#: constant lives here, not in wire.py, because wire.py imports this module
#: (for ReplicatedBatch) and default-argument values need it at class-body
#: execution time, before the bottom-of-module wire import has run.
#: Level 1 is the throughput sweet spot on merge-batch payloads (random-ish
#: float features + low-entropy keys/timestamps): ~97% of level 6's ratio
#: at ~1/3 the encode cost; 0 disables compression entirely.
DEFAULT_COMPRESS_LEVEL = 1


class ReplicationLogFull(RuntimeError):
    """The log hit capacity and no fully-acknowledged prefix can be
    truncated — backpressure instead of dropping un-acked batches."""


#: delivery-state gauge encoding (``replication/state/{replica}``)
STATE_CODES = {"healthy": 0, "suspect": 1, "dead": 2}


@dataclasses.dataclass(frozen=True)
class DeliveryPolicy:
    """Knobs of the per-replica delivery state machine.

    Time is LOGICAL — drain ticks, not wall-clock — so every threshold is
    deterministic and the chaos suite can gate retry counts exactly.
    ``ack_timeout_ms`` is the one model-time knob: a delivery whose modeled
    latency exceeds it (WAN spike) counts as un-acked even though the
    bytes eventually land, and the replica-side per-seq dedup absorbs the
    resulting redelivery."""

    #: modeled one-way latency above which a delivery counts as un-acked
    ack_timeout_ms: float = 5_000.0
    #: consecutive failures before HEALTHY -> SUSPECT
    suspect_after: int = 2
    #: consecutive failures before -> DEAD (drives topology.mark_down)
    dead_after: int = 5
    #: backoff after the n-th consecutive failure, in drain ticks:
    #: min(backoff_cap, backoff_base << (n-1)) + deterministic jitter
    backoff_base: int = 1
    backoff_cap: int = 16
    #: drain ticks between re-probes of a DEAD link
    probe_interval: int = 4
    #: extra attempts per bootstrap chunk before DeliveryError
    bootstrap_retries: int = 10
    #: forced drain rounds a promotion replay may take before DeliveryError
    promote_rounds: int = 64
    #: consecutive failures before the replica is dropped from the set
    #: entirely (None = never; re-admission goes through rejoin/bootstrap)
    evict_after: Optional[int] = None
    #: bounded in-flight window for pipelined draining over carriers that
    #: support it (``post``/``collect`` — core/daemon.py's SocketChannel):
    #: up to this many encoded frames ride the link un-acked at once, so
    #: encode, socket transfer, and replica apply overlap.  1 serializes
    #: (the in-process behavior); the log's out-of-order ack handling and
    #: per-seq dedup are what make >1 safe.
    inflight_window: int = 8


@dataclasses.dataclass
class DeliveryState:
    """What the publisher knows about one replica link — detected health,
    backoff schedule, and the fault ledger the chaos gates read."""

    status: str = "healthy"
    #: logical clock: +1 per drain pass over this replica
    tick: int = 0
    consecutive_failures: int = 0
    #: drains are deferred while tick < backoff_until
    backoff_until: int = 0
    #: next tick a DEAD link gets a probe frame
    next_probe_tick: int = 0
    retries: int = 0  # batches re-shipped after going un-acked
    timeouts: int = 0  # deliveries with no usable ack
    corrupt_frames: int = 0  # arrivals the wire CRC rejected
    redelivered_batches: int = 0  # already-acked batches that arrived again
    bootstrap_retries: int = 0
    probes: int = 0
    #: highest non-bootstrap seq ever transmitted (retry detection)
    max_seq_sent: int = -1
    #: (tick, from_status, to_status) history
    transitions: list[tuple[int, str, str]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class ReplicatedBatch:
    """One reduced merge batch from either store plane.

    ``plane="online"``: the winning writes a single home online-store merge
    applied, in (part, slot) order as the home store reported them —
    ``values`` is the (G, D) float32 feature plane, ``columns`` is None.

    ``plane="offline"``: the rows a single home offline-store merge actually
    INSERTED (post full-key dedup, arrival order) — ``values`` is empty and
    ``columns`` carries the record-schema remainder (index columns + native-
    dtype feature columns), so the replica rebuilds byte-identical chunks.
    """

    seq: int
    table: tuple[str, int]
    creation_ts: int
    keys: np.ndarray  # (G,) int64 encoded entity keys
    event_ts: np.ndarray  # (G,) int64 winning event_ts per key
    values: np.ndarray  # (G, D) float32 winning feature rows (online plane)
    plane: str = "online"
    columns: Optional[dict[str, np.ndarray]] = None  # offline plane payload

    @property
    def rows(self) -> int:
        return len(self.keys)

    @property
    def nbytes(self) -> int:
        n = self.keys.nbytes + self.event_ts.nbytes + self.values.nbytes
        if self.columns is not None:
            n += sum(v.nbytes for v in self.columns.values())
        return n


def _frozen_copy(a: np.ndarray, dtype=None) -> np.ndarray:
    """Private read-only copy of a caller array: the log must not alias
    live publisher buffers (copy) and nothing downstream may mutate a
    logged batch in place (writeable=False)."""
    out = np.array(a, dtype=dtype, copy=True)
    out.flags.writeable = False
    return out


@dataclasses.dataclass(frozen=True)
class PlaneLag:
    """Un-acked backlog of one store plane (online serving vs offline
    history) toward one replica."""

    batches: int = 0
    rows: int = 0

    def as_dict(self) -> dict:
        return {"batches": self.batches, "rows": self.rows}


@dataclasses.dataclass(frozen=True)
class LagStats:
    """Replication lag of one replica: combined un-acked counts, per-plane
    breakdown, and staleness in clock units.  Frozen — a lag reading is a
    snapshot; the multi-home aggregate extends the schema by SUMMING
    readings across shard-home logs (``__add__``) instead of growing more
    string keys."""

    batches: int = 0
    rows: int = 0
    staleness_ms: int = 0
    oldest_pending_creation_ts: Optional[int] = None
    online: PlaneLag = PlaneLag()
    offline: PlaneLag = PlaneLag()

    @property
    def planes(self) -> dict:
        return {"online": self.online, "offline": self.offline}

    def __add__(self, other: "LagStats") -> "LagStats":
        oldest = [
            t
            for t in (
                self.oldest_pending_creation_ts,
                other.oldest_pending_creation_ts,
            )
            if t is not None
        ]
        return LagStats(
            batches=self.batches + other.batches,
            rows=self.rows + other.rows,
            staleness_ms=max(self.staleness_ms, other.staleness_ms),
            oldest_pending_creation_ts=min(oldest) if oldest else None,
            online=PlaneLag(
                self.online.batches + other.online.batches,
                self.online.rows + other.online.rows,
            ),
            offline=PlaneLag(
                self.offline.batches + other.offline.batches,
                self.offline.rows + other.offline.rows,
            ),
        )

    def as_dict(self) -> dict:
        return {
            "batches": self.batches,
            "rows": self.rows,
            "staleness_ms": self.staleness_ms,
            "oldest_pending_creation_ts": self.oldest_pending_creation_ts,
            "planes": {p: d.as_dict() for p, d in self.planes.items()},
        }


@dataclasses.dataclass
class PlaneShip:
    """Per-plane slice of one replica link's shipping ledger."""

    frames: int = 0
    batches: int = 0
    rows: int = 0
    bytes: int = 0
    raw_bytes: int = 0

    def as_dict(self) -> dict:
        return {
            "frames": self.frames,
            "batches": self.batches,
            "rows": self.rows,
            "bytes": self.bytes,
            "raw_bytes": self.raw_bytes,
        }


@dataclasses.dataclass
class ShipLedger:
    """One replica link's shipping ledger.  ``bytes`` is the TRUE wire size
    (post-compression frame bytes, the size the WAN bandwidth model
    prices); ``raw_bytes`` the serialized payload before compression;
    ``frames`` counts wire messages (a coalesced frame carries several
    batches).  MUTABLE by design — these are running counters charged from
    the transmit/apply paths — unlike the frozen snapshot stats
    (``LagStats``/``MergeStats``)."""

    frames: int = 0
    batches: int = 0
    rows: int = 0
    bytes: int = 0
    raw_bytes: int = 0
    ms: float = 0.0
    online: PlaneShip = dataclasses.field(default_factory=PlaneShip)
    offline: PlaneShip = dataclasses.field(default_factory=PlaneShip)

    def plane(self, name: str) -> PlaneShip:
        if name == "online":
            return self.online
        if name == "offline":
            return self.offline
        raise KeyError(name)

    @property
    def by_plane(self) -> dict:
        return {"online": self.online, "offline": self.offline}

    def as_dict(self) -> dict:
        return {
            "frames": self.frames,
            "batches": self.batches,
            "rows": self.rows,
            "bytes": self.bytes,
            "raw_bytes": self.raw_bytes,
            "ms": self.ms,
            "by_plane": {p: d.as_dict() for p, d in self.by_plane.items()},
        }


class ReplicationLog:
    """Bounded sequence of reduced batches + one cursor per replica.

    A cursor is the lowest un-acknowledged sequence number; acks may land
    out of order, and the cursor advances only over the contiguous prefix.
    """

    def __init__(self, capacity: int = 1024) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.next_seq = 0
        self.cursors: dict[str, int] = {}
        self._batches: deque[ReplicatedBatch] = deque()
        self._acked_ahead: dict[str, set[int]] = {}

    def __len__(self) -> int:
        return len(self._batches)

    def register_replica(self, name: str, from_seq: Optional[int] = None) -> int:
        """Start tracking a replica.  By default its cursor starts at the
        current head — the caller is responsible for snapshot-bootstrapping
        state appended before registration.  An explicit ``from_seq`` must
        lie between the oldest RETAINED sequence number and the head: a
        cursor past ``next_seq`` (or negative) drives ``pending_count``
        negative and silently passes the in-sync read gate while the
        replica is arbitrarily stale, and a cursor below the truncated
        floor pins pending batches that no longer exist — nothing is
        drainable, so the replica could never catch up (it missed the
        truncated data; it needs a snapshot bootstrap, not a cursor)."""
        if from_seq is not None:
            floor = self._batches[0].seq if self._batches else self.next_seq
            if not (floor <= from_seq <= self.next_seq):
                raise ValueError(
                    f"from_seq {from_seq} outside [{floor}, {self.next_seq}] "
                    f"(cursor may not start past the log head or below the "
                    f"truncated floor)"
                )
        cursor = self.next_seq if from_seq is None else from_seq
        self.cursors[name] = cursor
        self._acked_ahead[name] = set()
        return cursor

    def drop_replica(self, name: str) -> None:
        self.cursors.pop(name, None)
        self._acked_ahead.pop(name, None)

    def pending_count(self, replica: str) -> int:
        """O(1) un-acked batch count — the serving path's in-sync gate."""
        ahead = len(self._acked_ahead[replica])
        return self.next_seq - self.cursors[replica] - ahead

    def append(
        self,
        table: tuple[str, int],
        creation_ts: int,
        keys: np.ndarray,
        event_ts: np.ndarray,
        values: np.ndarray,
        *,
        plane: str = "online",
        columns: Optional[dict[str, np.ndarray]] = None,
        force: bool = False,
    ) -> ReplicatedBatch:
        """Append one reduced batch (either plane — both share the one
        sequence); truncates the fully-acked prefix first and raises
        ``ReplicationLogFull`` rather than evicting un-acked batches when
        the log is still at capacity.  ``force=True`` appends past capacity
        instead of raising — for a publisher whose store ALREADY applied
        the batch, losing it is worse than growing the log (see
        GeoReplicator._publish).

        The logged arrays are private COPIES, frozen read-only: the caller
        hands in live views of its own buffers (an online merge's
        ``touched_values``, an offline merge's ``inserted_columns`` slices
        of the frame), and an un-shipped batch may sit in the log across
        later in-place mutation or compaction of those buffers.  Aliasing
        them would silently corrupt whatever eventually ships."""
        if plane not in ("online", "offline"):
            raise ValueError(f"unknown plane {plane!r}")
        if len(self._batches) >= self.capacity:
            self.truncate()
        if len(self._batches) >= self.capacity and not force:
            slowest = min(self.cursors.values(), default=None)
            msg = f"log at capacity {self.capacity}; slowest cursor {slowest}"
            raise ReplicationLogFull(msg)
        batch = ReplicatedBatch(
            seq=self.next_seq,
            table=table,
            creation_ts=int(creation_ts),
            keys=_frozen_copy(keys, np.int64),
            event_ts=_frozen_copy(event_ts, np.int64),
            values=_frozen_copy(values, np.float32),
            plane=plane,
            columns=(
                None
                if columns is None
                else {k: _frozen_copy(v) for k, v in columns.items()}
            ),
        )
        self.next_seq += 1
        self._batches.append(batch)
        return batch

    def pending(self, replica: str) -> list[ReplicatedBatch]:
        """Batches the replica has not acknowledged, in sequence order."""
        cursor = self.cursors[replica]
        ahead = self._acked_ahead[replica]
        return [b for b in self._batches if b.seq >= cursor and b.seq not in ahead]

    def ack(self, replica: str, seq: int) -> None:
        """Acknowledge one batch; the cursor advances over the contiguous
        acknowledged prefix only, so out-of-order acks never hide lag."""
        if seq >= self.next_seq:
            raise ValueError(f"ack of unknown seq {seq}")
        ahead = self._acked_ahead[replica]
        if seq >= self.cursors[replica]:
            ahead.add(seq)
        while self.cursors[replica] in ahead:
            ahead.remove(self.cursors[replica])
            self.cursors[replica] += 1

    def is_acked(self, replica: str, seq: int) -> bool:
        """Has this replica already acknowledged ``seq``?  Redelivery
        detection for the at-least-once transport: an acked batch arriving
        again is absorbed by per-plane idempotence and counted — never
        re-acked into cursor state."""
        return seq < self.cursors[replica] or seq in self._acked_ahead[replica]

    def truncate(self) -> int:
        """Drop the prefix every replica has acknowledged.  Never touches a
        batch at or above any cursor, so un-acked batches survive.  Returns
        the number of batches dropped."""
        floor = min(self.cursors.values(), default=self.next_seq)
        dropped = 0
        while self._batches and self._batches[0].seq < floor:
            self._batches.popleft()
            dropped += 1
        return dropped

    def lag(self, replica: str) -> LagStats:
        """Un-acked batch/row counts (combined + per plane) and the oldest
        pending creation_ts.  The combined counts are what the in-sync read
        gate consumes; the per-plane breakdown feeds monitoring, so an
        offline-only backlog (e.g. a replica serving reads but behind on
        training history) is visible, not averaged away."""
        pend = self.pending(replica)
        planes = {
            p: PlaneLag(
                batches=sum(1 for b in pend if b.plane == p),
                rows=int(sum(b.rows for b in pend if b.plane == p)),
            )
            for p in ("online", "offline")
        }
        return LagStats(
            batches=len(pend),
            rows=int(sum(b.rows for b in pend)),
            oldest_pending_creation_ts=(
                min(b.creation_ts for b in pend) if pend else None
            ),
            online=planes["online"],
            offline=planes["offline"],
        )


class GeoReplicator:
    """Async applier: drains the home stores' replication log into replica
    stores (both planes) over the modeled WAN, tracks lag, and replays on
    fail-over.

    Every replica-bound batch — drain, out-of-order ``apply_batch``, delta
    bootstrap, failover replay — crosses the WAN hop as a serialized wire
    frame (core/wire.py): encode on the home side, decode on the replica
    side, apply only the decoded copy.  Adjacent same-plane same-table
    pending batches coalesce into one frame per ``drain``; shipping
    accounting records MEASURED raw and post-compression wire bytes, and
    the topology's bandwidth model prices the compressed size.

    The hop itself is a pluggable ``Channel`` and each replica link runs
    the ``DeliveryPolicy``/``DeliveryState`` machine documented in the
    module docstring's failure-model section: at-least-once transmission
    with ack-timeout detection, capped exponential backoff, automatic
    SUSPECT/DEAD health driving ``topology.mark_down``, probe-based
    recovery, and optional eviction.  ``on_evict`` (if given) is called
    with the region name after an evicted replica's state is torn down —
    the control-plane hook ``GeoFeatureStore`` uses to drop placement and
    queue an auto-rejoin."""

    def __init__(
        self,
        home_store: OnlineStore,
        *,
        topology: GeoTopology,
        home_region: str,
        home_offline: Optional[OfflineStore] = None,
        log: Optional[ReplicationLog] = None,
        clock: Optional[Callable[[], int]] = None,
        monitor=None,
        compress_level: Optional[int] = DEFAULT_COMPRESS_LEVEL,
        channel: Optional[Channel] = None,
        policy: Optional[DeliveryPolicy] = None,
        on_evict: Optional[Callable[[str], None]] = None,
        shard_map: Optional[ShardMap] = None,
    ) -> None:
        self.topology = topology
        self.home_region = home_region
        #: multi-home publish filter: when set, the home-merge listeners
        #: publish ONLY the key slice this home's shards own — a replica
        #: applying another home's batch therefore publishes nothing, which
        #: is what keeps the active-active mesh echo-free (module docstring,
        #: "Multi-home write path").  None = single-home, publish everything.
        self.shard_map = shard_map
        self.log = log if log is not None else ReplicationLog()
        self.clock = clock or (lambda: 0)
        self.monitor = monitor
        self.compress_level = compress_level
        self.channel: Channel = (
            channel if channel is not None else InProcessChannel(topology)
        )
        self.policy = policy if policy is not None else DeliveryPolicy()
        self.on_evict = on_evict
        self.delivery: dict[str, DeliveryState] = {}
        self.stores: dict[str, OnlineStore] = {home_region: home_store}
        # offline plane is optional: a standalone online-only replicator
        # (benchmarks, tests) never publishes offline batches
        self.offline_stores: dict[str, OfflineStore] = {}
        # OUT-OF-PROCESS replicas (core/daemon.py): region -> {"offline":
        # bool}.  A remote replica has no entry in ``stores`` — its state
        # lives in the daemon — so read routing and store-walking callers
        # skip it automatically; its per-region carrier lives in
        # ``channels`` (``channel`` stays the default for in-process
        # replicas, preserving every deterministic gate bit for bit).
        self.remote: dict[str, dict] = {}
        self.channels: dict[str, Channel] = {}
        self.shipped: dict[str, dict] = {}
        self._specs: dict[tuple[str, int], FeatureSetSpec] = {}
        home_store.merge_listeners.append(self._on_home_merge)
        if home_offline is not None:
            self.offline_stores[home_region] = home_offline
            home_offline.merge_listeners.append(self._on_home_offline_merge)

    # -- publish (home side) ------------------------------------------------
    def _publish(self, payload: tuple, plane: str, columns=None) -> int:
        """Append one reduced batch to the log, degrading under
        backpressure.  The home store has ALREADY applied this batch by the
        time a listener fires, so the append must never lose it: when the
        log is full, backpressure degrades async replication to a
        synchronous drain of every healthy replica — the drain applies
        BOTH planes, so a mixed online/offline tail is fully accounted
        (cursors advance over every batch, freeing the prefix) before
        concluding that a replica pins the log; only if an UNHEALTHY
        replica still pins the tail is the batch force-appended — the log
        temporarily exceeds capacity (surfaced via the
        ``replication/log_force_appends`` counter) rather than diverging
        the replicas forever."""
        try:
            batch = self.log.append(*payload, plane=plane, columns=columns)
        except ReplicationLogFull:
            for region in self.replica_regions():
                if self.topology.regions[region].healthy:
                    self.drain(region)
            try:
                batch = self.log.append(*payload, plane=plane, columns=columns)
            except ReplicationLogFull:
                batch = self.log.append(
                    *payload, plane=plane, columns=columns, force=True
                )
                if self.monitor is not None:
                    self.monitor.system.inc("replication/log_force_appends")
        return batch.seq

    def _owned_slice(self, keys: np.ndarray) -> Optional[np.ndarray]:
        """Multi-home publish filter: row indices of ``keys`` owned by this
        home's shards, or None when no shard map is set (single-home —
        publish everything).  An all-owned batch returns the full index
        range, a fully-foreign batch (a replica applying another home's
        writes) an empty one."""
        if self.shard_map is None:
            return None
        shards = self.shard_map.shard_of(keys)
        mine = np.array(
            [o == self.home_region for o in self.shard_map.owners], bool
        )
        return np.flatnonzero(mine[shards])

    def _on_home_merge(self, spec: FeatureSetSpec, stats) -> None:
        """Home ONLINE-store merge listener: append the batch's reduced
        winning writes to the log and annotate the stats with the seq.
        Under a shard map, only the home-owned key slice is published
        (``_owned_slice``) — the multi-home echo breaker."""
        self._specs[spec.key] = spec
        keys = stats.get("touched_keys")
        if keys is None or len(keys) == 0:
            stats.annotate_replication_seq(None)  # pure no-op batch
            return
        event_ts = stats["touched_event_ts"]
        values = stats["touched_values"]
        owned = self._owned_slice(keys)
        if owned is not None:
            if len(owned) == 0:
                stats.annotate_replication_seq(None)  # fully-foreign batch
                return
            if len(owned) < len(keys):
                keys = keys[owned]
                event_ts = event_ts[owned]
                values = values[owned]
        payload = (spec.key, stats["creation_ts"], keys, event_ts, values)
        stats.annotate_replication_seq(self._publish(payload, "online"))

    def _on_home_offline_merge(self, spec: FeatureSetSpec, stats: dict) -> None:
        """Home OFFLINE-store merge listener: ship the rows the merge
        actually inserted (post full-key dedup) as an offline-plane batch —
        shard-filtered like the online listener."""
        self._specs[spec.key] = spec
        keys = stats.get("inserted_keys")
        if keys is None or len(keys) == 0:
            stats["replication_seq"] = None  # fully-deduped batch: no-op
            return
        event_ts = stats["inserted_event_ts"]
        columns = stats["inserted_columns"]
        owned = self._owned_slice(keys)
        if owned is not None:
            if len(owned) == 0:
                stats["replication_seq"] = None
                return
            if len(owned) < len(keys):
                keys = keys[owned]
                event_ts = event_ts[owned]
                columns = {k: v[owned] for k, v in columns.items()}
        payload = (
            spec.key,
            stats["creation_ts"],
            keys,
            event_ts,
            np.empty((len(keys), 0), np.float32),
        )
        stats["replication_seq"] = self._publish(
            payload, "offline", columns=columns
        )

    # -- replica membership --------------------------------------------------
    def replica_regions(self) -> list[str]:
        out = [r for r in self.stores if r != self.home_region]
        out.extend(r for r in self.remote if r not in out)
        return out

    def channel_for(self, region: str) -> Channel:
        """The carrier for one replica link — a per-region channel (remote
        replicas) or the shared default."""
        return self.channels.get(region, self.channel)

    def _new_ship_ledger(self) -> ShipLedger:
        return ShipLedger()

    def add_replica(
        self,
        region: str,
        store: OnlineStore,
        offline_store: Optional[OfflineStore] = None,
    ) -> int:
        """Start tracking a replica; its single cursor (both planes) starts
        at the current head — the snapshot-cut sequence number the caller's
        ``bootstrap_delta`` streams state up to.  Returns that cut."""
        if region in self.stores:
            raise ValueError(f"region {region} already has a store")
        # the replica set must be plane-homogeneous: an online-only replica
        # under an offline-publishing home would crash every drain (and, via
        # the backpressure fallback, the home write path) on its first
        # offline batch — and an offline-capable replica under an
        # online-only home would set up the same crash for its siblings the
        # moment promote() makes it the publisher
        home_offline = self.home_region in self.offline_stores
        if offline_store is None and home_offline:
            raise ValueError(
                f"home {self.home_region} replicates the offline plane; "
                f"replica {region} must provide an offline store too"
            )
        if offline_store is not None and not home_offline:
            raise ValueError(
                f"home {self.home_region} does not replicate the offline "
                f"plane; construct GeoReplicator with home_offline or drop "
                f"replica {region}'s offline store"
            )
        self.stores[region] = store
        if offline_store is not None:
            self.offline_stores[region] = offline_store
        cut = self.log.register_replica(region)
        self.delivery[region] = DeliveryState()
        self.shipped[region] = self._new_ship_ledger()
        return cut

    def add_remote_replica(
        self,
        region: str,
        channel: Channel,
        *,
        offline: Optional[bool] = None,
    ) -> int:
        """Start tracking an OUT-OF-PROCESS replica reached over its own
        carrier (core/daemon.py's ``SocketChannel``): frames ship through
        ``channel``, the daemon applies and acks, and the publisher trusts
        the acks instead of applying anything locally.  The replica set
        stays plane-homogeneous with the home (``offline`` defaults to
        whatever the home publishes).  Returns the registration cut, like
        ``add_replica``."""
        if region in self.stores or region in self.remote:
            raise ValueError(f"region {region} already has a store")
        home_offline = self.home_region in self.offline_stores
        if offline is None:
            offline = home_offline
        if not offline and home_offline:
            raise ValueError(
                f"home {self.home_region} replicates the offline plane; "
                f"remote replica {region} must carry it too"
            )
        if offline and not home_offline:
            raise ValueError(
                f"home {self.home_region} does not replicate the offline "
                f"plane; remote replica {region} cannot"
            )
        self.remote[region] = {"offline": bool(offline)}
        self.channels[region] = channel
        # the carrier's own ack wait must not outlast the policy's notion
        # of "timed out", or the state machine would never see timeouts
        if hasattr(channel, "ack_timeout_ms"):
            channel.ack_timeout_ms = float(self.policy.ack_timeout_ms)
        cut = self.log.register_replica(region)
        self.delivery[region] = DeliveryState()
        self.shipped[region] = self._new_ship_ledger()
        return cut

    def bootstrap_delta(
        self,
        region: str,
        spec: FeatureSetSpec,
        *,
        chunk_rows: int = 65_536,
        key_range: Optional[tuple[int, int]] = None,
    ) -> dict:
        """Stream one table's home state AS OF the replica's registration
        cut into the new replica, in bounded ``chunk_rows`` pieces — the
        delta bootstrap: snapshot cut at a log sequence number (the cursor
        ``add_replica`` registered) + normal catch-up draining from that
        cursor.  A late replica therefore never holds a full second copy in
        flight, batches appended during the stream overlap it harmlessly
        (per-plane idempotence), and an interrupted stream is simply
        retried — ``apply_chunks``/``merge_reduced`` make re-application a
        no-op.  Every chunk crosses the WAN as a wire frame (seq = the
        out-of-log ``BOOTSTRAP_SEQ`` sentinel, never acked); offline chunks
        span many merges, so their per-row creation_ts rides along as a
        wire column the apply side peels off.

        ``key_range`` — half-open ``[lo, hi)`` over the uniform
        ``keys.shard_coordinate`` of encoded keys (the space ``ShardMap``
        bounds cut) — streams only that slice of both planes: the
        multi-home rebalance path ("stream the moving range") reuses this
        bootstrap with one shard's ``ShardMap.shard_range`` instead of
        re-shipping whole tables.  Returns per-plane bootstrapped row
        counts."""
        self._specs[spec.key] = spec
        out = {"online_rows": 0, "offline_rows": 0, "chunks": 0}

        def in_range(keys: np.ndarray) -> Optional[np.ndarray]:
            if key_range is None:
                return None
            lo, hi = key_range
            coord = shard_coordinate(keys)
            return (coord >= np.uint64(lo)) & (coord < np.uint64(hi))

        home_online = self.stores[self.home_region]
        store = self.stores.get(region)
        is_remote = region in self.remote
        if (
            (store is not None or is_remote)
            and spec.materialization.online_enabled
            and home_online.has(spec.name, spec.version)
        ):
            if store is not None:
                store.register(spec)
            dump = home_online.dump_all(spec.name, spec.version)
            mask = in_range(dump["__key__"]) if len(dump) else None
            if mask is not None:
                dump = dump.take(np.flatnonzero(mask))
            if len(dump):
                keys = dump["__key__"]
                event_ts = dump[EVENT_TS]
                creation_ts = dump[CREATION_TS]
                values = dump.column_stack([f.name for f in spec.features], np.float32)
                for cr in np.unique(creation_ts):
                    idx = np.flatnonzero(creation_ts == cr)
                    for lo in range(0, len(idx), chunk_rows):
                        sl = idx[lo : lo + chunk_rows]
                        batch = ReplicatedBatch(
                            seq=wire.BOOTSTRAP_SEQ,
                            table=spec.key,
                            creation_ts=int(cr),
                            keys=keys[sl],
                            event_ts=event_ts[sl],
                            values=values[sl],
                        )
                        self._ship_bootstrap(region, batch)
                        out["online_rows"] += len(sl)
                        out["chunks"] += 1
        home_offline = self.offline_stores.get(self.home_region)
        offline = self.offline_stores.get(region)
        remote_offline = is_remote and self.remote[region]["offline"]
        if (
            (offline is not None or remote_offline)
            and home_offline is not None
            and spec.materialization.offline_enabled
            and home_offline.has(spec.name, spec.version)
        ):
            if offline is not None:
                offline.register(spec)
            for chunk in home_offline.export_chunks(
                spec.name, spec.version, max_rows=chunk_rows
            ):
                mask = in_range(chunk["__key__"]) if len(chunk) else None
                if mask is not None:
                    chunk = chunk.take(np.flatnonzero(mask))
                if len(chunk) == 0:
                    continue
                # CREATION_TS stays IN the columns payload: bootstrap chunks
                # span merges, so creation_ts is per-row, not the batch
                # scalar — _ship_frame pops it back out on the replica side
                cols = {
                    k: chunk[k] for k in chunk.names if k not in ("__key__", EVENT_TS)
                }
                batch = ReplicatedBatch(
                    seq=wire.BOOTSTRAP_SEQ,
                    table=spec.key,
                    creation_ts=int(chunk[CREATION_TS][0]),
                    keys=chunk["__key__"],
                    event_ts=chunk[EVENT_TS],
                    values=np.empty((len(chunk), 0), np.float32),
                    plane="offline",
                    columns=cols,
                )
                self._ship_bootstrap(region, batch)
                out["offline_rows"] += len(chunk)
                out["chunks"] += 1
        return out

    def _ship_bootstrap(self, region: str, batch: ReplicatedBatch) -> None:
        """Ship one bootstrap chunk, retrying against the channel: a chunk
        is not a log entry (seq = BOOTSTRAP_SEQ, never acked), so a lost
        one would be lost FOREVER rather than redelivered by the normal
        drain — the stream must therefore push through transient faults or
        fail loudly.  Re-application of a chunk that actually landed is a
        no-op (per-plane idempotence), so blind retry is safe."""
        frame = wire.encode_batch(batch, compress_level=self.compress_level)
        st = self.delivery[region]
        for attempt in range(self.policy.bootstrap_retries + 1):
            if attempt:
                st.bootstrap_retries += 1
            if self._ship_frame(region, frame) is not None:
                return
        raise DeliveryError(
            f"bootstrap chunk for {region} undeliverable after "
            f"{self.policy.bootstrap_retries + 1} attempts"
        )

    # -- apply (replica side) -------------------------------------------------
    def _apply_decoded(self, region: str, batch: ReplicatedBatch) -> dict:
        """Apply ONE decoded batch to the replica's store for its plane.
        Both applies are idempotent (latest-wins online, full-key
        insert-if-absent offline), which is what makes the at-least-once
        channel exactly-once in effect."""
        spec = self._specs[batch.table]
        if batch.plane == "offline":
            cols = dict(batch.columns or {})
            creation = cols.pop(CREATION_TS, batch.creation_ts)
            return self.offline_stores[region].apply_chunks(
                spec, batch.keys, batch.event_ts, creation, cols
            )
        return self.stores[region].merge_reduced(
            spec, batch.keys, batch.event_ts, batch.values, batch.creation_ts
        )

    def _charge_transmit(self, region: str, frame, latency_ms: float) -> None:
        """TRANSMIT-side ledger: the home pays for the send whether or not
        it lands, so retries show up as byte amplification."""
        ship = self.shipped[region]
        ship.frames += 1
        ship.bytes += frame.wire_nbytes
        ship.raw_bytes += frame.raw_nbytes
        ship.ms += latency_ms
        plane = ship.plane(frame.plane)
        plane.frames += 1
        plane.bytes += frame.wire_nbytes
        plane.raw_bytes += frame.raw_nbytes

    def _note_sent_seqs(self, region: str, frame) -> None:
        """Retry detection: any logged seq at or below the high-water mark
        has been transmitted before."""
        st = self.delivery[region]
        resent = sum(
            1
            for s in frame.seqs
            if s != wire.BOOTSTRAP_SEQ and s <= st.max_seq_sent
        )
        if resent:
            st.retries += resent
            if self.monitor is not None:
                self.monitor.record_delivery_retry(region, resent)
        for s in frame.seqs:
            if s != wire.BOOTSTRAP_SEQ and s > st.max_seq_sent:
                st.max_seq_sent = s

    def _announce_tables(self, region: str, frame) -> None:
        """Remote carriers need the table's schema before its first frame
        (specs carry user code that never crosses the wire); idempotent —
        the channel remembers what it has announced."""
        if frame.table == wire.PROBE_TABLE:
            return
        ch = self.channel_for(region)
        ensure = getattr(ch, "ensure_table", None)
        spec = self._specs.get(frame.table)
        if ensure is not None and spec is not None:
            ensure(spec)

    def _absorb_remote(self, region: str, frame, delivery) -> Optional[list[dict]]:
        """Digest a remote carrier's delivery: the replica daemon applied
        the frame itself, so the publisher's whole apply step reduces to
        trusting (or not) the returned ``wire.Ack`` — same contract as the
        in-process path: per-batch stats on success, None on failure (the
        state machine's cue), ledger charged for what the ack proves was
        applied even when the ack itself came back unusable."""
        st = self.delivery[region]
        ack = delivery.remote
        ack_ok = (
            not delivery.ack_lost
            and delivery.latency_ms <= self.policy.ack_timeout_ms
        )
        if ack is None:
            st.timeouts += 1
            if self.monitor is not None:
                self.monitor.record_delivery_fault(region, "timeout")
            return None
        if ack.status == wire.ACK_CORRUPT:
            # the daemon's CRC rejected the frame at its door — the
            # remote mirror of the local corrupt-arrival path
            st.corrupt_frames += 1
            st.timeouts += 1
            if self.monitor is not None:
                self.monitor.record_delivery_fault(region, "corrupt_frame")
                self.monitor.record_delivery_fault(region, "timeout")
            return None
        for s in ack.seqs:
            if s != wire.BOOTSTRAP_SEQ and self.log.is_acked(region, s):
                st.redelivered_batches += 1
                if self.monitor is not None:
                    self.monitor.record_delivery_fault(region, "redelivered")
        if ack_ok:
            for s in ack.seqs:
                if s != wire.BOOTSTRAP_SEQ:
                    self.log.ack(region, s)
        ship = self.shipped[region]
        plane = ship.plane(frame.plane)
        ship.batches += len(ack.seqs)
        ship.rows += ack.rows
        plane.batches += len(ack.seqs)
        plane.rows += ack.rows
        if self.monitor is not None:
            self.monitor.record_replication_ship(
                ack.rows,
                batches=len(ack.seqs),
                raw_nbytes=frame.raw_nbytes,
                wire_nbytes=frame.wire_nbytes,
                plane=frame.plane,
            )
            self.monitor.system.observe(
                f"replication/socket_rtt_ms/{region}", delivery.latency_ms
            )
        if not ack_ok or ack.status != wire.ACK_OK:
            st.timeouts += 1
            if self.monitor is not None:
                self.monitor.record_delivery_fault(region, "timeout")
            return None
        return [{"remote": True, "seq": s} for s in ack.seqs]

    def _ship_frame(self, region: str, frame) -> Optional[list[dict]]:
        """The WAN hop: transmit one encoded ``wire.WireFrame`` over the
        channel, decode and apply every payload that arrives, and ack each
        applied logged seq IF the acknowledgement made it back in time.
        Returns the per-batch apply stats, or None when the delivery
        failed (nothing decodable arrived, or the ack was lost/late) — the
        caller's cue to back off and retry; un-acked batches stay pending.

        For a REMOTE replica the apply happens in the daemon process: the
        carrier returns its ack in ``delivery.remote`` and ``_absorb_remote``
        digests it — the ``DeliveryState`` machine above cannot tell the
        difference.

        Accounting is split by side and is exception-safe: the TRANSMIT
        ledger (frames/bytes/ms) is charged up front — the home pays for
        the send whether or not it lands, so retries show up as byte
        amplification — while the APPLY ledger (batches/rows) is recorded
        in a ``finally`` per batch actually applied, so a replica-side
        apply error mid-frame still accounts the earlier batches it acked
        before the exception propagates."""
        st = self.delivery[region]
        if region in self.remote:
            self._announce_tables(region, frame)
            delivery = self.channel_for(region).transmit(
                self.home_region, region, frame
            )
            self._charge_transmit(region, frame, delivery.latency_ms)
            self._note_sent_seqs(region, frame)
            return self._absorb_remote(region, frame, delivery)
        delivery = self.channel.transmit(self.home_region, region, frame)
        self._charge_transmit(region, frame, delivery.latency_ms)
        self._note_sent_seqs(region, frame)
        ship = self.shipped[region]
        plane = ship.plane(frame.plane)
        ack_ok = (
            not delivery.ack_lost
            and delivery.latency_ms <= self.policy.ack_timeout_ms
        )
        applied: list[dict] = []
        applied_rows = 0
        decoded_any = False
        try:
            for payload in delivery.arrivals:
                try:
                    batches = wire.decode_frame(payload)
                except wire.WireFormatError:
                    # WAN damage caught at the door by the wire CRC — the
                    # frame never touches replica state, no ack returns
                    st.corrupt_frames += 1
                    if self.monitor is not None:
                        self.monitor.record_delivery_fault(region, "corrupt_frame")
                    continue
                decoded_any = True
                for batch in batches:
                    if batch.seq != wire.BOOTSTRAP_SEQ and self.log.is_acked(
                        region, batch.seq
                    ):
                        st.redelivered_batches += 1
                        if self.monitor is not None:
                            self.monitor.record_delivery_fault(region, "redelivered")
                    applied.append(self._apply_decoded(region, batch))
                    applied_rows += batch.rows
                    if ack_ok and batch.seq != wire.BOOTSTRAP_SEQ:
                        self.log.ack(region, batch.seq)
        finally:
            ship.batches += len(applied)
            ship.rows += applied_rows
            plane.batches += len(applied)
            plane.rows += applied_rows
            if self.monitor is not None:
                self.monitor.record_replication_ship(
                    applied_rows,
                    batches=len(applied),
                    raw_nbytes=frame.raw_nbytes,
                    wire_nbytes=frame.wire_nbytes,
                    plane=frame.plane,
                )
        if not decoded_any or not ack_ok:
            st.timeouts += 1
            if self.monitor is not None:
                self.monitor.record_delivery_fault(region, "timeout")
            return None
        return applied

    def apply_batch(self, region: str, batch: ReplicatedBatch) -> dict:
        """Ship + apply ONE batch (either plane) to a replica and
        acknowledge it — a single-batch wire frame, no coalescing.  Exposed
        so tests can drive out-of-order delivery; ``drain`` is the in-order
        coalescing fast path.  Raises ``DeliveryError`` if the channel ate
        the frame (the batch stays pending for a later drain)."""
        frame = wire.encode_batch(batch, compress_level=self.compress_level)
        stats = self._ship_frame(region, frame)
        if not stats:
            raise DeliveryError(f"batch seq {batch.seq} undelivered to {region}")
        return stats[0]

    def _drain_remote_pipelined(
        self, region: str, pend: list[ReplicatedBatch], encoded: dict
    ) -> tuple[int, int, bool, bool]:
        """Drain one REMOTE replica with a bounded in-flight window: keep
        up to ``policy.inflight_window`` encoded frames riding the carrier
        un-acked, absorbing acks as they land, so encode, socket transfer,
        and replica apply overlap instead of serializing.  Safe because
        the log acks out of order (contiguous-prefix cursor advance) and
        the daemon's apply is idempotent per seq — a frame that times out
        mid-window just stays pending and is re-shipped next pass.
        Returns (applied_batches, rows, shipped_any, failed)."""
        ch = self.channel_for(region)
        st = self.delivery[region]
        window = max(1, self.policy.inflight_window)
        runs = wire.coalesce(pend)
        idx = 0
        inflight: dict[int, tuple[object, object]] = {}
        applied_batches = 0
        rows = 0
        shipped_any = False
        failed = False
        while (idx < len(runs) and not failed) or inflight:
            while idx < len(runs) and len(inflight) < window and not failed:
                run = runs[idx]
                idx += 1
                key = (run[0].plane, run[0].table, tuple(b.seq for b in run))
                frame = encoded.get(key)
                if frame is None:
                    frame = wire.encode_run(run, compress_level=self.compress_level)
                    encoded[key] = frame
                self._announce_tables(region, frame)
                self._charge_transmit(region, frame, 0.0)
                self._note_sent_seqs(region, frame)
                token = ch.post(frame)
                if token is None:
                    # the injector ate the send before it hit the socket:
                    # a delivery failure — stop posting new frames but
                    # keep collecting the window already in flight
                    st.timeouts += 1
                    if self.monitor is not None:
                        self.monitor.record_delivery_fault(region, "timeout")
                    failed = True
                else:
                    inflight[id(token)] = (token, frame)
            if not inflight:
                break
            done = ch.collect(self.policy.ack_timeout_ms)
            if not done:
                # nothing completed within the ack timeout: every frame
                # still in flight is charged as timed out and abandoned
                # (a late ack resolves the identical retry next pass)
                for token, _frame in inflight.values():
                    ch.forget(token)
                    st.timeouts += 1
                    if self.monitor is not None:
                        self.monitor.record_delivery_fault(region, "timeout")
                inflight.clear()
                failed = True
                break
            for token, delivery in done:
                entry = inflight.pop(id(token), None)
                if entry is None:
                    continue  # completion for a frame another pass forgot
                _tok, frame = entry
                self.shipped[region].ms += delivery.latency_ms
                stats = self._absorb_remote(region, frame, delivery)
                if stats is None:
                    failed = True
                else:
                    shipped_any = True
                    applied_batches += len(stats)
                    rows += frame.rows
        return applied_batches, rows, shipped_any, failed

    def drain(
        self,
        region: Optional[str] = None,
        max_batches: Optional[int] = None,
        *,
        force: bool = False,
    ) -> dict:
        """Apply pending batches in sequence order — all replicas or one.
        Adjacent same-plane same-table batches coalesce into one wire frame
        (shared header + compression stream); each constituent batch is
        still acked by its own seq.  Replicas whose cursors align get the
        SAME frame — logged batches are immutable, so a run's encoding is
        a pure function of (plane, table, seq range) and is encoded (and
        zlib-compressed) once per drain pass, not once per replica.

        Each pass advances the replica's logical delivery clock by one
        tick.  Unless ``force``d (promotion replay must push through), a
        backing-off link is skipped (``"deferred": "backoff"``) and a DEAD
        link gets a probe at its schedule instead of real frames
        (``"deferred": "dead"``); the first failed frame ends the pass for
        that replica and feeds the state machine.
        Returns {region: {"applied_batches", "applied_rows", ...}}."""
        regions = [region] if region is not None else self.replica_regions()
        out: dict[str, dict] = {}
        encoded: dict[tuple, object] = {}
        for r in regions:
            st = self.delivery[r]
            st.tick += 1
            if not force:
                if st.status == "dead":
                    if st.tick >= st.next_probe_tick:
                        self.probe(r)
                    # the probe may have evicted r, or flipped it healthy
                    if self.delivery.get(r) is None or (
                        self.delivery[r].status == "dead"
                    ):
                        out[r] = {
                            "applied_batches": 0,
                            "applied_rows": 0,
                            "deferred": "dead",
                        }
                        continue
                elif st.tick < st.backoff_until:
                    out[r] = {
                        "applied_batches": 0,
                        "applied_rows": 0,
                        "deferred": "backoff",
                    }
                    self._record_lag(r)
                    continue
            pend = self.log.pending(r)
            if max_batches is not None:
                pend = pend[:max_batches]
            ch = self.channel_for(r)
            if (
                r in self.remote
                and self.policy.inflight_window > 1
                and hasattr(ch, "post")
                and hasattr(ch, "collect")
            ):
                applied_batches, rows, shipped_any, failed = (
                    self._drain_remote_pipelined(r, pend, encoded)
                )
            else:
                rows = 0
                applied_batches = 0
                shipped_any = False
                failed = False
                for run in wire.coalesce(pend):
                    # exact seq tuple, not a (first, last) range:
                    # out-of-order acks can punch holes in one replica's
                    # pending run, and a range key would collide it with
                    # another replica's gapless run over the same span
                    key = (run[0].plane, run[0].table, tuple(b.seq for b in run))
                    frame = encoded.get(key)
                    if frame is None:
                        frame = wire.encode_run(
                            run, compress_level=self.compress_level
                        )
                        encoded[key] = frame
                    stats = self._ship_frame(r, frame)
                    if stats is None:
                        failed = True
                        break
                    shipped_any = True
                    applied_batches += len(stats)
                    rows += frame.rows
            if failed:
                self._record_failure(r)
            elif shipped_any:
                self._record_success(r)
            out[r] = {"applied_batches": applied_batches, "applied_rows": rows}
            if r in self.delivery:  # a failure may have evicted r
                self._record_lag(r)
            else:
                out[r]["evicted"] = True
        self.log.truncate()
        return out

    # -- delivery state machine ------------------------------------------------
    def _set_state(self, region: str, st: DeliveryState, status: str) -> None:
        if st.status == status:
            return
        st.transitions.append((st.tick, st.status, status))
        st.status = status
        if self.monitor is not None:
            self.monitor.record_delivery_state(region, status, STATE_CODES[status])

    def _record_failure(self, region: str) -> None:
        """One failed delivery: schedule capped exponential backoff with
        deterministic per-(replica, streak) jitter, walk the health state
        machine, and — at the DEAD transition — drive ``topology.mark_down``
        so read routing and ``failover()`` react to the DETECTED outage."""
        st = self.delivery[region]
        st.consecutive_failures += 1
        n = st.consecutive_failures
        p = self.policy
        backoff = min(p.backoff_cap, p.backoff_base << min(n - 1, 10))
        # deterministic jitter in [0, backoff): desynchronizes replica
        # retry schedules without any RNG state (chaos runs stay replayable)
        jitter = mix64(zlib.crc32(region.encode()) ^ (n << 1)) % max(backoff, 1)
        st.backoff_until = st.tick + backoff + jitter
        if n >= p.dead_after and st.status != "dead":
            self._set_state(region, st, "dead")
            self.topology.mark_down(region)
            st.next_probe_tick = st.tick + p.probe_interval
            if self.monitor is not None:
                self.monitor.alert(
                    f"replica {region} marked DEAD after {n} consecutive "
                    f"delivery failures"
                )
        elif n >= p.suspect_after and st.status == "healthy":
            self._set_state(region, st, "suspect")
        if (
            p.evict_after is not None
            and n >= p.evict_after
            and region != self.home_region
        ):
            self.evict_replica(region)

    def _record_success(self, region: str) -> None:
        st = self.delivery[region]
        st.consecutive_failures = 0
        st.backoff_until = st.tick
        if st.status != "healthy":
            was_dead = st.status == "dead"
            self._set_state(region, st, "healthy")
            if was_dead:
                # recovery undoes the DETECTED mark_down: the replica is
                # still cursor-tracked, so normal draining catches it up —
                # no bootstrap needed (that path is for EVICTED regions)
                self.topology.mark_up(region)

    def probe(self, region: str) -> bool:
        """Re-probe a DEAD link with a zero-batch probe frame.  Success
        flips the link back HEALTHY (and the region back up); failure
        re-schedules the next probe — and can push the streak over the
        eviction threshold.  Any frames a faulty channel had withheld
        (reorder) ride in with the probe's delivery and are applied."""
        st = self.delivery[region]
        st.probes += 1
        ok = self._ship_frame(region, wire.encode_probe()) is not None
        if ok:
            self._record_success(region)
            return True
        self._record_failure(region)
        st = self.delivery.get(region)  # the failure may have evicted it
        if st is not None:
            st.next_probe_tick = st.tick + self.policy.probe_interval
        return False

    def evict_replica(self, region: str) -> None:
        """Tear down a replica that stayed dead past ``evict_after``: its
        stores, ledger, cursor, and delivery state all go — the log stops
        retaining batches for it, so one unreachable region cannot pin the
        log at capacity forever.  Re-admission is a fresh ``rejoin`` (delta
        bootstrap), and ``on_evict`` lets the control plane react."""
        if region == self.home_region:
            raise ValueError("cannot evict the home region")
        self.stores.pop(region, None)
        self.offline_stores.pop(region, None)
        self.remote.pop(region, None)
        self.channels.pop(region, None)
        self.shipped.pop(region, None)
        self.delivery.pop(region, None)
        self.log.drop_replica(region)
        if self.monitor is not None:
            self.monitor.clear_replica_gauges(region)
            self.monitor.system.inc("replication/evictions")
            self.monitor.alert(f"replica {region} evicted from the serving set")
        if self.on_evict is not None:
            self.on_evict(region)

    # -- lag accounting --------------------------------------------------------
    def lag_batches(self, region: str) -> int:
        """O(1) un-acked batch count — cheap enough for the read hot path
        (the full ``lag`` scans the log for rows/staleness; monitoring
        cadence only)."""
        if region == self.home_region:
            return 0
        return self.log.pending_count(region)

    def lag(self, region: str) -> LagStats:
        """Replication lag of one region: un-acked batches/rows (combined +
        per plane) plus staleness in clock units (0 when fully caught up).
        The home region is by definition in sync."""
        if region == self.home_region:
            return LagStats()
        raw = self.log.lag(region)
        oldest = raw.oldest_pending_creation_ts
        return dataclasses.replace(
            raw,
            staleness_ms=(
                max(0, int(self.clock()) - oldest) if oldest is not None else 0
            ),
        )

    def _record_lag(self, region: str) -> None:
        if self.monitor is not None:
            self.monitor.record_replication_lag(region, self.lag(region))

    # -- fail-over replay -------------------------------------------------------
    def _adopt_remote(self, region: str) -> None:
        """Materialize a remote replica's daemon-held state into fresh
        in-process stores (the ``bootstrap_delta`` rebuild pattern run in
        reverse: dump chunks -> ``merge_reduced``/``apply_chunks``) and
        move the region from the remote set into the local store map.
        ``dump_all`` order is the sorted key index, so the rebuilt online
        store is byte-identical to what an in-process replica would hold;
        offline chunks rebuild through full-key dedup, so the canonical
        history matches chunk-set-identically."""
        ch = self.channels[region]
        home = self.stores[self.home_region]
        store = OnlineStore(
            home.num_partitions,
            home.initial_capacity,
            interpret=home.interpret,
            merge_engine=home.merge_engine,
        )
        home_off = self.offline_stores.get(self.home_region)
        off: Optional[OfflineStore] = None
        if self.remote[region]["offline"] and home_off is not None:
            off = OfflineStore(
                home_off.num_shards,
                home_off.time_partition,
                merge_engine=home_off.merge_engine,
                compact_threshold=home_off.compact_threshold,
            )
        for spec in list(self._specs.values()):
            if spec.materialization.online_enabled:
                store.register(spec)
                for b in ch.fetch_dump(spec, "online"):
                    store.merge_reduced(
                        spec, b.keys, b.event_ts, b.values, b.creation_ts
                    )
            if off is not None and spec.materialization.offline_enabled:
                off.register(spec)
                for b in ch.fetch_dump(spec, "offline"):
                    cols = dict(b.columns or {})
                    creation = cols.pop(CREATION_TS, b.creation_ts)
                    off.apply_chunks(spec, b.keys, b.event_ts, creation, cols)
        self.stores[region] = store
        if off is not None:
            self.offline_stores[region] = off
        self.remote.pop(region, None)
        self.channels.pop(region, None)

    def promote(self, region: str) -> dict:
        """Data-plane half of fail-over: replay the promoted replica's
        un-acked log suffix into its stores — BOTH planes (per-plane
        idempotence makes any overlap with already-applied batches a
        no-op) — then make it the new home: its online AND offline merges
        now feed the log for the remaining replicas, whose cursors carry
        over untouched.  The lost ex-home's stores leave the replica set;
        a recovered ex-home rejoins via the delta-bootstrap path
        (``GeoFeatureStore.rejoin``)."""
        if region == self.home_region:
            return {"replayed_batches": 0, "replayed_rows": 0}
        if region not in self.stores and region not in self.remote:
            raise RegionDownError(f"no replica store in {region}")
        # the replay MUST complete — a promoted home missing acked-elsewhere
        # suffix batches would diverge forever — so push through channel
        # faults with forced drains (no backoff deferral, probes bypassed)
        # and fail loudly if the link won't carry the suffix at all
        replay = {"applied_batches": 0, "applied_rows": 0}
        for _ in range(self.policy.promote_rounds):
            got = self.drain(region, force=True)[region]
            replay["applied_batches"] += got["applied_batches"]
            replay["applied_rows"] += got["applied_rows"]
            if self.log.pending_count(region) == 0:
                break
        else:
            raise DeliveryError(
                f"promotion replay for {region} did not converge within "
                f"{self.policy.promote_rounds} forced drains"
            )
        if region in self.remote:
            # the promoted replica's state lives in a daemon process; a
            # home must publish from in-process stores, so adopt the
            # daemon's (now fully converged) state before the swap
            self._adopt_remote(region)
        old_home_region = self.home_region
        old_home = self.stores[self.home_region]
        try:
            old_home.merge_listeners.remove(self._on_home_merge)
        except ValueError:
            pass
        old_offline = self.offline_stores.pop(self.home_region, None)
        if old_offline is not None:
            try:
                old_offline.merge_listeners.remove(self._on_home_offline_merge)
            except ValueError:
                pass
        del self.stores[self.home_region]
        self.log.drop_replica(region)
        self.shipped.pop(region, None)
        self.delivery.pop(region, None)
        self.home_region = region
        if self.monitor is not None:
            # neither region is a replica any more: the promoted one is the
            # new home (in sync by definition), the dead ex-home left the
            # serving set — without this, a departed replica's last lag/
            # staleness gauges would report forever
            self.monitor.clear_replica_gauges(region)
            self.monitor.clear_replica_gauges(old_home_region)
        self.stores[region].merge_listeners.append(self._on_home_merge)
        new_offline = self.offline_stores.get(region)
        if new_offline is not None:
            new_offline.merge_listeners.append(self._on_home_offline_merge)
        return {
            "replayed_batches": replay["applied_batches"],
            "replayed_rows": replay["applied_rows"],
        }


class GeoFeatureStore:
    """Read/write router over a home ``FeatureStore`` plus geo-replicated
    replicas of BOTH store planes.

    Writes (materialization ticks, backfills, direct merges) always land in
    the home region; listeners stream every online merge's reduced batch
    AND every offline merge's inserted rows into the one replication log.
    Online reads route to the nearest IN-SYNC region (lag <=
    ``max_lag_batches``), preferring the consumer's own region — the
    paper's local-read latency win.  ``failover`` composes the placement
    decision (nearest healthy replica) with the log replay that makes the
    promoted region's online store byte-identical and its offline store
    chunk-set-identical to the lost home, then re-points both of the home
    ``FeatureStore``'s planes at the promoted stores.  ``rejoin`` re-admits
    a recovered ex-home through the delta-bootstrap path.
    """

    def __init__(
        self,
        name: str,
        *,
        topology: GeoTopology,
        home_region: str,
        replica_regions: tuple[str, ...] = (),
        max_lag_batches: int = 0,
        log_capacity: int = 1024,
        auto_drain: bool = False,
        compress_level: Optional[int] = DEFAULT_COMPRESS_LEVEL,
        channel: Optional[Channel] = None,
        delivery_policy: Optional[DeliveryPolicy] = None,
        **fs_kwargs,
    ) -> None:
        self.fs = FeatureStore(
            name,
            region=home_region,
            topology=topology,
            replication=ReplicationPolicy.GEO_REPLICATED,
            **fs_kwargs,
        )
        self.topology = topology
        self.placement = self.fs.geo
        self.max_lag_batches = max_lag_batches
        self.auto_drain = auto_drain
        self.log = ReplicationLog(capacity=log_capacity)
        #: regions the delivery state machine evicted; each all-region
        #: drain re-probes them and rejoins the ones whose link came back
        self.evicted: set[str] = set()
        self.replicator = GeoReplicator(
            self.fs.online,
            topology=topology,
            home_region=home_region,
            home_offline=self.fs.offline,
            log=self.log,
            clock=self.fs.clock,
            monitor=self.fs.monitor,
            compress_level=compress_level,
            channel=channel,
            policy=delivery_policy,
            on_evict=self._on_evict,
        )
        self.fs.attach_replication(self.replicator)
        self.last_bootstrap: Optional[dict] = None
        for region in replica_regions:
            self.add_replica(region)

    @property
    def home_region(self) -> str:
        return self.replicator.home_region

    # -- explicit home-store delegation ---------------------------------------
    # (formerly a __getattr__ passthrough: every delegated name is now
    # spelled out, so the geo surface IS the visible API — StoreFacade plus
    # the home store's asset/clock/monitoring handles)
    @property
    def registry(self):
        return self.fs.registry

    @property
    def monitor(self):
        return self.fs.monitor

    @property
    def clock(self):
        return self.fs.clock

    def register_source(self, source) -> None:
        self.fs.register_source(source)

    def create_entity(self, entity):
        return self.fs.create_entity(entity)

    def advance_clock(self, to: int) -> None:
        self.fs.advance_clock(to)

    def check_consistency(self, name: str, version: int):
        return self.fs.check_consistency(name, version)

    def get_offline_features(self, *args, **kwargs):
        return self.fs.get_offline_features(*args, **kwargs)

    # -- membership ----------------------------------------------------------
    def add_replica(self, region: str, *, chunk_rows: int = 65_536) -> OnlineStore:
        """Create a two-plane replica in ``region``: compliance-check
        placement, clone both home stores' configuration, delta-bootstrap
        every table (snapshot cut at the registered cursor, streamed in
        bounded ``chunk_rows`` pieces), and start cursor-tracking new
        batches.  Returns the replica's online store; bootstrap stats land
        in ``last_bootstrap``."""
        self.placement.add_replica(region)  # ComplianceError when geo-fenced
        home = self.fs.online
        home_off = self.fs.offline
        store = OnlineStore(
            num_partitions=home.num_partitions,
            initial_capacity=home.initial_capacity,
            interpret=home.interpret,
            merge_engine=home.merge_engine,
        )
        offline = OfflineStore(
            num_shards=home_off.num_shards,
            time_partition=home_off.time_partition,
            merge_engine=home_off.merge_engine,
            compact_threshold=home_off.compact_threshold,
        )
        cut = self.replicator.add_replica(region, store, offline)
        totals = {"cut_seq": cut, "online_rows": 0, "offline_rows": 0, "chunks": 0}
        for n, v in self.fs.registry.list_feature_sets():
            spec = self.fs.registry.get_feature_set(n, v)
            got = self.replicator.bootstrap_delta(region, spec, chunk_rows=chunk_rows)
            for k in ("online_rows", "offline_rows", "chunks"):
                totals[k] += got[k]
        self.last_bootstrap = totals
        return store

    def rejoin(self, region: str, *, chunk_rows: int = 65_536) -> dict:
        """Re-admit a recovered ex-home (or any previously-dropped region)
        as a replica: fresh stores, delta bootstrap of BOTH planes, cursor
        at the snapshot cut — the reverse of failover's prune, so a region
        whose stores were lost at promotion returns to the serving set
        instead of being gone forever.  Requires the region healthy again
        (``mark_up``).  Returns the bootstrap stats."""
        if region not in self.topology.regions:
            raise ValueError(f"unknown region {region}")
        if not self.topology.regions[region].healthy:
            raise RegionDownError(f"region {region} is still down; mark_up first")
        if region in self.replicator.stores:
            raise ValueError(f"region {region} is already in the serving set")
        self.add_replica(region, chunk_rows=chunk_rows)
        return {"rejoined": region, **self.last_bootstrap}

    # -- asset management ------------------------------------------------------
    def create_feature_set(self, spec: FeatureSetSpec) -> FeatureSetSpec:
        """Register with the home store, then pre-register the (empty)
        tables on every replica — both planes — so a relaxed-staleness read
        can serve before the first batch arrives."""
        spec = self.fs.create_feature_set(spec)
        for region in self.replicator.replica_regions():
            if spec.materialization.online_enabled:
                self.replicator.stores[region].register(spec)
            offline = self.replicator.offline_stores.get(region)
            if offline is not None and spec.materialization.offline_enabled:
                offline.register(spec)
        return spec

    # -- writes (home region) -------------------------------------------------
    def tick(self, now: Optional[int] = None) -> dict[str, int]:
        stats = self.fs.tick(now)
        if self.auto_drain:
            self.drain()
        return stats

    def backfill(self, name: str, version: int, start: int, end: int) -> dict:
        stats = self.fs.backfill(name, version, start, end)
        if self.auto_drain:
            self.drain()
        return stats

    def write_batch(
        self,
        name: str,
        version: int,
        frame,
        *,
        creation_ts: Optional[int] = None,
        region: Optional[str] = None,
    ) -> dict:
        """Facade write surface: single-home geo — every write lands in the
        home region regardless of where it originated (``region`` must be
        the home when given; multi-home splitting is ``MultiHomeGeoStore``)."""
        if region is not None and region != self.home_region:
            raise ValueError(
                f"single-home geo store writes land in {self.home_region}; "
                f"got region={region!r} (want MultiHomeGeoStore?)"
            )
        stats = self.fs.write_batch(name, version, frame, creation_ts=creation_ts)
        if self.auto_drain:
            self.drain()
        return stats

    def drain(self, region: Optional[str] = None) -> dict:
        out = self.replicator.drain(region)
        if region is None:
            # evicted regions are no longer cursor-tracked, so the normal
            # probe path can't see them — re-probe here and rejoin (delta
            # bootstrap) the ones whose link carries bytes again
            for r in sorted(self.evicted):
                if self._try_rejoin(r):
                    out[r] = {
                        "applied_batches": 0,
                        "applied_rows": 0,
                        "rejoined": True,
                    }
        return out

    def _try_rejoin(self, region: str) -> bool:
        """One recovery attempt for an evicted region: probe the link with
        a zero-batch frame; if the probe lands, re-admit through the full
        ``rejoin`` delta bootstrap.  A bootstrap that dies against a
        still-flaky link rolls membership back (the region stays evicted)
        and the next drain tries again."""
        rep = self.replicator
        d = rep.channel.transmit(self.home_region, region, wire.encode_probe())
        decoded = False
        for payload in d.arrivals:
            try:
                wire.decode_frame(payload)
                decoded = True
            except wire.WireFormatError:
                pass
        if d.ack_lost or d.latency_ms > rep.policy.ack_timeout_ms or not decoded:
            return False
        self.mark_up(region)
        self.evicted.discard(region)
        try:
            self.rejoin(region)
        except DeliveryError:
            rep.evict_replica(region)  # rolls back via the on_evict hook
            self.mark_down(region)
            return False
        return True

    def recover(self, region: str) -> dict:
        """Manually re-admit an evicted region (the automatic path runs on
        every all-region ``drain``).  Raises ``DeliveryError`` if the link
        still won't carry the bootstrap."""
        if region not in self.evicted:
            raise ValueError(f"region {region} is not evicted")
        self.mark_up(region)
        self.evicted.discard(region)
        try:
            return self.rejoin(region)
        except DeliveryError:
            self.replicator.evict_replica(region)
            self.mark_down(region)
            raise

    def lag(self, region: str) -> LagStats:
        return self.replicator.lag(region)

    # -- reads (nearest in-sync region) ----------------------------------------
    def route_read(
        self, consumer_region: str, *, max_lag_batches: Optional[int] = None
    ) -> tuple[str, float]:
        """Pick the serving region for ``consumer_region``: the consumer's
        own region when it hosts an in-sync healthy store, else the
        nearest in-sync healthy one (home is always in sync).  The sync
        gate is an O(1) cursor-distance check; nearest-healthy selection
        and read-log bookkeeping delegate to placement.  Returns (region,
        modeled one-way latency ms)."""
        max_lag = self.max_lag_batches if max_lag_batches is None else max_lag_batches
        rep = self.replicator
        in_sync = [r for r in rep.stores if rep.lag_batches(r) <= max_lag]
        return self.placement.route_read(consumer_region, candidates=in_sync)

    def get_online_features(
        self,
        name: str,
        version: int,
        id_columns: list[np.ndarray],
        *,
        consumer_region: Optional[str] = None,
        use_kernel: bool = True,
        max_lag_batches: Optional[int] = None,
    ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Geo-routed online GET.  Returns (values, found, route) where
        ``route`` records the serving region and the modeled latency the
        read paid — the number the geo benchmark contrasts across
        mechanisms."""
        consumer = consumer_region or self.home_region
        serving, ms = self.route_read(consumer, max_lag_batches=max_lag_batches)
        vals, found = self.replicator.stores[serving].lookup(
            name, version, id_columns, now=self.fs.clock(), use_kernel=use_kernel
        )
        self.fs.monitor.system.observe("geo/read_modeled_ms", ms)
        return vals, found, {"region": serving, "modeled_ms": ms}

    # -- failure handling --------------------------------------------------------
    def _on_evict(self, region: str) -> None:
        """Replicator eviction hook: drop the region from placement's
        serving set and queue it for the auto-rejoin probe in ``drain``."""
        if region != self.placement.home_region:
            self.placement.remove_replica(region)
        self.evicted.add(region)

    def mark_down(self, region: str) -> None:
        self.placement.mark_down(region)

    def mark_up(self, region: str) -> None:
        self.placement.mark_up(region)

    def failover(self, region: Optional[str] = None) -> Optional[dict]:
        """Promote the nearest healthy replica when the home region is down:
        placement re-points (regions.py), the replicator replays the
        promoted replica's un-acked suffix — BOTH planes — and the home
        ``FeatureStore`` adopts the promoted stores as its online AND
        offline planes, so materialization and training reads resume
        against the new primary without offline/online skew.  The dead
        ex-home leaves the serving set entirely (its stores are gone; a
        LATER failover must never promote it) — if it recovers, ``rejoin``
        re-admits it via delta bootstrap.  Returns promotion info, or None
        when the home region is healthy.

        ``region`` (facade surface) names the lost region; a single-home
        store only ever loses its home, so anything else is an error."""
        old_home = self.home_region
        if region is not None and region != old_home:
            raise ValueError(
                f"single-home geo store can only fail over its home "
                f"{old_home}; got {region!r}"
            )
        new_home = self.placement.failover()
        if new_home is None:
            return None
        replay = self.replicator.promote(new_home)
        self.placement.remove_replica(old_home)
        promoted = self.replicator.stores[new_home]
        self.fs.online = promoted
        self.fs.materializer.online = promoted
        promoted_offline = self.replicator.offline_stores.get(new_home)
        if promoted_offline is not None:
            self.fs.offline = promoted_offline
            self.fs.materializer.offline = promoted_offline
        return {"promoted": new_home, **replay}


# Imported at the BOTTOM: wire.py needs ReplicatedBatch (and the compression
# default) from this module, so importing it any earlier would be circular.
# By the time any GeoReplicator method dereferences `wire`, both modules are
# fully initialized regardless of which one a caller imported first.
from repro.core import wire  # noqa: E402
