"""Async geo-replication of BOTH store planes (paper §2.1, §4.1.2 road map).

The paper's implemented mechanism keeps an asset in its creation region and
pays WAN latency on every remote read; its road-map mechanism replicates the
asset into consumer regions so reads are local.  This module is that road-map
mechanism made concrete for both materialization targets: the paper's store
is only a feature store because the SAME data lands offline (training) and
online (inferencing), so a failover that recovers one plane but not the
other reintroduces exactly the online–offline skew the architecture exists
to prevent.  Both planes ship through one log:

  * ONLINE plane — every ``OnlineStore.merge`` reduces a materialization
    frame to the winning writes it actually applied (encoded key, winning
    event_ts, feature row, one shared creation_ts) and reports them in its
    stats (PR 2's shipping unit);
  * OFFLINE plane — every ``OfflineStore.merge`` reports the rows it
    actually INSERTED (post full-key dedup, arrival order): encoded entity
    keys + event_ts flat arrays plus the index/feature columns in native
    dtypes.  Replica-side ``OfflineStore.apply_chunks`` re-runs the same
    full-key dedup, so a replica's shard-chunk set converges to the home's.

Two-plane ``ReplicatedBatch`` protocol
--------------------------------------
A batch tags ``plane="online"|"offline"`` over one shared sequence: the
``ReplicationLog`` is ONE totally-ordered log per home store, and each
replica owns ONE cursor covering both planes — per-replica cursor semantics,
out-of-order ack handling, truncation, and backpressure are plane-agnostic.
``keys``/``event_ts``/``values`` are flat planes for both variants; offline
batches add ``columns`` (index + native-dtype feature arrays, the record-
schema remainder) and leave ``values`` empty.  ``ReplicationLog.lag``
reports a per-plane breakdown on top of the combined counts.

Wire transport (core/wire.py)
-----------------------------
Replica-bound batches do NOT travel as in-process references: every batch a
replica receives — drain, out-of-order ``apply_batch``, delta bootstrap,
failover replay — is serialized into a contiguous wire frame (fixed header
+ length-prefixed dtype-tagged arrays, optional zlib), shipped over the
modeled WAN, and DECODED on the replica side; the replica applies read-only
views of the received buffer, so it can never alias or corrupt publisher
memory.  The log itself stores frozen private copies on ``append`` for the
same reason (an un-shipped batch must survive later in-place mutation of
the publisher's buffers).  ``drain`` coalesces runs of adjacent same-plane
same-table pending batches into one frame per run (one header, one shared
compression stream), while acking each constituent batch by its own seq.
Shipping accounting (``GeoReplicator.shipped``, the monitor's
``replication/shipped_*`` counters) records MEASURED bytes — serialized
raw payload and post-compression wire size — and ``topology.transfer_ms``
prices the wire size, making the per-plane shipped-bytes benchmarks true
transport measurements rather than array-size estimates.

Log / cursor / replay protocol
------------------------------
``ReplicationLog`` is a bounded, totally-ordered sequence of reduced
batches, appended by listeners on the home stores' ``merge_listeners``.
Each replica owns a CURSOR: the lowest sequence number it has not yet
acknowledged.  The async applier (``GeoReplicator.drain``) ships pending
batches over the modeled WAN link and applies them to the replica stores —
``OnlineStore.merge_reduced`` (the same Algorithm-2 engines the home store
runs) or ``OfflineStore.apply_chunks`` by plane.  Acknowledgements may
arrive out of order (``apply_batch``); the cursor only advances over the
contiguous acknowledged prefix, so lag accounting never under-reports.
``truncate`` drops exactly the prefix below EVERY cursor — an un-acked
batch is never dropped; when the log is full and no prefix is fully
acknowledged, ``append`` raises ``ReplicationLogFull`` (backpressure)
instead of losing data.  The PUBLISHER must never lose a batch either (the
home store has already applied it when the listener fires), so under
backpressure the replicator first degrades to a synchronous drain of every
healthy replica — a drain applies BOTH planes, so mixed-plane tails are
fully accounted before concluding a replica pins the log — and only if a
dead replica still pins the tail does it force-append past capacity —
bounded growth plus a monitor counter, never divergence.

Replay safety is per plane: the online plane relies on Algorithm 2 being an
idempotent, commutative, latest-wins join on (event_ts, creation_ts); the
offline plane relies on full-key (id, event_ts, creation_ts) insert-if-
absent idempotence.  Re-delivering a batch is a no-op, reordered batches
converge, and replaying a suffix that partially overlaps already-applied
writes is safe.  That is what makes fail-over exactly-once in EFFECT with
at-least-once DELIVERY: ``GeoPlacement.failover`` picks the nearest healthy
replica (regions.py), then ``GeoReplicator.promote`` replays that replica's
un-acked suffix, leaving its online store byte-identical and its offline
store chunk-set-identical to the home's pre-failure state.

Delta bootstrap + rejoin lifecycle
----------------------------------
A replica added after data exists bootstraps via ``bootstrap_delta``: its
cursor registers at the CURRENT log head (the snapshot-cut sequence
number), then the home state as of that cut streams over in bounded chunks
(``chunk_rows`` at a time — offline via ``OfflineStore.export_chunks``,
online via creation_ts-grouped slices of the dump), and normal draining
from the cut cursor catches it up.  Batches appended DURING the stream
overlap the snapshot harmlessly (idempotence again), and an interrupted
stream can simply be retried — no chunk is ever applied twice.  The same
path re-admits a recovered ex-home: ``GeoFeatureStore.rejoin(region)`` =
fresh stores + delta bootstrap of both planes + cursor at the cut, so a
region whose stores were lost at promotion rejoins as a first-class
replica instead of being dropped forever.

``GeoFeatureStore`` is the read/write router on top: writes (materialization
ticks, backfills) go to the home region's ``FeatureStore``; online reads are
served by the nearest IN-SYNC replica (replication lag at most
``max_lag_batches``), falling back to the home store; per-replica and
per-plane lag / staleness land in the health monitor.  ``failover()``
re-points BOTH of the home ``FeatureStore``'s planes at the promoted
region's stores, so materialization and training reads resume against the
new primary without skew.  Geo-fenced home regions refuse replication
(``ComplianceError``, §4.1.2) exactly as placement does.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.featurestore import FeatureStore
from repro.core.offline_store import CREATION_TS, EVENT_TS, OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.regions import GeoTopology, RegionDownError, ReplicationPolicy

__all__ = [
    "DEFAULT_COMPRESS_LEVEL",
    "GeoFeatureStore",
    "GeoReplicator",
    "ReplicatedBatch",
    "ReplicationLog",
    "ReplicationLogFull",
]

#: default zlib level for the wire codec (core/wire.py re-exports it); the
#: constant lives here, not in wire.py, because wire.py imports this module
#: (for ReplicatedBatch) and default-argument values need it at class-body
#: execution time, before the bottom-of-module wire import has run.
#: Level 1 is the throughput sweet spot on merge-batch payloads (random-ish
#: float features + low-entropy keys/timestamps): ~97% of level 6's ratio
#: at ~1/3 the encode cost; 0 disables compression entirely.
DEFAULT_COMPRESS_LEVEL = 1


class ReplicationLogFull(RuntimeError):
    """The log hit capacity and no fully-acknowledged prefix can be
    truncated — backpressure instead of dropping un-acked batches."""


@dataclasses.dataclass(frozen=True)
class ReplicatedBatch:
    """One reduced merge batch from either store plane.

    ``plane="online"``: the winning writes a single home online-store merge
    applied, in (part, slot) order as the home store reported them —
    ``values`` is the (G, D) float32 feature plane, ``columns`` is None.

    ``plane="offline"``: the rows a single home offline-store merge actually
    INSERTED (post full-key dedup, arrival order) — ``values`` is empty and
    ``columns`` carries the record-schema remainder (index columns + native-
    dtype feature columns), so the replica rebuilds byte-identical chunks.
    """

    seq: int
    table: tuple[str, int]
    creation_ts: int
    keys: np.ndarray  # (G,) int64 encoded entity keys
    event_ts: np.ndarray  # (G,) int64 winning event_ts per key
    values: np.ndarray  # (G, D) float32 winning feature rows (online plane)
    plane: str = "online"
    columns: Optional[dict[str, np.ndarray]] = None  # offline plane payload

    @property
    def rows(self) -> int:
        return len(self.keys)

    @property
    def nbytes(self) -> int:
        n = self.keys.nbytes + self.event_ts.nbytes + self.values.nbytes
        if self.columns is not None:
            n += sum(v.nbytes for v in self.columns.values())
        return n


def _frozen_copy(a: np.ndarray, dtype=None) -> np.ndarray:
    """Private read-only copy of a caller array: the log must not alias
    live publisher buffers (copy) and nothing downstream may mutate a
    logged batch in place (writeable=False)."""
    out = np.array(a, dtype=dtype, copy=True)
    out.flags.writeable = False
    return out


class ReplicationLog:
    """Bounded sequence of reduced batches + one cursor per replica.

    A cursor is the lowest un-acknowledged sequence number; acks may land
    out of order, and the cursor advances only over the contiguous prefix.
    """

    def __init__(self, capacity: int = 1024) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.next_seq = 0
        self.cursors: dict[str, int] = {}
        self._batches: deque[ReplicatedBatch] = deque()
        self._acked_ahead: dict[str, set[int]] = {}

    def __len__(self) -> int:
        return len(self._batches)

    def register_replica(self, name: str, from_seq: Optional[int] = None) -> int:
        """Start tracking a replica.  By default its cursor starts at the
        current head — the caller is responsible for snapshot-bootstrapping
        state appended before registration.  An explicit ``from_seq`` must
        lie between the oldest RETAINED sequence number and the head: a
        cursor past ``next_seq`` (or negative) drives ``pending_count``
        negative and silently passes the in-sync read gate while the
        replica is arbitrarily stale, and a cursor below the truncated
        floor pins pending batches that no longer exist — nothing is
        drainable, so the replica could never catch up (it missed the
        truncated data; it needs a snapshot bootstrap, not a cursor)."""
        if from_seq is not None:
            floor = self._batches[0].seq if self._batches else self.next_seq
            if not (floor <= from_seq <= self.next_seq):
                raise ValueError(
                    f"from_seq {from_seq} outside [{floor}, {self.next_seq}] "
                    f"(cursor may not start past the log head or below the "
                    f"truncated floor)"
                )
        cursor = self.next_seq if from_seq is None else from_seq
        self.cursors[name] = cursor
        self._acked_ahead[name] = set()
        return cursor

    def drop_replica(self, name: str) -> None:
        self.cursors.pop(name, None)
        self._acked_ahead.pop(name, None)

    def pending_count(self, replica: str) -> int:
        """O(1) un-acked batch count — the serving path's in-sync gate."""
        ahead = len(self._acked_ahead[replica])
        return self.next_seq - self.cursors[replica] - ahead

    def append(
        self,
        table: tuple[str, int],
        creation_ts: int,
        keys: np.ndarray,
        event_ts: np.ndarray,
        values: np.ndarray,
        *,
        plane: str = "online",
        columns: Optional[dict[str, np.ndarray]] = None,
        force: bool = False,
    ) -> ReplicatedBatch:
        """Append one reduced batch (either plane — both share the one
        sequence); truncates the fully-acked prefix first and raises
        ``ReplicationLogFull`` rather than evicting un-acked batches when
        the log is still at capacity.  ``force=True`` appends past capacity
        instead of raising — for a publisher whose store ALREADY applied
        the batch, losing it is worse than growing the log (see
        GeoReplicator._publish).

        The logged arrays are private COPIES, frozen read-only: the caller
        hands in live views of its own buffers (an online merge's
        ``touched_values``, an offline merge's ``inserted_columns`` slices
        of the frame), and an un-shipped batch may sit in the log across
        later in-place mutation or compaction of those buffers.  Aliasing
        them would silently corrupt whatever eventually ships."""
        if plane not in ("online", "offline"):
            raise ValueError(f"unknown plane {plane!r}")
        if len(self._batches) >= self.capacity:
            self.truncate()
        if len(self._batches) >= self.capacity and not force:
            slowest = min(self.cursors.values(), default=None)
            msg = f"log at capacity {self.capacity}; slowest cursor {slowest}"
            raise ReplicationLogFull(msg)
        batch = ReplicatedBatch(
            seq=self.next_seq,
            table=table,
            creation_ts=int(creation_ts),
            keys=_frozen_copy(keys, np.int64),
            event_ts=_frozen_copy(event_ts, np.int64),
            values=_frozen_copy(values, np.float32),
            plane=plane,
            columns=(
                None
                if columns is None
                else {k: _frozen_copy(v) for k, v in columns.items()}
            ),
        )
        self.next_seq += 1
        self._batches.append(batch)
        return batch

    def pending(self, replica: str) -> list[ReplicatedBatch]:
        """Batches the replica has not acknowledged, in sequence order."""
        cursor = self.cursors[replica]
        ahead = self._acked_ahead[replica]
        return [b for b in self._batches if b.seq >= cursor and b.seq not in ahead]

    def ack(self, replica: str, seq: int) -> None:
        """Acknowledge one batch; the cursor advances over the contiguous
        acknowledged prefix only, so out-of-order acks never hide lag."""
        if seq >= self.next_seq:
            raise ValueError(f"ack of unknown seq {seq}")
        ahead = self._acked_ahead[replica]
        if seq >= self.cursors[replica]:
            ahead.add(seq)
        while self.cursors[replica] in ahead:
            ahead.remove(self.cursors[replica])
            self.cursors[replica] += 1

    def truncate(self) -> int:
        """Drop the prefix every replica has acknowledged.  Never touches a
        batch at or above any cursor, so un-acked batches survive.  Returns
        the number of batches dropped."""
        floor = min(self.cursors.values(), default=self.next_seq)
        dropped = 0
        while self._batches and self._batches[0].seq < floor:
            self._batches.popleft()
            dropped += 1
        return dropped

    def lag(self, replica: str) -> dict:
        """Un-acked batch/row counts (combined + per plane) and the oldest
        pending creation_ts.  The combined counts are what the in-sync read
        gate consumes; the per-plane breakdown feeds monitoring, so an
        offline-only backlog (e.g. a replica serving reads but behind on
        training history) is visible, not averaged away."""
        pend = self.pending(replica)
        planes = {
            p: {
                "batches": sum(1 for b in pend if b.plane == p),
                "rows": int(sum(b.rows for b in pend if b.plane == p)),
            }
            for p in ("online", "offline")
        }
        return {
            "batches": len(pend),
            "rows": int(sum(b.rows for b in pend)),
            "oldest_pending_creation_ts": (
                min(b.creation_ts for b in pend) if pend else None
            ),
            "planes": planes,
        }


class GeoReplicator:
    """Async applier: drains the home stores' replication log into replica
    stores (both planes) over the modeled WAN, tracks lag, and replays on
    fail-over.

    Every replica-bound batch — drain, out-of-order ``apply_batch``, delta
    bootstrap, failover replay — crosses the WAN hop as a serialized wire
    frame (core/wire.py): encode on the home side, decode on the replica
    side, apply only the decoded copy.  Adjacent same-plane same-table
    pending batches coalesce into one frame per ``drain``; shipping
    accounting records MEASURED raw and post-compression wire bytes, and
    the topology's bandwidth model prices the compressed size."""

    def __init__(
        self,
        home_store: OnlineStore,
        *,
        topology: GeoTopology,
        home_region: str,
        home_offline: Optional[OfflineStore] = None,
        log: Optional[ReplicationLog] = None,
        clock: Optional[Callable[[], int]] = None,
        monitor=None,
        compress_level: Optional[int] = DEFAULT_COMPRESS_LEVEL,
    ) -> None:
        self.topology = topology
        self.home_region = home_region
        self.log = log if log is not None else ReplicationLog()
        self.clock = clock or (lambda: 0)
        self.monitor = monitor
        self.compress_level = compress_level
        self.stores: dict[str, OnlineStore] = {home_region: home_store}
        # offline plane is optional: a standalone online-only replicator
        # (benchmarks, tests) never publishes offline batches
        self.offline_stores: dict[str, OfflineStore] = {}
        self.shipped: dict[str, dict] = {}
        self._specs: dict[tuple[str, int], FeatureSetSpec] = {}
        home_store.merge_listeners.append(self._on_home_merge)
        if home_offline is not None:
            self.offline_stores[home_region] = home_offline
            home_offline.merge_listeners.append(self._on_home_offline_merge)

    # -- publish (home side) ------------------------------------------------
    def _publish(self, payload: tuple, plane: str, columns=None) -> int:
        """Append one reduced batch to the log, degrading under
        backpressure.  The home store has ALREADY applied this batch by the
        time a listener fires, so the append must never lose it: when the
        log is full, backpressure degrades async replication to a
        synchronous drain of every healthy replica — the drain applies
        BOTH planes, so a mixed online/offline tail is fully accounted
        (cursors advance over every batch, freeing the prefix) before
        concluding that a replica pins the log; only if an UNHEALTHY
        replica still pins the tail is the batch force-appended — the log
        temporarily exceeds capacity (surfaced via the
        ``replication/log_force_appends`` counter) rather than diverging
        the replicas forever."""
        try:
            batch = self.log.append(*payload, plane=plane, columns=columns)
        except ReplicationLogFull:
            for region in self.replica_regions():
                if self.topology.regions[region].healthy:
                    self.drain(region)
            try:
                batch = self.log.append(*payload, plane=plane, columns=columns)
            except ReplicationLogFull:
                batch = self.log.append(
                    *payload, plane=plane, columns=columns, force=True
                )
                if self.monitor is not None:
                    self.monitor.system.inc("replication/log_force_appends")
        return batch.seq

    def _on_home_merge(self, spec: FeatureSetSpec, stats: dict) -> None:
        """Home ONLINE-store merge listener: append the batch's reduced
        winning writes to the log and annotate the stats with the seq."""
        self._specs[spec.key] = spec
        keys = stats.get("touched_keys")
        if keys is None or len(keys) == 0:
            stats["replication_seq"] = None  # pure no-op batch: nothing ships
            return
        payload = (
            spec.key,
            stats["creation_ts"],
            keys,
            stats["touched_event_ts"],
            stats["touched_values"],
        )
        stats["replication_seq"] = self._publish(payload, "online")

    def _on_home_offline_merge(self, spec: FeatureSetSpec, stats: dict) -> None:
        """Home OFFLINE-store merge listener: ship the rows the merge
        actually inserted (post full-key dedup) as an offline-plane batch."""
        self._specs[spec.key] = spec
        keys = stats.get("inserted_keys")
        if keys is None or len(keys) == 0:
            stats["replication_seq"] = None  # fully-deduped batch: no-op
            return
        payload = (
            spec.key,
            stats["creation_ts"],
            keys,
            stats["inserted_event_ts"],
            np.empty((len(keys), 0), np.float32),
        )
        stats["replication_seq"] = self._publish(
            payload, "offline", columns=stats["inserted_columns"]
        )

    # -- replica membership --------------------------------------------------
    def replica_regions(self) -> list[str]:
        return [r for r in self.stores if r != self.home_region]

    def add_replica(
        self,
        region: str,
        store: OnlineStore,
        offline_store: Optional[OfflineStore] = None,
    ) -> int:
        """Start tracking a replica; its single cursor (both planes) starts
        at the current head — the snapshot-cut sequence number the caller's
        ``bootstrap_delta`` streams state up to.  Returns that cut."""
        if region in self.stores:
            raise ValueError(f"region {region} already has a store")
        # the replica set must be plane-homogeneous: an online-only replica
        # under an offline-publishing home would crash every drain (and, via
        # the backpressure fallback, the home write path) on its first
        # offline batch — and an offline-capable replica under an
        # online-only home would set up the same crash for its siblings the
        # moment promote() makes it the publisher
        home_offline = self.home_region in self.offline_stores
        if offline_store is None and home_offline:
            raise ValueError(
                f"home {self.home_region} replicates the offline plane; "
                f"replica {region} must provide an offline store too"
            )
        if offline_store is not None and not home_offline:
            raise ValueError(
                f"home {self.home_region} does not replicate the offline "
                f"plane; construct GeoReplicator with home_offline or drop "
                f"replica {region}'s offline store"
            )
        self.stores[region] = store
        if offline_store is not None:
            self.offline_stores[region] = offline_store
        cut = self.log.register_replica(region)
        # "bytes" is the TRUE wire size (post-compression frame bytes, the
        # size the WAN bandwidth model prices); "raw_bytes" the serialized
        # payload before compression; "frames" counts wire messages (a
        # coalesced frame carries several batches)
        self.shipped[region] = {
            "frames": 0,
            "batches": 0,
            "rows": 0,
            "bytes": 0,
            "raw_bytes": 0,
            "ms": 0.0,
            "by_plane": {
                p: {"frames": 0, "batches": 0, "rows": 0, "bytes": 0, "raw_bytes": 0}
                for p in ("online", "offline")
            },
        }
        return cut

    def bootstrap_delta(
        self, region: str, spec: FeatureSetSpec, *, chunk_rows: int = 65_536
    ) -> dict:
        """Stream one table's home state AS OF the replica's registration
        cut into the new replica, in bounded ``chunk_rows`` pieces — the
        delta bootstrap: snapshot cut at a log sequence number (the cursor
        ``add_replica`` registered) + normal catch-up draining from that
        cursor.  A late replica therefore never holds a full second copy in
        flight, batches appended during the stream overlap it harmlessly
        (per-plane idempotence), and an interrupted stream is simply
        retried — ``apply_chunks``/``merge_reduced`` make re-application a
        no-op.  Every chunk crosses the WAN as a wire frame (seq = the
        out-of-log ``BOOTSTRAP_SEQ`` sentinel, never acked); offline chunks
        span many merges, so their per-row creation_ts rides along as a
        wire column the apply side peels off.  Returns per-plane
        bootstrapped row counts."""
        self._specs[spec.key] = spec
        out = {"online_rows": 0, "offline_rows": 0, "chunks": 0}
        home_online = self.stores[self.home_region]
        store = self.stores.get(region)
        if (
            store is not None
            and spec.materialization.online_enabled
            and home_online.has(spec.name, spec.version)
        ):
            store.register(spec)
            dump = home_online.dump_all(spec.name, spec.version)
            if len(dump):
                keys = dump["__key__"]
                event_ts = dump[EVENT_TS]
                creation_ts = dump[CREATION_TS]
                values = dump.column_stack([f.name for f in spec.features], np.float32)
                for cr in np.unique(creation_ts):
                    idx = np.flatnonzero(creation_ts == cr)
                    for lo in range(0, len(idx), chunk_rows):
                        sl = idx[lo : lo + chunk_rows]
                        batch = ReplicatedBatch(
                            seq=wire.BOOTSTRAP_SEQ,
                            table=spec.key,
                            creation_ts=int(cr),
                            keys=keys[sl],
                            event_ts=event_ts[sl],
                            values=values[sl],
                        )
                        self._ship_frame(
                            region,
                            wire.encode_batch(
                                batch, compress_level=self.compress_level
                            ),
                        )
                        out["online_rows"] += len(sl)
                        out["chunks"] += 1
        home_offline = self.offline_stores.get(self.home_region)
        offline = self.offline_stores.get(region)
        if (
            offline is not None
            and home_offline is not None
            and spec.materialization.offline_enabled
            and home_offline.has(spec.name, spec.version)
        ):
            offline.register(spec)
            for chunk in home_offline.export_chunks(
                spec.name, spec.version, max_rows=chunk_rows
            ):
                if len(chunk) == 0:
                    continue
                # CREATION_TS stays IN the columns payload: bootstrap chunks
                # span merges, so creation_ts is per-row, not the batch
                # scalar — _ship_frame pops it back out on the replica side
                cols = {
                    k: chunk[k] for k in chunk.names if k not in ("__key__", EVENT_TS)
                }
                batch = ReplicatedBatch(
                    seq=wire.BOOTSTRAP_SEQ,
                    table=spec.key,
                    creation_ts=int(chunk[CREATION_TS][0]),
                    keys=chunk["__key__"],
                    event_ts=chunk[EVENT_TS],
                    values=np.empty((len(chunk), 0), np.float32),
                    plane="offline",
                    columns=cols,
                )
                self._ship_frame(
                    region,
                    wire.encode_batch(batch, compress_level=self.compress_level),
                )
                out["offline_rows"] += len(chunk)
                out["chunks"] += 1
        return out

    # -- apply (replica side) -------------------------------------------------
    def _ship_frame(self, region: str, frame) -> list[dict]:
        """The WAN hop: hand a replica one encoded ``wire.WireFrame``, which
        it decodes and applies batch by batch (acking each logged seq).  The
        replica only ever touches the DECODED copies — read-only views of
        the received buffer, never the home store's live arrays — and the
        shipping ledger records the frame's measured raw + wire bytes, with
        ``topology.transfer_ms`` pricing the compressed size."""
        stats = []
        for batch in wire.decode_frame(frame.data):
            spec = self._specs[batch.table]
            if batch.plane == "offline":
                cols = dict(batch.columns or {})
                creation = cols.pop(CREATION_TS, batch.creation_ts)
                st = self.offline_stores[region].apply_chunks(
                    spec, batch.keys, batch.event_ts, creation, cols
                )
            else:
                st = self.stores[region].merge_reduced(
                    spec, batch.keys, batch.event_ts, batch.values, batch.creation_ts
                )
            if batch.seq != wire.BOOTSTRAP_SEQ:
                self.log.ack(region, batch.seq)
            stats.append(st)
        ship = self.shipped[region]
        ship["frames"] += 1
        ship["batches"] += len(stats)
        ship["rows"] += frame.rows
        ship["bytes"] += frame.wire_nbytes
        ship["raw_bytes"] += frame.raw_nbytes
        ship["ms"] += self.topology.transfer_ms(
            self.home_region, region, frame.wire_nbytes
        )
        plane = ship["by_plane"][frame.plane]
        plane["frames"] += 1
        plane["batches"] += len(stats)
        plane["rows"] += frame.rows
        plane["bytes"] += frame.wire_nbytes
        plane["raw_bytes"] += frame.raw_nbytes
        if self.monitor is not None:
            self.monitor.record_replication_ship(
                frame.rows,
                batches=len(stats),
                raw_nbytes=frame.raw_nbytes,
                wire_nbytes=frame.wire_nbytes,
                plane=frame.plane,
            )
        return stats

    def apply_batch(self, region: str, batch: ReplicatedBatch) -> dict:
        """Ship + apply ONE batch (either plane) to a replica and
        acknowledge it — a single-batch wire frame, no coalescing.  Exposed
        so tests can drive out-of-order delivery; ``drain`` is the in-order
        coalescing fast path."""
        frame = wire.encode_batch(batch, compress_level=self.compress_level)
        return self._ship_frame(region, frame)[0]

    def drain(
        self, region: Optional[str] = None, max_batches: Optional[int] = None
    ) -> dict:
        """Apply pending batches in sequence order — all replicas or one.
        Adjacent same-plane same-table batches coalesce into one wire frame
        (shared header + compression stream); each constituent batch is
        still acked by its own seq.  Replicas whose cursors align get the
        SAME frame — logged batches are immutable, so a run's encoding is
        a pure function of (plane, table, seq range) and is encoded (and
        zlib-compressed) once per drain pass, not once per replica.
        Returns {region: {"applied_batches", "applied_rows"}}."""
        regions = [region] if region is not None else self.replica_regions()
        out: dict[str, dict] = {}
        encoded: dict[tuple, object] = {}
        for r in regions:
            pend = self.log.pending(r)
            if max_batches is not None:
                pend = pend[:max_batches]
            rows = 0
            for run in wire.coalesce(pend):
                # exact seq tuple, not a (first, last) range: out-of-order
                # acks can punch holes in one replica's pending run, and a
                # range key would collide it with another replica's gapless
                # run over the same span
                key = (run[0].plane, run[0].table, tuple(b.seq for b in run))
                frame = encoded.get(key)
                if frame is None:
                    frame = wire.encode_run(run, compress_level=self.compress_level)
                    encoded[key] = frame
                self._ship_frame(r, frame)
                rows += frame.rows
            out[r] = {"applied_batches": len(pend), "applied_rows": rows}
            self._record_lag(r)
        self.log.truncate()
        return out

    # -- lag accounting --------------------------------------------------------
    def lag_batches(self, region: str) -> int:
        """O(1) un-acked batch count — cheap enough for the read hot path
        (the full ``lag`` scans the log for rows/staleness; monitoring
        cadence only)."""
        if region == self.home_region:
            return 0
        return self.log.pending_count(region)

    def lag(self, region: str) -> dict:
        """Replication lag of one region: un-acked batches/rows (combined +
        per plane) plus staleness in clock units (0 when fully caught up).
        The home region is by definition in sync."""
        if region == self.home_region:
            return {
                "batches": 0,
                "rows": 0,
                "staleness_ms": 0,
                "planes": {
                    p: {"batches": 0, "rows": 0} for p in ("online", "offline")
                },
            }
        raw = self.log.lag(region)
        oldest = raw.pop("oldest_pending_creation_ts")
        raw["staleness_ms"] = (
            max(0, int(self.clock()) - oldest) if oldest is not None else 0
        )
        return raw

    def _record_lag(self, region: str) -> None:
        if self.monitor is not None:
            self.monitor.record_replication_lag(region, **self.lag(region))

    # -- fail-over replay -------------------------------------------------------
    def promote(self, region: str) -> dict:
        """Data-plane half of fail-over: replay the promoted replica's
        un-acked log suffix into its stores — BOTH planes (per-plane
        idempotence makes any overlap with already-applied batches a
        no-op) — then make it the new home: its online AND offline merges
        now feed the log for the remaining replicas, whose cursors carry
        over untouched.  The lost ex-home's stores leave the replica set;
        a recovered ex-home rejoins via the delta-bootstrap path
        (``GeoFeatureStore.rejoin``)."""
        if region == self.home_region:
            return {"replayed_batches": 0, "replayed_rows": 0}
        if region not in self.stores:
            raise RegionDownError(f"no replica store in {region}")
        replay = self.drain(region)[region]
        old_home_region = self.home_region
        old_home = self.stores[self.home_region]
        try:
            old_home.merge_listeners.remove(self._on_home_merge)
        except ValueError:
            pass
        old_offline = self.offline_stores.pop(self.home_region, None)
        if old_offline is not None:
            try:
                old_offline.merge_listeners.remove(self._on_home_offline_merge)
            except ValueError:
                pass
        del self.stores[self.home_region]
        self.log.drop_replica(region)
        self.shipped.pop(region, None)
        self.home_region = region
        if self.monitor is not None:
            # neither region is a replica any more: the promoted one is the
            # new home (in sync by definition), the dead ex-home left the
            # serving set — without this, a departed replica's last lag/
            # staleness gauges would report forever
            self.monitor.clear_replica_gauges(region)
            self.monitor.clear_replica_gauges(old_home_region)
        self.stores[region].merge_listeners.append(self._on_home_merge)
        new_offline = self.offline_stores.get(region)
        if new_offline is not None:
            new_offline.merge_listeners.append(self._on_home_offline_merge)
        return {
            "replayed_batches": replay["applied_batches"],
            "replayed_rows": replay["applied_rows"],
        }


class GeoFeatureStore:
    """Read/write router over a home ``FeatureStore`` plus geo-replicated
    replicas of BOTH store planes.

    Writes (materialization ticks, backfills, direct merges) always land in
    the home region; listeners stream every online merge's reduced batch
    AND every offline merge's inserted rows into the one replication log.
    Online reads route to the nearest IN-SYNC region (lag <=
    ``max_lag_batches``), preferring the consumer's own region — the
    paper's local-read latency win.  ``failover`` composes the placement
    decision (nearest healthy replica) with the log replay that makes the
    promoted region's online store byte-identical and its offline store
    chunk-set-identical to the lost home, then re-points both of the home
    ``FeatureStore``'s planes at the promoted stores.  ``rejoin`` re-admits
    a recovered ex-home through the delta-bootstrap path.
    """

    def __init__(
        self,
        name: str,
        *,
        topology: GeoTopology,
        home_region: str,
        replica_regions: tuple[str, ...] = (),
        max_lag_batches: int = 0,
        log_capacity: int = 1024,
        auto_drain: bool = False,
        compress_level: Optional[int] = DEFAULT_COMPRESS_LEVEL,
        **fs_kwargs,
    ) -> None:
        self.fs = FeatureStore(
            name,
            region=home_region,
            topology=topology,
            replication=ReplicationPolicy.GEO_REPLICATED,
            **fs_kwargs,
        )
        self.topology = topology
        self.placement = self.fs.geo
        self.max_lag_batches = max_lag_batches
        self.auto_drain = auto_drain
        self.log = ReplicationLog(capacity=log_capacity)
        self.replicator = GeoReplicator(
            self.fs.online,
            topology=topology,
            home_region=home_region,
            home_offline=self.fs.offline,
            log=self.log,
            clock=self.fs.clock,
            monitor=self.fs.monitor,
            compress_level=compress_level,
        )
        self.fs.attach_replication(self.replicator)
        self.last_bootstrap: Optional[dict] = None
        for region in replica_regions:
            self.add_replica(region)

    @property
    def home_region(self) -> str:
        return self.replicator.home_region

    def __getattr__(self, name: str):
        # registry/asset/materialization surface delegates to the home store
        return getattr(self.fs, name)

    # -- membership ----------------------------------------------------------
    def add_replica(self, region: str, *, chunk_rows: int = 65_536) -> OnlineStore:
        """Create a two-plane replica in ``region``: compliance-check
        placement, clone both home stores' configuration, delta-bootstrap
        every table (snapshot cut at the registered cursor, streamed in
        bounded ``chunk_rows`` pieces), and start cursor-tracking new
        batches.  Returns the replica's online store; bootstrap stats land
        in ``last_bootstrap``."""
        self.placement.add_replica(region)  # ComplianceError when geo-fenced
        home = self.fs.online
        home_off = self.fs.offline
        store = OnlineStore(
            num_partitions=home.num_partitions,
            initial_capacity=home.initial_capacity,
            interpret=home.interpret,
            merge_engine=home.merge_engine,
        )
        offline = OfflineStore(
            num_shards=home_off.num_shards,
            time_partition=home_off.time_partition,
            merge_engine=home_off.merge_engine,
            compact_threshold=home_off.compact_threshold,
        )
        cut = self.replicator.add_replica(region, store, offline)
        totals = {"cut_seq": cut, "online_rows": 0, "offline_rows": 0, "chunks": 0}
        for n, v in self.fs.registry.list_feature_sets():
            spec = self.fs.registry.get_feature_set(n, v)
            got = self.replicator.bootstrap_delta(region, spec, chunk_rows=chunk_rows)
            for k in ("online_rows", "offline_rows", "chunks"):
                totals[k] += got[k]
        self.last_bootstrap = totals
        return store

    def rejoin(self, region: str, *, chunk_rows: int = 65_536) -> dict:
        """Re-admit a recovered ex-home (or any previously-dropped region)
        as a replica: fresh stores, delta bootstrap of BOTH planes, cursor
        at the snapshot cut — the reverse of failover's prune, so a region
        whose stores were lost at promotion returns to the serving set
        instead of being gone forever.  Requires the region healthy again
        (``mark_up``).  Returns the bootstrap stats."""
        if region not in self.topology.regions:
            raise ValueError(f"unknown region {region}")
        if not self.topology.regions[region].healthy:
            raise RegionDownError(f"region {region} is still down; mark_up first")
        if region in self.replicator.stores:
            raise ValueError(f"region {region} is already in the serving set")
        self.add_replica(region, chunk_rows=chunk_rows)
        return {"rejoined": region, **self.last_bootstrap}

    # -- asset management ------------------------------------------------------
    def create_feature_set(self, spec: FeatureSetSpec) -> FeatureSetSpec:
        """Register with the home store, then pre-register the (empty)
        tables on every replica — both planes — so a relaxed-staleness read
        can serve before the first batch arrives."""
        spec = self.fs.create_feature_set(spec)
        for region in self.replicator.replica_regions():
            if spec.materialization.online_enabled:
                self.replicator.stores[region].register(spec)
            offline = self.replicator.offline_stores.get(region)
            if offline is not None and spec.materialization.offline_enabled:
                offline.register(spec)
        return spec

    # -- writes (home region) -------------------------------------------------
    def tick(self, now: Optional[int] = None) -> dict[str, int]:
        stats = self.fs.tick(now)
        if self.auto_drain:
            self.drain()
        return stats

    def backfill(self, name: str, version: int, start: int, end: int) -> dict:
        stats = self.fs.backfill(name, version, start, end)
        if self.auto_drain:
            self.drain()
        return stats

    def drain(self, region: Optional[str] = None) -> dict:
        return self.replicator.drain(region)

    def lag(self, region: str) -> dict:
        return self.replicator.lag(region)

    # -- reads (nearest in-sync region) ----------------------------------------
    def route_read(
        self, consumer_region: str, *, max_lag_batches: Optional[int] = None
    ) -> tuple[str, float]:
        """Pick the serving region for ``consumer_region``: the consumer's
        own region when it hosts an in-sync healthy store, else the
        nearest in-sync healthy one (home is always in sync).  The sync
        gate is an O(1) cursor-distance check; nearest-healthy selection
        and read-log bookkeeping delegate to placement.  Returns (region,
        modeled one-way latency ms)."""
        max_lag = self.max_lag_batches if max_lag_batches is None else max_lag_batches
        rep = self.replicator
        in_sync = [r for r in rep.stores if rep.lag_batches(r) <= max_lag]
        return self.placement.route_read(consumer_region, candidates=in_sync)

    def get_online_features(
        self,
        name: str,
        version: int,
        id_columns: list[np.ndarray],
        *,
        consumer_region: Optional[str] = None,
        use_kernel: bool = True,
        max_lag_batches: Optional[int] = None,
    ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Geo-routed online GET.  Returns (values, found, route) where
        ``route`` records the serving region and the modeled latency the
        read paid — the number the geo benchmark contrasts across
        mechanisms."""
        consumer = consumer_region or self.home_region
        serving, ms = self.route_read(consumer, max_lag_batches=max_lag_batches)
        vals, found = self.replicator.stores[serving].lookup(
            name, version, id_columns, now=self.fs.clock(), use_kernel=use_kernel
        )
        self.fs.monitor.system.observe("geo/read_modeled_ms", ms)
        return vals, found, {"region": serving, "modeled_ms": ms}

    # -- failure handling --------------------------------------------------------
    def mark_down(self, region: str) -> None:
        self.placement.mark_down(region)

    def mark_up(self, region: str) -> None:
        self.placement.mark_up(region)

    def failover(self) -> Optional[dict]:
        """Promote the nearest healthy replica when the home region is down:
        placement re-points (regions.py), the replicator replays the
        promoted replica's un-acked suffix — BOTH planes — and the home
        ``FeatureStore`` adopts the promoted stores as its online AND
        offline planes, so materialization and training reads resume
        against the new primary without offline/online skew.  The dead
        ex-home leaves the serving set entirely (its stores are gone; a
        LATER failover must never promote it) — if it recovers, ``rejoin``
        re-admits it via delta bootstrap.  Returns promotion info, or None
        when the home region is healthy."""
        old_home = self.home_region
        new_home = self.placement.failover()
        if new_home is None:
            return None
        replay = self.replicator.promote(new_home)
        self.placement.remove_replica(old_home)
        promoted = self.replicator.stores[new_home]
        self.fs.online = promoted
        self.fs.materializer.online = promoted
        promoted_offline = self.replicator.offline_stores.get(new_home)
        if promoted_offline is not None:
            self.fs.offline = promoted_offline
            self.fs.materializer.offline = promoted_offline
        return {"promoted": new_home, **replay}


# Imported at the BOTTOM: wire.py needs ReplicatedBatch (and the compression
# default) from this module, so importing it any earlier would be circular.
# By the time any GeoReplicator method dereferences `wire`, both modules are
# fully initialized regardless of which one a caller imported first.
from repro.core import wire  # noqa: E402
