"""Async geo-replication of online merge batches (paper §2.1, §4.1.2 road map).

The paper's implemented mechanism keeps an asset in its creation region and
pays WAN latency on every remote read; its road-map mechanism replicates the
asset into consumer regions so reads are local.  This module is that road-map
mechanism made concrete for the online store, built on the shipping unit PR 2
created: every ``OnlineStore.merge`` already reduces a materialization frame
to the winning writes it actually applied (encoded key, winning event_ts,
feature row, one shared creation_ts) and reports them in its stats.

Log / cursor / replay protocol
------------------------------
``ReplicationLog`` is a bounded, totally-ordered sequence of those reduced
batches, appended by a listener on the home store's ``merge_listeners``.
Each replica owns a CURSOR: the lowest sequence number it has not yet
acknowledged.  The async applier (``GeoReplicator.drain``) ships pending
batches over the modeled WAN link and applies them to the replica store via
``OnlineStore.merge_reduced`` — the same Algorithm-2 engines the home store
runs.  Acknowledgements may arrive out of order (``apply_batch``); the
cursor only advances over the contiguous acknowledged prefix, so lag
accounting never under-reports.  ``truncate`` drops exactly the prefix below
EVERY cursor — an un-acked batch is never dropped; when the log is full and
no prefix is fully acknowledged, ``append`` raises ``ReplicationLogFull``
(backpressure) instead of losing data.  The PUBLISHER must never lose a
batch either (the home store has already applied it when the listener
fires), so under backpressure the replicator first degrades to a
synchronous drain of every healthy replica, and only if a dead replica
still pins the tail does it force-append past capacity — bounded growth
plus a monitor counter, never divergence.

Everything relies on Algorithm 2 being an idempotent, commutative,
latest-wins join on (event_ts, creation_ts): re-delivering a batch is a
no-op, reordered batches converge to the same store state, and replaying a
suffix that partially overlaps already-applied writes is safe.  That is what
makes fail-over exactly-once in EFFECT with at-least-once DELIVERY:
``GeoPlacement.failover`` picks the nearest healthy replica (regions.py),
then ``GeoReplicator.promote`` replays that replica's un-acked suffix,
leaving its store byte-identical to the home store's pre-failure state.

``GeoFeatureStore`` is the read/write router on top: writes (materialization
ticks, backfills) go to the home region's ``FeatureStore``; online reads are
served by the nearest IN-SYNC replica (replication lag at most
``max_lag_batches``), falling back to the home store; per-replica lag /
staleness land in the health monitor.  Geo-fenced home regions refuse
replication (``ComplianceError``, §4.1.2) exactly as placement does.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.featurestore import FeatureStore
from repro.core.offline_store import CREATION_TS, EVENT_TS
from repro.core.online_store import OnlineStore
from repro.core.regions import GeoTopology, RegionDownError, ReplicationPolicy

__all__ = [
    "GeoFeatureStore",
    "GeoReplicator",
    "ReplicatedBatch",
    "ReplicationLog",
    "ReplicationLogFull",
]


class ReplicationLogFull(RuntimeError):
    """The log hit capacity and no fully-acknowledged prefix can be
    truncated — backpressure instead of dropping un-acked batches."""


@dataclasses.dataclass(frozen=True)
class ReplicatedBatch:
    """One reduced merge batch: the winning writes a single home-store merge
    applied, in (part, slot) order as the home store reported them."""

    seq: int
    table: tuple[str, int]
    creation_ts: int
    keys: np.ndarray  # (G,) int64 encoded entity keys
    event_ts: np.ndarray  # (G,) int64 winning event_ts per key
    values: np.ndarray  # (G, D) float32 winning feature rows

    @property
    def rows(self) -> int:
        return len(self.keys)

    @property
    def nbytes(self) -> int:
        return self.keys.nbytes + self.event_ts.nbytes + self.values.nbytes


class ReplicationLog:
    """Bounded sequence of reduced batches + one cursor per replica.

    A cursor is the lowest un-acknowledged sequence number; acks may land
    out of order, and the cursor advances only over the contiguous prefix.
    """

    def __init__(self, capacity: int = 1024) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.next_seq = 0
        self.cursors: dict[str, int] = {}
        self._batches: deque[ReplicatedBatch] = deque()
        self._acked_ahead: dict[str, set[int]] = {}

    def __len__(self) -> int:
        return len(self._batches)

    def register_replica(self, name: str, from_seq: Optional[int] = None) -> int:
        """Start tracking a replica.  By default its cursor starts at the
        current head — the caller is responsible for snapshot-bootstrapping
        state appended before registration."""
        cursor = self.next_seq if from_seq is None else from_seq
        self.cursors[name] = cursor
        self._acked_ahead[name] = set()
        return cursor

    def drop_replica(self, name: str) -> None:
        self.cursors.pop(name, None)
        self._acked_ahead.pop(name, None)

    def pending_count(self, replica: str) -> int:
        """O(1) un-acked batch count — the serving path's in-sync gate."""
        ahead = len(self._acked_ahead[replica])
        return self.next_seq - self.cursors[replica] - ahead

    def append(
        self,
        table: tuple[str, int],
        creation_ts: int,
        keys: np.ndarray,
        event_ts: np.ndarray,
        values: np.ndarray,
        *,
        force: bool = False,
    ) -> ReplicatedBatch:
        """Append one reduced batch; truncates the fully-acked prefix first
        and raises ``ReplicationLogFull`` rather than evicting un-acked
        batches when the log is still at capacity.  ``force=True`` appends
        past capacity instead of raising — for a publisher whose store
        ALREADY applied the batch, losing it is worse than growing the log
        (see GeoReplicator._on_home_merge)."""
        if len(self._batches) >= self.capacity:
            self.truncate()
        if len(self._batches) >= self.capacity and not force:
            slowest = min(self.cursors.values(), default=None)
            msg = f"log at capacity {self.capacity}; slowest cursor {slowest}"
            raise ReplicationLogFull(msg)
        batch = ReplicatedBatch(
            seq=self.next_seq,
            table=table,
            creation_ts=int(creation_ts),
            keys=np.asarray(keys, np.int64),
            event_ts=np.asarray(event_ts, np.int64),
            values=np.asarray(values, np.float32),
        )
        self.next_seq += 1
        self._batches.append(batch)
        return batch

    def pending(self, replica: str) -> list[ReplicatedBatch]:
        """Batches the replica has not acknowledged, in sequence order."""
        cursor = self.cursors[replica]
        ahead = self._acked_ahead[replica]
        return [b for b in self._batches if b.seq >= cursor and b.seq not in ahead]

    def ack(self, replica: str, seq: int) -> None:
        """Acknowledge one batch; the cursor advances over the contiguous
        acknowledged prefix only, so out-of-order acks never hide lag."""
        if seq >= self.next_seq:
            raise ValueError(f"ack of unknown seq {seq}")
        ahead = self._acked_ahead[replica]
        if seq >= self.cursors[replica]:
            ahead.add(seq)
        while self.cursors[replica] in ahead:
            ahead.remove(self.cursors[replica])
            self.cursors[replica] += 1

    def truncate(self) -> int:
        """Drop the prefix every replica has acknowledged.  Never touches a
        batch at or above any cursor, so un-acked batches survive.  Returns
        the number of batches dropped."""
        floor = min(self.cursors.values(), default=self.next_seq)
        dropped = 0
        while self._batches and self._batches[0].seq < floor:
            self._batches.popleft()
            dropped += 1
        return dropped

    def lag(self, replica: str) -> dict:
        """Un-acked batch/row counts and the oldest pending creation_ts."""
        pend = self.pending(replica)
        return {
            "batches": len(pend),
            "rows": int(sum(b.rows for b in pend)),
            "oldest_pending_creation_ts": (
                min(b.creation_ts for b in pend) if pend else None
            ),
        }


class GeoReplicator:
    """Async applier: drains the home store's replication log into replica
    stores over the modeled WAN, tracks lag, and replays on fail-over."""

    def __init__(
        self,
        home_store: OnlineStore,
        *,
        topology: GeoTopology,
        home_region: str,
        log: Optional[ReplicationLog] = None,
        clock: Optional[Callable[[], int]] = None,
        monitor=None,
    ) -> None:
        self.topology = topology
        self.home_region = home_region
        self.log = log if log is not None else ReplicationLog()
        self.clock = clock or (lambda: 0)
        self.monitor = monitor
        self.stores: dict[str, OnlineStore] = {home_region: home_store}
        self.shipped: dict[str, dict] = {}
        self._specs: dict[tuple[str, int], FeatureSetSpec] = {}
        home_store.merge_listeners.append(self._on_home_merge)

    # -- publish (home side) ------------------------------------------------
    def _on_home_merge(self, spec: FeatureSetSpec, stats: dict) -> None:
        """Home-store merge listener: append the batch's reduced winning
        writes to the log and annotate the stats with the assigned seq.

        The home store has ALREADY applied this batch by the time the
        listener fires, so the append must never lose it: when the log is
        full, backpressure degrades async replication to a synchronous
        drain of every healthy replica (advancing their cursors frees the
        prefix); if an UNHEALTHY replica still pins the tail, the batch is
        force-appended — the log temporarily exceeds capacity (surfaced via
        the ``replication/log_force_appends`` counter) rather than
        diverging the replicas forever."""
        self._specs[spec.key] = spec
        keys = stats.get("touched_keys")
        if keys is None or len(keys) == 0:
            stats["replication_seq"] = None  # pure no-op batch: nothing ships
            return
        payload = (
            spec.key,
            stats["creation_ts"],
            keys,
            stats["touched_event_ts"],
            stats["touched_values"],
        )
        try:
            batch = self.log.append(*payload)
        except ReplicationLogFull:
            for region in self.replica_regions():
                if self.topology.regions[region].healthy:
                    self.drain(region)
            try:
                batch = self.log.append(*payload)
            except ReplicationLogFull:
                batch = self.log.append(*payload, force=True)
                if self.monitor is not None:
                    self.monitor.system.inc("replication/log_force_appends")
        stats["replication_seq"] = batch.seq

    # -- replica membership --------------------------------------------------
    def replica_regions(self) -> list[str]:
        return [r for r in self.stores if r != self.home_region]

    def add_replica(self, region: str, store: OnlineStore) -> None:
        if region in self.stores:
            raise ValueError(f"region {region} already has a store")
        self.stores[region] = store
        self.log.register_replica(region)
        self.shipped[region] = {"batches": 0, "rows": 0, "bytes": 0, "ms": 0.0}

    def bootstrap_snapshot(self, region: str, spec: FeatureSetSpec) -> int:
        """Copy one table's CURRENT home state into a new replica — the
        §4.5.5-style bootstrap for replicas added after data exists.  The
        dump is replayed as reduced batches grouped by creation_ts (a
        ``merge_reduced`` batch shares one creation_ts); overlap with
        batches already in the log is safe by idempotence."""
        home = self.stores[self.home_region]
        store = self.stores[region]
        dump = home.dump_all(spec.name, spec.version)
        if len(dump) == 0:
            store.register(spec)
            return 0
        keys = dump["__key__"]
        event_ts = dump[EVENT_TS]
        creation_ts = dump[CREATION_TS]
        values = dump.column_stack([f.name for f in spec.features], np.float32)
        for cr in np.unique(creation_ts):
            m = creation_ts == cr
            store.merge_reduced(spec, keys[m], event_ts[m], values[m], int(cr))
        return len(keys)

    # -- apply (replica side) -------------------------------------------------
    def apply_batch(self, region: str, batch: ReplicatedBatch) -> dict:
        """Ship + apply ONE batch to a replica and acknowledge it.  Exposed
        so tests can drive out-of-order delivery; ``drain`` is the in-order
        fast path."""
        spec = self._specs[batch.table]
        stats = self.stores[region].merge_reduced(
            spec, batch.keys, batch.event_ts, batch.values, batch.creation_ts
        )
        self.log.ack(region, batch.seq)
        ship = self.shipped[region]
        ship["batches"] += 1
        ship["rows"] += batch.rows
        ship["bytes"] += batch.nbytes
        ship["ms"] += self.topology.transfer_ms(self.home_region, region, batch.nbytes)
        if self.monitor is not None:
            self.monitor.record_replication_ship(batch.nbytes, batch.rows)
        return stats

    def drain(
        self, region: Optional[str] = None, max_batches: Optional[int] = None
    ) -> dict:
        """Apply pending batches in sequence order — all replicas or one.
        Returns {region: {"applied_batches", "applied_rows"}}."""
        regions = [region] if region is not None else self.replica_regions()
        out: dict[str, dict] = {}
        for r in regions:
            pend = self.log.pending(r)
            if max_batches is not None:
                pend = pend[:max_batches]
            rows = 0
            for batch in pend:
                self.apply_batch(r, batch)
                rows += batch.rows
            out[r] = {"applied_batches": len(pend), "applied_rows": rows}
            self._record_lag(r)
        self.log.truncate()
        return out

    # -- lag accounting --------------------------------------------------------
    def lag_batches(self, region: str) -> int:
        """O(1) un-acked batch count — cheap enough for the read hot path
        (the full ``lag`` scans the log for rows/staleness; monitoring
        cadence only)."""
        if region == self.home_region:
            return 0
        return self.log.pending_count(region)

    def lag(self, region: str) -> dict:
        """Replication lag of one region: un-acked batches/rows plus
        staleness in clock units (0 when fully caught up).  The home region
        is by definition in sync."""
        if region == self.home_region:
            return {"batches": 0, "rows": 0, "staleness_ms": 0}
        raw = self.log.lag(region)
        oldest = raw.pop("oldest_pending_creation_ts")
        raw["staleness_ms"] = (
            max(0, int(self.clock()) - oldest) if oldest is not None else 0
        )
        return raw

    def _record_lag(self, region: str) -> None:
        if self.monitor is not None:
            self.monitor.record_replication_lag(region, **self.lag(region))

    # -- fail-over replay -------------------------------------------------------
    def promote(self, region: str) -> dict:
        """Data-plane half of fail-over: replay the promoted replica's
        un-acked log suffix into its store (Algorithm-2 idempotence makes
        any overlap with already-applied batches a no-op), then make it the
        new home — its merges now feed the log for the remaining replicas,
        whose cursors carry over untouched."""
        if region == self.home_region:
            return {"replayed_batches": 0, "replayed_rows": 0}
        if region not in self.stores:
            raise RegionDownError(f"no replica store in {region}")
        replay = self.drain(region)[region]
        old_home = self.stores[self.home_region]
        try:
            old_home.merge_listeners.remove(self._on_home_merge)
        except ValueError:
            pass
        del self.stores[self.home_region]
        self.log.drop_replica(region)
        self.shipped.pop(region, None)
        self.home_region = region
        self.stores[region].merge_listeners.append(self._on_home_merge)
        return {
            "replayed_batches": replay["applied_batches"],
            "replayed_rows": replay["applied_rows"],
        }


class GeoFeatureStore:
    """Read/write router over a home ``FeatureStore`` plus geo-replicated
    online serving replicas.

    Writes (materialization ticks, backfills, direct merges) always land in
    the home region; a listener streams every online merge's reduced batch
    into the replication log.  Online reads route to the nearest IN-SYNC
    region (lag <= ``max_lag_batches``), preferring the consumer's own
    region — the paper's local-read latency win.  ``failover`` composes the
    placement decision (nearest healthy replica) with the log replay that
    makes the promoted store byte-identical to the lost home.
    """

    def __init__(
        self,
        name: str,
        *,
        topology: GeoTopology,
        home_region: str,
        replica_regions: tuple[str, ...] = (),
        max_lag_batches: int = 0,
        log_capacity: int = 1024,
        auto_drain: bool = False,
        **fs_kwargs,
    ) -> None:
        self.fs = FeatureStore(
            name,
            region=home_region,
            topology=topology,
            replication=ReplicationPolicy.GEO_REPLICATED,
            **fs_kwargs,
        )
        self.topology = topology
        self.placement = self.fs.geo
        self.max_lag_batches = max_lag_batches
        self.auto_drain = auto_drain
        self.log = ReplicationLog(capacity=log_capacity)
        self.replicator = GeoReplicator(
            self.fs.online,
            topology=topology,
            home_region=home_region,
            log=self.log,
            clock=self.fs.clock,
            monitor=self.fs.monitor,
        )
        self.fs.attach_replication(self.replicator)
        for region in replica_regions:
            self.add_replica(region)

    @property
    def home_region(self) -> str:
        return self.replicator.home_region

    def __getattr__(self, name: str):
        # registry/asset/materialization surface delegates to the home store
        return getattr(self.fs, name)

    # -- membership ----------------------------------------------------------
    def add_replica(self, region: str) -> OnlineStore:
        """Create an online serving replica in ``region``: compliance-check
        placement, clone the home store's configuration, snapshot-bootstrap
        every online table, and start cursor-tracking new batches."""
        self.placement.add_replica(region)  # ComplianceError when geo-fenced
        home = self.fs.online
        store = OnlineStore(
            num_partitions=home.num_partitions,
            initial_capacity=home.initial_capacity,
            interpret=home.interpret,
            merge_engine=home.merge_engine,
        )
        self.replicator.add_replica(region, store)
        for n, v in self.fs.registry.list_feature_sets():
            spec = self.fs.registry.get_feature_set(n, v)
            if spec.materialization.online_enabled and home.has(n, v):
                self.replicator.bootstrap_snapshot(region, spec)
        return store

    # -- asset management ------------------------------------------------------
    def create_feature_set(self, spec: FeatureSetSpec) -> FeatureSetSpec:
        """Register with the home store, then pre-register the (empty) table
        on every replica so a relaxed-staleness read can serve before the
        first batch arrives."""
        spec = self.fs.create_feature_set(spec)
        if spec.materialization.online_enabled:
            for region in self.replicator.replica_regions():
                self.replicator.stores[region].register(spec)
        return spec

    # -- writes (home region) -------------------------------------------------
    def tick(self, now: Optional[int] = None) -> dict[str, int]:
        stats = self.fs.tick(now)
        if self.auto_drain:
            self.drain()
        return stats

    def backfill(self, name: str, version: int, start: int, end: int) -> dict:
        stats = self.fs.backfill(name, version, start, end)
        if self.auto_drain:
            self.drain()
        return stats

    def drain(self, region: Optional[str] = None) -> dict:
        return self.replicator.drain(region)

    def lag(self, region: str) -> dict:
        return self.replicator.lag(region)

    # -- reads (nearest in-sync region) ----------------------------------------
    def route_read(
        self, consumer_region: str, *, max_lag_batches: Optional[int] = None
    ) -> tuple[str, float]:
        """Pick the serving region for ``consumer_region``: the consumer's
        own region when it hosts an in-sync healthy store, else the
        nearest in-sync healthy one (home is always in sync).  The sync
        gate is an O(1) cursor-distance check; nearest-healthy selection
        and read-log bookkeeping delegate to placement.  Returns (region,
        modeled one-way latency ms)."""
        max_lag = self.max_lag_batches if max_lag_batches is None else max_lag_batches
        rep = self.replicator
        in_sync = [r for r in rep.stores if rep.lag_batches(r) <= max_lag]
        return self.placement.route_read(consumer_region, candidates=in_sync)

    def get_online_features(
        self,
        name: str,
        version: int,
        id_columns: list[np.ndarray],
        *,
        consumer_region: Optional[str] = None,
        use_kernel: bool = True,
        max_lag_batches: Optional[int] = None,
    ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Geo-routed online GET.  Returns (values, found, route) where
        ``route`` records the serving region and the modeled latency the
        read paid — the number the geo benchmark contrasts across
        mechanisms."""
        consumer = consumer_region or self.home_region
        serving, ms = self.route_read(consumer, max_lag_batches=max_lag_batches)
        vals, found = self.replicator.stores[serving].lookup(
            name, version, id_columns, now=self.fs.clock(), use_kernel=use_kernel
        )
        self.fs.monitor.system.observe("geo/read_modeled_ms", ms)
        return vals, found, {"region": serving, "modeled_ms": ms}

    # -- failure handling --------------------------------------------------------
    def mark_down(self, region: str) -> None:
        self.placement.mark_down(region)

    def mark_up(self, region: str) -> None:
        self.placement.mark_up(region)

    def failover(self) -> Optional[dict]:
        """Promote the nearest healthy replica when the home region is down:
        placement re-points (regions.py), the replicator replays the
        promoted replica's un-acked suffix, and the home ``FeatureStore``
        adopts the promoted store as its online plane — so materialization
        resumes against the new primary.  The dead ex-home leaves the
        serving set entirely (its store is gone; a LATER failover must
        never promote it) — if it recovers, ``add_replica`` re-admits it
        via snapshot bootstrap.  Returns promotion info, or None when the
        home region is healthy."""
        old_home = self.home_region
        new_home = self.placement.failover()
        if new_home is None:
            return None
        replay = self.replicator.promote(new_home)
        self.placement.remove_replica(old_home)
        promoted = self.replicator.stores[new_home]
        self.fs.online = promoted
        self.fs.materializer.online = promoted
        return {"promoted": new_home, **replay}
