"""Point-in-time correct offline retrieval (paper §4.4).

Given an observation ("spine") table with entity keys and observation
timestamps ts0, join each requested feature set so that every row receives
the feature value from the NEAREST PAST of ts0 — never the future — while
honouring the feature set's expected source/feature delay:

    eligible records:  event_ts <= ts0 - expected_delay
    chosen record:     max event_ts among eligible (break ties by max
                       creation_ts, matching the §4.5 record ordering)

The search runs on the kernels/pit_join counting-search Pallas kernel over
the offline store's (entity-sorted, time-sorted) history.  Timestamps are
rebased host-side into the int32 domain the kernel compares natively; spans
that cannot be rebased fall back to the jnp oracle (see kernels/pit_join).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.keys import encode_keys
from repro.core.offline_store import CREATION_TS, EVENT_TS, OfflineStore
from repro.core.table import Table
from repro.kernels.pit_join import ops as pit_ops
from repro.kernels.pit_join import ref as pit_ref

__all__ = ["pit_join_feature_set", "get_offline_features"]

_I32 = 2**31 - 1


@dataclasses.dataclass
class PitResult:
    values: dict[str, np.ndarray]  # feature name -> (B,) values
    found: np.ndarray  # (B,) bool
    event_ts: np.ndarray  # (B,) int64 (0 where not found)


def _prepare_history(history: Table) -> tuple[Table, np.ndarray, np.ndarray]:
    """Sort history by (key, event_ts, creation_ts); return per-row sorted
    table + unique keys + segment offsets (len = n_unique + 1)."""
    order = np.lexsort((history[CREATION_TS], history[EVENT_TS], history["__key__"]))
    h = history.take(order)
    keys = h["__key__"]
    uniq, first = np.unique(keys, return_index=True)
    offsets = np.concatenate([first, [len(keys)]])
    return h, uniq, offsets


def pit_join_feature_set(
    spine_keys: list[np.ndarray],
    spine_ts: np.ndarray,
    spec: FeatureSetSpec,
    history: Table,
    *,
    interpret: bool = True,
    use_kernel: bool = True,
) -> PitResult:
    """Join one feature set's history onto the spine, point-in-time correct."""
    b = len(spine_ts)
    spine_ts = np.asarray(spine_ts, dtype=np.int64)
    ids = encode_keys(spine_keys)
    d = len(spec.features)
    empty = PitResult(
        {f.name: np.zeros(b, np.float32) for f in spec.features},
        np.zeros(b, bool),
        np.zeros(b, np.int64),
    )
    if len(history) == 0 or b == 0:
        return empty

    h, uniq, offsets = _prepare_history(history)
    table_ev = h[EVENT_TS].astype(np.int64)

    # Route each spine row to its entity segment.
    seg = np.searchsorted(uniq, ids)
    seg_clipped = np.clip(seg, 0, len(uniq) - 1)
    has_entity = (seg < len(uniq)) & (uniq[seg_clipped] == ids)
    q_lo = offsets[seg_clipped]
    q_hi = np.where(has_entity, offsets[seg_clipped + 1], q_lo)  # empty range

    # Leakage guard: only the past of ts0, minus the expected delay.
    q_ts = spine_ts - spec.expected_delay

    # Rebase int64 epoch-ms into the kernel's int32 domain.
    t0 = int(table_ev.min())
    lo_ts = min(t0, int(q_ts.min()))
    span_ok = int(table_ev.max()) - lo_ts < _I32 and int(q_ts.max()) - lo_ts < _I32
    if use_kernel and span_ok:
        idx, valid = pit_ops.pit_search(
            jnp.asarray((table_ev - lo_ts).astype(np.int32)),
            jnp.asarray(np.maximum(q_ts - lo_ts, -1).astype(np.int32)),
            jnp.asarray(q_lo.astype(np.int32)),
            jnp.asarray(q_hi.astype(np.int32)),
            interpret=interpret,
        )
        idx, valid = np.asarray(idx), np.asarray(valid)
    else:
        idx, valid = pit_ref.pit_search_ref(
            jnp.asarray(table_ev),
            jnp.asarray(q_ts),
            jnp.asarray(q_lo),
            jnp.asarray(q_hi),
        )
        idx, valid = np.asarray(idx), np.asarray(valid)
    # Queries whose ts0 - delay predates the rebase floor can match nothing.
    valid = valid & has_entity

    safe_idx = np.where(valid, idx, 0)
    values = {
        f.name: np.where(valid, h[f.name][safe_idx], 0).astype(np.float32)
        for f in spec.features
    }
    event_out = np.where(valid, table_ev[safe_idx], 0)
    return PitResult(values, valid, event_out)


def get_offline_features(
    store: OfflineStore,
    spine: Table,
    specs: Sequence[FeatureSetSpec],
    *,
    spine_ts_col: str = "ts",
    interpret: bool = True,
    use_kernel: bool = True,
) -> Table:
    """Spine join across many feature sets (the training-data path).

    Output columns: spine columns + ``<fs>:v<n>:<feature>`` per feature +
    ``<fs>:v<n>:__found__`` validity flags (the §4.3 "no data vs. not
    materialized" distinction is surfaced by the caller via the scheduler's
    interval state; here absence of any past record reads as not-found).
    """
    out = dict(spine.to_dict())
    for spec in specs:
        history = store.read(spec.name, spec.version)
        res = pit_join_feature_set(
            [spine[c] for c in spec.index_columns],
            spine[spine_ts_col],
            spec,
            history,
            interpret=interpret,
            use_kernel=use_kernel,
        )
        prefix = f"{spec.name}:v{spec.version}"
        for fname, vals in res.values.items():
            out[f"{prefix}:{fname}"] = vals
        out[f"{prefix}:__found__"] = res.found
    return Table(out)
