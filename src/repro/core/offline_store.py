"""Offline store (paper §3.1.4, §4.5) — the ADLS/Delta analogue.

Semantics reproduced exactly:
  * records are keyed by IDs + event_timestamp + creation_timestamp;
  * the store keeps EVERY record per ID over time (append-only history);
  * Algorithm 2, offline branch: insert iff the full key does not exist,
    otherwise no-op (idempotent merges make job retries safe — the basis of
    the §4.5.4 eventual-consistency argument);
  * storage partitioning: rows are hash-partitioned by entity key into
    ``num_shards`` shards (the unit of parallel/distributed reads) and each
    shard tracks time-partition statistics (the Delta-table analogue).

Write-path layout (the vectorized merge engine):
  * each shard is a CHUNK LIST — one columnar chunk appended per merge —
    with lazy compaction once the list passes ``compact_threshold``, so a
    merge costs O(batch) (+ amortized compaction), never the
    O(history) concat-per-merge of a single monolithic table;
  * full-key idempotence is enforced against a per-shard SORTED int64 index
    of splitmix-mixed (key, event_ts, creation_ts) record keys
    (``keys.encode_full_keys`` — the same ~2^-64 collision trade the entity
    key codec documents): in-batch dedup via ``np.unique`` (first occurrence
    wins, as in the sequential loop) and store dedup via a C-speed
    ``np.searchsorted`` membership — no Python ``set[tuple]`` bookkeeping,
    no structured-dtype comparisons in the hot path;
  * the per-row reference loop is retained as ``engine="loop"`` for parity
    tests and the old-style benchmark baseline.

Geo-replication surface (core/replication.py consumes all three):
  * ``merge_listeners`` fire after every non-empty merge with the rows the
    merge actually INSERTED (post-dedup, arrival order) — the offline
    plane's shipping unit, mirroring ``OnlineStore.merge``;
  * ``apply_chunks`` is the replica-side apply: the same full-key dedup the
    home merge ran, so re-delivered or bootstrap-overlapping chunks are
    no-ops and a replica converges chunk-set-identical to the home;
  * ``export_chunks`` streams the full history as bounded record-schema
    chunks — the delta-bootstrap source that never materializes a second
    full copy in flight.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.keys import encode_full_keys, encode_keys
from repro.core.merge_engine import merge_sorted
from repro.core.table import Table, concat_tables
from repro.kernels.online_lookup.ops import partition_of

__all__ = ["OfflineStore", "EVENT_TS", "CREATION_TS"]

EVENT_TS = "event_ts"
CREATION_TS = "creation_ts"


def _record_schema(spec: FeatureSetSpec) -> dict[str, np.dtype]:
    schema: dict[str, np.dtype] = {"__key__": np.dtype(np.int64)}
    for k in spec.index_columns:
        schema[k] = np.dtype(np.int64)
    schema[EVENT_TS] = np.dtype(np.int64)
    schema[CREATION_TS] = np.dtype(np.int64)
    for f in spec.features:
        schema[f.name] = f.np_dtype()
    return schema


def _arrival_order(kept_per_shard: list[np.ndarray]) -> np.ndarray:
    """Union of per-shard kept-row indices, back in batch arrival order."""
    if not kept_per_shard:
        return np.empty(0, np.int64)
    return np.sort(np.concatenate(kept_per_shard)).astype(np.int64, copy=False)


def _gather_cols(spec: FeatureSetSpec, source, kept_rows: np.ndarray) -> dict:
    """Index columns (as int64) + feature columns (native dtype) sliced to
    the kept rows.  ``source`` is anything column-indexable — a merge frame
    (``Table``) or a replicated batch's columns dict."""
    cols: dict[str, np.ndarray] = {
        c: np.asarray(source[c], np.int64)[kept_rows] for c in spec.index_columns
    }
    for f in spec.features:
        cols[f.name] = np.asarray(source[f.name], f.np_dtype())[kept_rows]
    return cols


@dataclasses.dataclass
class _Shard:
    chunks: list[Table]
    # sorted int64 full-key hashes for O(log) idempotent-merge checks
    index: np.ndarray = dataclasses.field(default_factory=lambda: np.empty(0, np.int64))
    num_rows: int = 0
    # loop-engine membership set, maintained incrementally so the reference
    # baseline pays seed-equivalent O(batch) per merge (invalidated by
    # vector merges)
    key_set: Optional[set] = None


class OfflineStore:
    """Append-only, history-complete feature record store."""

    def __init__(
        self,
        num_shards: int = 4,
        time_partition: int = 86_400_000,
        *,
        merge_engine: str = "vector",
        compact_threshold: int = 64,
    ):
        self.num_shards = num_shards
        self.time_partition = time_partition
        self.merge_engine = self._normalize_engine(merge_engine)
        self.compact_threshold = compact_threshold
        self._shards: dict[tuple[str, int], list[_Shard]] = {}
        self._specs: dict[tuple[str, int], FeatureSetSpec] = {}
        self.rows_merged = 0
        self.rows_deduped = 0
        # fire after every non-empty merge with (spec, stats); stats carry
        # the inserted rows (the offline replication shipping unit)
        self.merge_listeners: list = []

    @staticmethod
    def _normalize_engine(engine: str) -> str:
        # "kernel" is an online-store notion (device-side compare-and-update);
        # the offline equivalent is the vector path, so accept it here rather
        # than making every caller re-implement the mapping.
        if engine == "kernel":
            return "vector"
        if engine not in ("vector", "loop"):
            raise ValueError(f"unknown merge engine {engine!r}")
        return engine

    # -- lifecycle ----------------------------------------------------------
    def register(self, spec: FeatureSetSpec) -> None:
        key = spec.key
        if key in self._shards:
            return
        schema = _record_schema(spec)
        self._shards[key] = [
            _Shard([Table.empty(schema)]) for _ in range(self.num_shards)
        ]
        self._specs[key] = spec

    def has(self, name: str, version: int) -> bool:
        return (name, version) in self._shards

    # -- Algorithm 2, offline branch -----------------------------------------
    def merge(
        self,
        spec: FeatureSetSpec,
        frame: Table,
        creation_ts: int,
        *,
        engine: Optional[str] = None,
    ) -> int:
        """Merge a materialization-job output frame.  ``frame`` carries index
        columns + event timestamp + features; the store stamps creation_ts
        (the materialization time, always > event_ts).  Returns #rows inserted.
        """
        return self.merge_with_stats(spec, frame, creation_ts, engine=engine)[
            "inserted"
        ]

    def merge_with_stats(
        self,
        spec: FeatureSetSpec,
        frame: Table,
        creation_ts: int,
        *,
        engine: Optional[str] = None,
    ) -> dict:
        """``merge`` returning the full per-batch stats dict.  When (and
        only when) ``merge_listeners`` are subscribed, the stats also carry
        the inserted rows themselves (``inserted_keys/inserted_event_ts/
        inserted_columns``, arrival order) — the reduced form
        geo-replication ships — and the listeners fire with (spec, stats),
        mirroring ``OnlineStore.merge``; a replication listener annotates
        ``stats["replication_seq"]``."""
        engine = self._normalize_engine(engine) if engine else self.merge_engine
        self.register(spec)
        n = len(frame)
        if n == 0:
            return {
                "engine": engine,
                "creation_ts": int(creation_ts),
                "inserted": 0,
                "deduped": 0,
            }
        ids = encode_keys([frame[c] for c in spec.index_columns])
        event_ts = frame[spec.timestamp_col].astype(np.int64)
        if (creation_ts <= event_ts).any():
            raise ValueError(
                "creation_timestamp must exceed every event_timestamp (§4.5.1)"
            )
        if engine == "loop":
            inserted, kept = self._merge_loop(spec, frame, ids, event_ts, creation_ts)
        else:
            inserted, kept = self._merge_vector(spec, frame, ids, event_ts, creation_ts)
        self.rows_merged += inserted
        stats = {
            "engine": engine,
            "creation_ts": int(creation_ts),
            "inserted": inserted,
            "deduped": n - inserted,
        }
        if self.merge_listeners:
            # the inserted-rows payload (a second gather of every column) is
            # only built when a subscriber will ship it — a store without
            # replication attached pays nothing beyond the merge itself
            stats["inserted_keys"] = ids[kept]
            stats["inserted_event_ts"] = event_ts[kept]
            stats["inserted_columns"] = _gather_cols(spec, frame, kept)
            for cb in self.merge_listeners:
                cb(spec, stats)
        return stats

    def _merge_vector(
        self,
        spec: FeatureSetSpec,
        frame: Table,
        ids: np.ndarray,
        event_ts: np.ndarray,
        creation_ts: int,
    ) -> tuple[int, np.ndarray]:
        h = encode_full_keys(ids, event_ts, creation_ts)
        cr_rows = np.full(len(ids), creation_ts, np.int64)
        return self._insert_unique(
            spec, ids, event_ts, cr_rows, h,
            lambda kept_rows: _gather_cols(spec, frame, kept_rows),
        )

    def _insert_unique(
        self,
        spec: FeatureSetSpec,
        ids: np.ndarray,
        event_ts: np.ndarray,
        cr_rows: np.ndarray,
        h: np.ndarray,
        row_cols,
    ) -> tuple[int, np.ndarray]:
        """The vectorized insert-if-absent core shared by home merges
        (``_merge_vector``) and replica applies (``apply_chunks``), so the
        full-key idempotence invariant lives in exactly one place.

        Full-key hashes make both dedup levels primitive int64 ops: ONE
        global sort of the hashes groups duplicate full keys (equal hash ==
        equal triple up to the documented ~2^-64 collision trade), and
        ``minimum.reduceat`` over each equal-hash run recovers the FIRST
        occurrence — exactly the sequential loop's keep-first rule —
        without needing a (much slower for int64) stable sort.  Everything
        downstream operates on the ~unique keys, and store dedup is a
        sorted-array ``searchsorted`` membership probe per shard.

        ``row_cols(kept_rows)`` materializes the chunk's index + feature
        columns for the surviving rows.  Returns (#inserted, kept row
        indices in batch arrival order)."""
        n = len(ids)
        shard_of = partition_of(ids, self.num_shards)
        order = np.argsort(h)
        hs = h[order]
        run_start = np.empty(n, bool)
        run_start[0] = True
        run_start[1:] = hs[1:] != hs[:-1]
        starts = np.flatnonzero(run_start)
        uh_all = hs[starts]  # ascending, unique
        if len(starts) == n:  # common case: no in-batch duplicates at all
            kept_orig = order
        else:
            kept_orig = np.minimum.reduceat(order, starts)  # first arrival
        ushard = shard_of[kept_orig]
        shard_rows = np.bincount(shard_of, minlength=self.num_shards)
        inserted = 0
        kept_all: list[np.ndarray] = []
        for s in range(self.num_shards):
            if shard_rows[s] == 0:
                continue
            shard = self._shards[spec.key][s]
            shard.key_set = None
            msel = ushard == s
            uh = uh_all[msel]  # sorted subsequence
            k = len(shard.index)
            if k:
                pos = np.searchsorted(shard.index, uh)
                member = (pos < k) & (shard.index[np.minimum(pos, k - 1)] == uh)
            else:
                member = np.zeros(len(uh), bool)
            fresh = uh[~member]
            self.rows_deduped += int(shard_rows[s]) - len(fresh)
            if len(fresh) == 0:
                continue
            # chunk rows go back to ORIGINAL arrival order (loop parity)
            kept_rows = np.sort(kept_orig[msel][~member])
            self._append_rows(
                spec,
                shard,
                ids[kept_rows],
                row_cols(kept_rows),
                event_ts[kept_rows],
                cr_rows[kept_rows],
            )
            # the membership probe's positions double as merge positions
            (shard.index,) = merge_sorted(
                [shard.index], [fresh], pos=pos[~member] if k else None
            )
            inserted += len(fresh)
            kept_all.append(kept_rows)
        return inserted, _arrival_order(kept_all)

    def _merge_loop(
        self,
        spec: FeatureSetSpec,
        frame: Table,
        ids: np.ndarray,
        event_ts: np.ndarray,
        creation_ts: int,
    ) -> tuple[int, np.ndarray]:
        """Retained reference: per-row set-membership dedup (the original
        sequential implementation), ending in the same chunk/index state."""
        h = encode_full_keys(ids, event_ts, creation_ts)
        shard_of = partition_of(ids, self.num_shards)
        inserted = 0
        kept_all: list[np.ndarray] = []
        for s in range(self.num_shards):
            mask = shard_of == s
            if not mask.any():
                continue
            shard = self._shards[spec.key][s]
            keys = shard.key_set
            if keys is None:
                keys = set(shard.index.tolist())
                shard.key_set = keys
            rows = np.flatnonzero(mask)
            keep = np.zeros(len(rows), dtype=bool)
            for i, r in enumerate(rows):
                full = int(h[r])
                if full not in keys:
                    keys.add(full)
                    keep[i] = True
            self.rows_deduped += int((~keep).sum())
            if not keep.any():
                continue
            kept_rows = rows[keep]
            self._append_chunk(
                spec, shard, frame, ids, event_ts, creation_ts, kept_rows
            )
            fresh = np.sort(h[kept_rows])
            shard.index = np.insert(
                shard.index, np.searchsorted(shard.index, fresh), fresh
            )
            inserted += len(kept_rows)
            kept_all.append(kept_rows)
        return inserted, _arrival_order(kept_all)

    def _append_chunk(
        self,
        spec: FeatureSetSpec,
        shard: _Shard,
        frame: Table,
        ids: np.ndarray,
        event_ts: np.ndarray,
        creation_ts: int,
        kept_rows: np.ndarray,
    ) -> None:
        """Loop-engine entry into the shared chunk append."""
        self._append_rows(
            spec,
            shard,
            ids[kept_rows],
            _gather_cols(spec, frame, kept_rows),
            event_ts[kept_rows],
            np.full(len(kept_rows), creation_ts, np.int64),
        )

    def _append_rows(
        self,
        spec: FeatureSetSpec,
        shard: _Shard,
        ids_kept: np.ndarray,
        gathered: dict[str, np.ndarray],
        ev_kept: np.ndarray,
        cr_kept: np.ndarray,
    ) -> None:
        """Append one already-deduped chunk to a shard — the single place
        the record-schema column order and lazy compaction live."""
        cols = {"__key__": ids_kept}
        for c in spec.index_columns:
            cols[c] = gathered[c]
        cols[EVENT_TS] = ev_kept
        cols[CREATION_TS] = cr_kept
        for f in spec.features:
            cols[f.name] = gathered[f.name]
        shard.chunks.append(Table(cols))
        shard.num_rows += len(ids_kept)
        if len(shard.chunks) > self.compact_threshold:
            shard.chunks = [concat_tables(shard.chunks)]

    # -- replication apply / export (core/replication.py offline plane) ------
    def apply_chunks(
        self,
        spec: FeatureSetSpec,
        keys: np.ndarray,
        event_ts: np.ndarray,
        creation_ts,
        columns: dict[str, np.ndarray],
    ) -> dict:
        """Idempotently apply replicated rows (a shipped merge batch or a
        bootstrap chunk) with the SAME full-key dedup ``merge`` enforces.

        ``keys`` are the encoded entity keys (``__key__``); ``columns``
        carries the index columns plus native-dtype feature columns;
        ``creation_ts`` is a scalar (live replication: one merge, one stamp)
        or a per-row array (bootstrap chunks span many merges).  Rows whose
        (key, event_ts, creation_ts) full key is already present are
        no-ops, so re-delivery, replay overlap, and an interrupted-then-
        retried bootstrap all converge to the same chunk set."""
        self.register(spec)
        keys = np.asarray(keys, np.int64)
        event_ts = np.asarray(event_ts, np.int64)
        n = len(keys)
        if n == 0:
            return {"applied": 0, "deduped": 0}
        cr = np.asarray(creation_ts, np.int64)
        cr_rows = (
            np.full(n, int(cr), np.int64) if cr.ndim == 0 else cr.astype(np.int64)
        )
        h = encode_full_keys(keys, event_ts, cr_rows)
        applied, _ = self._insert_unique(
            spec, keys, event_ts, cr_rows, h,
            lambda kept_rows: _gather_cols(spec, columns, kept_rows),
        )
        self.rows_merged += applied
        return {"applied": applied, "deduped": n - applied}

    def export_chunks(self, name: str, version: int, *, max_rows: int = 65_536):
        """Yield the full history as bounded record-schema ``Table`` chunks
        (each carries ``__key__`` + index columns + both timestamps +
        features, at most ``max_rows`` rows) — the delta-bootstrap stream.
        Bounded chunks mean a late replica applies the snapshot piecewise
        and never holds a second full copy in flight."""
        for shard in self._shards[(name, version)]:
            for chunk in shard.chunks:
                m = len(chunk)
                for start in range(0, m, max_rows):
                    yield Table(
                        {
                            k: v[start : start + max_rows]
                            for k, v in chunk.columns.items()
                        }
                    )

    def canonical_history(self, name: str, version: int) -> Table:
        """Full history sorted by (key, event_ts, creation_ts) — the chunk-
        layout-independent canonical form replica-equivalence checks
        compare (same full-key set and values <=> equal tables)."""
        t = self.read(name, version)
        if len(t) == 0:
            return t
        order = np.lexsort((t[CREATION_TS], t[EVENT_TS], t["__key__"]))
        return t.take(order)

    # -- reads ---------------------------------------------------------------
    def read(
        self,
        name: str,
        version: int,
        window: Optional[tuple[int, int]] = None,
        shards: Optional[Iterable[int]] = None,
    ) -> Table:
        """Full history (optionally clipped to an event-ts window / shard set)."""
        shard_list = list(shards) if shards is not None else range(self.num_shards)
        parts = [
            c
            for s in shard_list
            for c in self._shards[(name, version)][s].chunks
        ]
        out = concat_tables(parts)
        if window is not None and len(out):
            ev = out[EVENT_TS]
            out = out.filter((ev >= window[0]) & (ev < window[1]))
        return out

    def latest_per_key(self, name: str, version: int) -> Table:
        """max(tuple(event_ts, creation_ts)) per ID — the §4.5.5
        offline→online bootstrap read."""
        t = self.read(name, version)
        if len(t) == 0:
            return t
        order = np.lexsort((t[CREATION_TS], t[EVENT_TS], t["__key__"]))
        t = t.take(order)
        keys = t["__key__"]
        is_last = np.ones(len(t), dtype=bool)
        is_last[:-1] = keys[:-1] != keys[1:]
        return t.filter(is_last)

    def num_rows(self, name: str, version: int) -> int:
        return sum(s.num_rows for s in self._shards[(name, version)])

    def max_event_ts(self, name: str, version: int) -> Optional[int]:
        t = self.read(name, version)
        return int(t[EVENT_TS].max()) if len(t) else None

    def time_partitions(self, name: str, version: int) -> dict[int, int]:
        """Rows per time partition (Delta-style file statistics)."""
        t = self.read(name, version)
        if len(t) == 0:
            return {}
        part = t[EVENT_TS] // self.time_partition
        uniq, counts = np.unique(part, return_counts=True)
        return {int(u): int(c) for u, c in zip(uniq, counts)}
