"""Offline store (paper §3.1.4, §4.5) — the ADLS/Delta analogue.

Semantics reproduced exactly:
  * records are keyed by IDs + event_timestamp + creation_timestamp;
  * the store keeps EVERY record per ID over time (append-only history);
  * Algorithm 2, offline branch: insert iff the full key does not exist,
    otherwise no-op (idempotent merges make job retries safe — the basis of
    the §4.5.4 eventual-consistency argument);
  * storage partitioning: rows are hash-partitioned by entity key into
    ``num_shards`` shards (the unit of parallel/distributed reads) and each
    shard tracks time-partition statistics (the Delta-table analogue).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.keys import encode_keys
from repro.core.table import Table, concat_tables
from repro.kernels.online_lookup.ops import partition_of

__all__ = ["OfflineStore", "EVENT_TS", "CREATION_TS"]

EVENT_TS = "event_ts"
CREATION_TS = "creation_ts"


def _record_schema(spec: FeatureSetSpec) -> dict[str, np.dtype]:
    schema: dict[str, np.dtype] = {"__key__": np.dtype(np.int64)}
    for k in spec.index_columns:
        schema[k] = np.dtype(np.int64)
    schema[EVENT_TS] = np.dtype(np.int64)
    schema[CREATION_TS] = np.dtype(np.int64)
    for f in spec.features:
        schema[f.name] = f.np_dtype()
    return schema


@dataclasses.dataclass
class _Shard:
    table: Table
    # full-key set for O(1) idempotent-merge checks
    keys: set[tuple[int, int, int]] = dataclasses.field(default_factory=set)


class OfflineStore:
    """Append-only, history-complete feature record store."""

    def __init__(self, num_shards: int = 4, time_partition: int = 86_400_000):
        self.num_shards = num_shards
        self.time_partition = time_partition
        self._shards: dict[tuple[str, int], list[_Shard]] = {}
        self._specs: dict[tuple[str, int], FeatureSetSpec] = {}
        self.rows_merged = 0
        self.rows_deduped = 0

    # -- lifecycle ----------------------------------------------------------
    def register(self, spec: FeatureSetSpec) -> None:
        key = spec.key
        if key in self._shards:
            return
        schema = _record_schema(spec)
        self._shards[key] = [
            _Shard(Table.empty(schema)) for _ in range(self.num_shards)
        ]
        self._specs[key] = spec

    def has(self, name: str, version: int) -> bool:
        return (name, version) in self._shards

    # -- Algorithm 2, offline branch -----------------------------------------
    def merge(self, spec: FeatureSetSpec, frame: Table, creation_ts: int) -> int:
        """Merge a materialization-job output frame.  ``frame`` carries index
        columns + event timestamp + features; the store stamps creation_ts
        (the materialization time, always > event_ts).  Returns #rows inserted.
        """
        self.register(spec)
        n = len(frame)
        if n == 0:
            return 0
        ids = encode_keys([frame[c] for c in spec.index_columns])
        event_ts = frame[spec.timestamp_col].astype(np.int64)
        if (creation_ts <= event_ts).any():
            raise ValueError(
                "creation_timestamp must exceed every event_timestamp (§4.5.1)"
            )
        shard_of = partition_of(ids, self.num_shards)
        inserted = 0
        for s in range(self.num_shards):
            mask = shard_of == s
            if not mask.any():
                continue
            shard = self._shards[spec.key][s]
            sub_ids = ids[mask]
            sub_ev = event_ts[mask]
            keep = np.zeros(mask.sum(), dtype=bool)
            for i, (k, ev) in enumerate(zip(sub_ids, sub_ev)):
                full = (int(k), int(ev), creation_ts)
                if full not in shard.keys:
                    shard.keys.add(full)
                    keep[i] = True
            self.rows_deduped += int((~keep).sum())
            if not keep.any():
                continue
            sub = frame.filter(mask).filter(keep)
            cols = {"__key__": sub_ids[keep]}
            for c in spec.index_columns:
                cols[c] = sub[c].astype(np.int64)
            cols[EVENT_TS] = sub[spec.timestamp_col].astype(np.int64)
            cols[CREATION_TS] = np.full(len(sub), creation_ts, np.int64)
            for f in spec.features:
                cols[f.name] = sub[f.name].astype(f.np_dtype())
            shard.table = concat_tables([shard.table, Table(cols)])
            inserted += len(sub)
        self.rows_merged += inserted
        return inserted

    # -- reads ---------------------------------------------------------------
    def read(
        self,
        name: str,
        version: int,
        window: Optional[tuple[int, int]] = None,
        shards: Optional[Iterable[int]] = None,
    ) -> Table:
        """Full history (optionally clipped to an event-ts window / shard set)."""
        shard_list = list(shards) if shards is not None else range(self.num_shards)
        parts = [self._shards[(name, version)][s].table for s in shard_list]
        out = concat_tables(parts)
        if window is not None and len(out):
            ev = out[EVENT_TS]
            out = out.filter((ev >= window[0]) & (ev < window[1]))
        return out

    def latest_per_key(self, name: str, version: int) -> Table:
        """max(tuple(event_ts, creation_ts)) per ID — the §4.5.5
        offline→online bootstrap read."""
        t = self.read(name, version)
        if len(t) == 0:
            return t
        order = np.lexsort((t[CREATION_TS], t[EVENT_TS], t["__key__"]))
        t = t.take(order)
        keys = t["__key__"]
        is_last = np.ones(len(t), dtype=bool)
        is_last[:-1] = keys[:-1] != keys[1:]
        return t.filter(is_last)

    def num_rows(self, name: str, version: int) -> int:
        return sum(len(s.table) for s in self._shards[(name, version)])

    def max_event_ts(self, name: str, version: int) -> Optional[int]:
        t = self.read(name, version)
        return int(t[EVENT_TS].max()) if len(t) else None

    def time_partitions(self, name: str, version: int) -> dict[int, int]:
        """Rows per time partition (Delta-style file statistics)."""
        t = self.read(name, version)
        if len(t) == 0:
            return {}
        part = t[EVENT_TS] // self.time_partition
        uniq, counts = np.unique(part, return_counts=True)
        return {int(u): int(c) for u, c in zip(uniq, counts)}
