"""The managed feature store facade (paper §2.1 functional surface).

Wires every subsystem together behind the operations the paper lists:
feature store management, asset management, feature engineering (scheduled +
backfill materialization, offline PIT retrieval, online retrieval),
monitoring/lineage, and geo-distributed access.  This is also the object the
training/serving launchers consume as their data plane.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.assets import Entity, FeatureSetSpec
from repro.core.consistency import (
    bootstrap_offline_to_online,
    bootstrap_online_to_offline,
    check_consistency,
)
from repro.core.lineage import LineageGraph, ModelNode
from repro.core.materializer import FaultInjector, Materializer
from repro.core.monitoring import HealthMonitor
from repro.core.offline_store import OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.pit import get_offline_features
from repro.core.registry import AssetRegistry
from repro.core.regions import (
    GeoPlacement,
    GeoTopology,
    Region,
    ReplicationPolicy,
)
from repro.core.scheduler import Scheduler
from repro.core.serving import ServingConfig, ServingFront
from repro.core.table import Table
from repro.core.transform import FeatureWindow, SourceProtocol

__all__ = ["FeatureStore"]


class FeatureStore:
    def __init__(
        self,
        name: str,
        *,
        region: str = "region-0",
        subscription: str = "sub-0",
        topology: Optional[GeoTopology] = None,
        replication: ReplicationPolicy = ReplicationPolicy.CROSS_REGION_ACCESS,
        clock: Optional[Callable[[], int]] = None,
        offline_shards: int = 4,
        online_partitions: int = 16,
        interpret: bool = True,
        merge_engine: str = "vector",
        serving: Optional[ServingConfig] = None,
    ) -> None:
        self.name = name
        self._now = 0
        self.clock = clock or (lambda: self._now)
        self.registry = AssetRegistry(name, region, subscription)
        self.offline = OfflineStore(
            num_shards=offline_shards, merge_engine=merge_engine
        )
        self.online = OnlineStore(
            num_partitions=online_partitions,
            interpret=interpret,
            merge_engine=merge_engine,
        )
        self.scheduler = Scheduler()
        self.monitor = HealthMonitor()
        self.lineage = LineageGraph()
        self.faults = FaultInjector()
        self.materializer = Materializer(
            self.offline, self.online, clock=self.clock, faults=self.faults
        )
        if topology is None:
            topology = GeoTopology(regions={region: Region(region)})
        self.geo = GeoPlacement(topology, region, replication)
        # every online GET goes through the serving front (core/serving.py).
        # The default config is a pure passthrough (no cache, no admission
        # control) so a plain store keeps exact OnlineStore.lookup semantics;
        # pass a ServingConfig to turn on micro-batching/caching/shedding.
        # Binding through a callable makes failover re-pointing self.online
        # at a promoted replica transparent to the front.
        self.serving = ServingFront(
            lambda: self.online,
            config=serving or ServingConfig(),
            clock=self.clock,
            monitor=self.monitor,
        )
        # set by attach_replication when a GeoReplicator streams this store's
        # online merges cross-region (core/replication.py)
        self.replicator = None
        self._sources: dict[str, SourceProtocol] = {}
        self.interpret = interpret

        from repro.runtime.supervisor import Supervisor  # avoid cycle

        self.supervisor = Supervisor(
            self.scheduler,
            self.materializer,
            self.monitor,
            spec_resolver=self.registry.get_feature_set,
            source_resolver=lambda n: self._sources[n],
        )

    # -- clock (tests drive time explicitly) ---------------------------------
    def advance_clock(self, to: int) -> None:
        self._now = max(self._now, to)

    # -- asset management ------------------------------------------------------
    def register_source(self, source: SourceProtocol) -> None:
        self._sources[source.name] = source

    def create_entity(self, entity: Entity) -> Entity:
        return self.registry.create_entity(entity)

    def create_feature_set(self, spec: FeatureSetSpec) -> FeatureSetSpec:
        spec = self.registry.create_feature_set(spec)
        if spec.source_name not in self._sources:
            raise ValueError(f"register source {spec.source_name!r} first")
        self.offline.register(spec)
        if spec.materialization.online_enabled:
            self.online.register(spec)
        self.scheduler.register_feature_set(
            spec.name,
            spec.version,
            schedule_interval=spec.materialization.schedule_interval,
            partition_window=spec.materialization.partition_window,
        )
        return spec

    # -- feature engineering -----------------------------------------------------
    def tick(self, now: Optional[int] = None) -> dict[str, int]:
        """Advance the schedule clock: generate due incremental jobs and drain
        the queue (recurrent materialization, §2.1)."""
        if now is not None:
            self.advance_clock(now)
        self.scheduler.tick(self.clock())
        stats = self.supervisor.drain()
        self._refresh_staleness()
        return stats

    def backfill(self, name: str, version: int, start: int, end: int) -> dict[str, int]:
        """On-demand backfill materialization (§2.1, §4.3)."""
        self.scheduler.request_backfill(name, version, FeatureWindow(start, end))
        stats = self.supervisor.drain()
        self.scheduler.resume_suspended()
        stats2 = self.supervisor.drain()
        self._refresh_staleness()
        return {k: stats[k] + stats2[k] for k in stats}

    def repair(self, name: str, version: int) -> dict[str, int]:
        """Re-enqueue every unmaterialized gap behind the schedule cursor as
        backfill jobs — the §4.5.2 'manual retry' that guarantees eventual
        consistency even after jobs exhaust their automatic retry budget.
        Fresh jobs get a fresh retry budget; merge idempotence makes any
        overlap with earlier partial progress safe."""
        cursor = self.scheduler.schedule_cursor.get((name, version), 0)
        if cursor <= 0:
            return {"succeeded": 0, "retried": 0, "failed": 0}
        self.scheduler.request_backfill(name, version, FeatureWindow(0, cursor))
        stats = self.supervisor.drain()
        self.scheduler.resume_suspended()
        stats2 = self.supervisor.drain()
        self._refresh_staleness()
        return {k: stats[k] + stats2[k] for k in stats}

    def write_batch(
        self,
        name: str,
        version: int,
        frame: Table,
        *,
        creation_ts: Optional[int] = None,
        region: Optional[str] = None,
    ) -> dict:
        """Direct ingest of one frame outside the scheduler — the
        ``StoreFacade`` write surface.  Merges into every enabled plane
        with one shared creation_ts (offline first, like a materialization
        job).  ``region`` is accepted for facade parity and ignored: a
        single-region store has exactly one place the write can land."""
        spec = self.registry.get_feature_set(name, version)
        creation = int(self.clock()) if creation_ts is None else int(creation_ts)
        out: dict = {"rows": len(frame), "creation_ts": creation}
        if spec.materialization.offline_enabled:
            out["offline"] = self.offline.merge_with_stats(spec, frame, creation)
        if spec.materialization.online_enabled:
            out["online"] = self.online.merge(spec, frame, creation)
        return out

    # -- facade degenerates (StoreFacade surface on a single-region store) ------
    def lag(self, region: str):
        """Replication lag toward ``region`` — all-zeros ``LagStats``
        unless a GeoReplicator is attached."""
        if self.replicator is not None:
            return self.replicator.lag(region)
        from repro.core.replication import LagStats  # import cycle: late

        return LagStats()

    def drain(self, region: Optional[str] = None) -> dict:
        if self.replicator is not None:
            return self.replicator.drain(region)
        return {}

    def failover(self, region: Optional[str] = None):
        """A single-region store has nothing to promote — always None."""
        return None

    def rejoin(self, region: str, **kwargs) -> dict:
        raise ValueError(
            "single-region FeatureStore has no replica set to rejoin; "
            "use GeoFeatureStore/MultiHomeGeoStore"
        )

    def get_offline_features(
        self,
        spine: Table,
        feature_sets: Sequence[tuple[str, int]],
        *,
        spine_ts_col: str = "ts",
        use_kernel: bool = True,
    ) -> Table:
        """Point-in-time correct offline retrieval (§2.1 item 3, §4.4)."""
        specs = [self.registry.get_feature_set(n, v) for n, v in feature_sets]
        return get_offline_features(
            self.offline,
            spine,
            specs,
            spine_ts_col=spine_ts_col,
            interpret=self.interpret,
            use_kernel=use_kernel,
        )

    def get_online_features(
        self,
        name: str,
        version: int,
        id_columns: list[np.ndarray],
        *,
        use_kernel: bool = True,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Low-latency online retrieval (§2.1 item 4), routed through the
        serving front: this GET joins any tickets already queued for the
        table, so concurrent callers share one coalesced store dispatch."""
        import time as _time

        t0 = _time.perf_counter()
        out = self.serving.get(
            name,
            version,
            id_columns,
            now=self.clock(),
            engine="kernel" if use_kernel else "host",
        )
        self.monitor.record_lookup_latency((_time.perf_counter() - t0) * 1e6)
        return out

    # -- consistency & bootstrap ----------------------------------------------------
    def check_consistency(self, name: str, version: int):
        spec = self.registry.get_feature_set(name, version)
        return check_consistency(spec, self.offline, self.online)

    def enable_online(self, name: str, version: int) -> int:
        """Late-enable the online store and bootstrap it from offline (§4.5.5)."""
        spec = self.registry.get_feature_set(name, version)
        spec.materialization.online_enabled = True
        self.online.register(spec)
        return bootstrap_offline_to_online(
            spec, self.offline, self.online, self.clock()
        )

    def enable_offline(self, name: str, version: int) -> int:
        """Late-enable the offline store and bootstrap it from online (§4.5.5)."""
        spec = self.registry.get_feature_set(name, version)
        spec.materialization.offline_enabled = True
        self.offline.register(spec)
        return bootstrap_online_to_offline(spec, self.offline, self.online)

    # -- geo-replication ---------------------------------------------------------
    def attach_replication(self, replicator) -> None:
        """Hook a GeoReplicator up to monitoring: per-replica lag/staleness
        gauges refresh alongside the §2.1 staleness SLA metric.  The
        replicator itself subscribes to ``online.merge_listeners``."""
        self.replicator = replicator

    # -- lineage -----------------------------------------------------------------
    def track_model(
        self, model: ModelNode, feature_sets: Sequence[tuple[str, int]]
    ) -> None:
        refs = []
        for n, v in feature_sets:
            spec = self.registry.get_feature_set(n, v)
            refs.extend(spec.full_feature_names())
        self.lineage.register_model(model, refs)

    # -- internals ------------------------------------------------------------------
    def _refresh_staleness(self) -> None:
        now = self.clock()
        for name, version in self.registry.list_feature_sets():
            ms = self.scheduler.staleness(name, version, now)
            self.monitor.record_staleness(name, version, ms)
        # surface the online store's host<->device traffic ledger so a
        # transfer regression on the serving path shows up in monitoring
        for k, v in self.online.transfer_stats().items():
            self.monitor.system.set_gauge(f"online_store/{k}", v)
        if self.replicator is not None:
            for region in self.replicator.replica_regions():
                self.monitor.record_replication_lag(
                    region, self.replicator.lag(region)
                )

    # -- state checkpoint (resume without data loss) ----------------------------------
    def scheduler_state(self) -> str:
        return self.scheduler.to_json()

    def restore_scheduler(self, payload: str) -> None:
        self.scheduler = Scheduler.from_json(payload)
        self.supervisor.scheduler = self.scheduler
