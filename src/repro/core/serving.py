"""Online serving front (paper §2.1 item 4, §3.1.4): the request plane in
front of ``OnlineStore``.

The paper's online store exists for one reason — low-latency point lookups
at inference time — but a store reference alone is not a serving tier: at
"millions of users" every caller holding the store would pay a full kernel
dispatch per point GET (the ~ms Pallas dispatch dominates the lookup at
request-sized batches).  This module is the §2.1/§3.1.4 serving tier built
from three mechanisms, each mapped to its paper motivation:

  * MICRO-BATCHED GET SCHEDULER (§3.1.4 "low latency and high throughput
    point lookup"): concurrent point GETs enqueue as ``Ticket``s with a
    deadline; the scheduler coalesces every queued ticket for a table into
    ONE deduplicated, lane-bucketed ``lookup_encoded`` dispatch — the kernel
    cost is paid once per coalesced batch instead of once per caller, which
    is what lets the device-resident kernel path compete with the host path
    at serving time (see benchmarks/bench_serving.py for the measured
    crossover).  Results scatter back to each ticket byte-identical to a
    per-request lookup.
  * HOT-KEY CACHE (§2.1 SLA "data staleness"): a CLOCK (second-chance)
    cache over decoded rows.  Coherence is event-driven, not TTL-driven:
    every ``OnlineStore`` merge fires ``merge_listeners`` with the
    touched-slot keys and the front marks those entries STALE (recording
    the superseding merge's creation_ts) instead of dropping them.  Fresh
    entries serve with staleness zero; stale entries are only eligible for
    DEGRADED serves, and only while ``now - stale_since`` stays within the
    configured ``staleness_bound_ms`` — the "explicit staleness bound" is
    therefore enforced per read, not assumed.  Record TTL (§4.5.2) is
    re-checked at serve time from the cached creation_ts, so an expired row
    serves as a miss exactly like the store would.
  * ADMISSION CONTROL / LOAD SHEDDING (§2.1 "serve features ... with high
    availability"): each dispatch updates a service-rate estimate; a new
    request whose projected queue wait exceeds its deadline budget (or that
    would overflow ``max_queue_keys``) is not queued.  It degrades to a
    bounded-staleness cache serve when every missing key is coverable
    within the staleness bound, and is SHED otherwise — bounded staleness
    before unavailability, unavailability before unbounded queues.

Per-stage latency (queue wait, batch assembly, kernel, decode, end-to-end)
is observed into ``HealthMonitor``'s bounded histograms for every request.

Two clocks, deliberately distinct: the DATA clock (``clock``, logical ms —
the same clock the store's TTL and the §2.1 staleness SLA run on) governs
TTL expiry and staleness bounds; the REQUEST clock (wall ms) governs
deadlines, queue waits, and the latency histograms.  Tests inject both.

The front binds its store through a callable, re-resolved on every
operation: a geo failover that re-points ``FeatureStore.online`` at the
promoted replica is picked up on the next request (cache dropped, merge
listener moved) without the caller doing anything.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional, Union

import numpy as np

from repro.core.keys import encode_keys
from repro.core.monitoring import HealthMonitor
from repro.core.online_store import OnlineStore

__all__ = ["HotKeyCache", "ServingConfig", "ServingFront", "Ticket"]

PENDING, DONE, SHED = "pending", "done", "shed"


@dataclasses.dataclass
class ServingConfig:
    """Knobs for the request plane.  The defaults suit a live serving tier;
    ``FeatureStore`` constructs a PASSTHROUGH front (no cache, no admission
    control) unless handed an explicit config, so a plain store keeps its
    exact pre-front semantics and transfer profile."""

    # scheduler: a table's queue dispatches when this many keys are waiting
    # (pump()/flush() dispatch earlier on deadline pressure / explicitly)
    max_batch_keys: int = 4096
    # admission: hard bound on queued keys across all tables
    max_queue_keys: int = 1 << 30
    # default per-request deadline (request-clock ms); None disables
    # projected-wait admission control (hard queue bound still applies)
    deadline_ms: Optional[float] = None
    # hot-key cache capacity in decoded rows; 0 disables caching entirely
    cache_capacity: int = 0
    # max age (data-clock ms since a newer write superseded the row) a
    # DEGRADED serve may return; None forbids serving stale rows at all
    staleness_bound_ms: Optional[int] = 2_000
    # store path a flush dispatches on: "kernel" (device-resident) | "host"
    engine: str = "kernel"


class _Entry:
    __slots__ = ("values", "creation_ts", "found", "stale_since", "ref")

    def __init__(self, values, creation_ts: int, found: bool) -> None:
        self.values = values
        self.creation_ts = creation_ts
        self.found = found
        self.stale_since: Optional[int] = None  # data-clock ms; None = fresh
        self.ref = True  # CLOCK second-chance bit


class HotKeyCache:
    """CLOCK cache over decoded online rows, keyed (table, encoded id).

    CLOCK rather than strict LRU: a hit only sets a reference bit (no
    per-hit reordering), so the zipfian fast path costs one dict probe.
    Negative results are cached too — under power-law traffic a popular
    missing key is as hot as a popular present one.

    Invalidation MARKS rather than drops: a superseded entry remembers
    ``stale_since`` (the creation_ts of the merge that overwrote it), which
    is exactly the quantity the degraded path's staleness bound is defined
    over.  ``mark_stale`` takes the whole touched-key array of a merge and
    intersects it with the cached ids vectorized, so a 100k-row
    materialization merge does not pay a 100k-iteration Python loop to
    invalidate a 10k-entry cache."""

    def __init__(self, capacity: int) -> None:
        self.capacity = capacity
        self._tables: dict[tuple, dict[int, _Entry]] = {}
        self._ring: list[tuple] = []  # (table, id) in insertion order
        self._hand = 0
        self.size = 0
        self.evictions = 0
        self.invalidations = 0

    def get(self, table: tuple, key: int) -> Optional[_Entry]:
        d = self._tables.get(table)
        return d.get(key) if d is not None else None

    def put(
        self, table: tuple, key: int, values, creation_ts: int, found: bool
    ) -> None:
        if self.capacity <= 0:
            return
        d = self._tables.setdefault(table, {})
        e = d.get(key)
        if e is not None:  # refresh in place: entry is fresh again
            e.values = values
            e.creation_ts = creation_ts
            e.found = found
            e.stale_since = None
            e.ref = True
            return
        if self.size >= self.capacity:
            self._evict_one(table, key)
        else:
            self._ring.append((table, key))
            self.size += 1
        d[key] = _Entry(values, creation_ts, found)

    def _evict_one(self, table: tuple, key: int) -> None:
        """Advance the CLOCK hand to a victim, replace it in the ring."""
        ring = self._ring
        while True:
            self._hand %= len(ring)
            vt, vk = ring[self._hand]
            victim = self._tables[vt][vk]
            if victim.ref:
                victim.ref = False
                self._hand += 1
                continue
            del self._tables[vt][vk]
            ring[self._hand] = (table, key)
            self._hand += 1
            self.evictions += 1
            return

    def mark_stale(self, table: tuple, keys: np.ndarray, ts: int) -> None:
        """A merge touched ``keys`` at data-clock ``ts``: any cached row for
        them is now superseded.  The FIRST superseding write defines the
        staleness onset, so an already-stale entry keeps its earlier
        ``stale_since`` (ages monotonically, never resets)."""
        d = self._tables.get(table)
        if not d or len(keys) == 0:
            return
        if len(keys) > len(d):
            cached = np.fromiter(d.keys(), np.int64, len(d))
            keys = cached[np.isin(cached, keys)]
        for k in keys:
            e = d.get(int(k))
            if e is not None and e.stale_since is None:
                e.stale_since = ts
                self.invalidations += 1

    def clear(self) -> None:
        self._tables.clear()
        self._ring.clear()
        self._hand = 0
        self.size = 0


@dataclasses.dataclass
class Ticket:
    """One in-flight GET.  ``values/found/creation_ts`` fill progressively
    (cache rows at admission, store rows at dispatch) and are final once
    ``status == DONE``; a SHED ticket keeps all-miss results."""

    table: tuple
    ids: np.ndarray
    values: np.ndarray
    found: np.ndarray
    creation_ts: np.ndarray
    enqueued_ms: float
    deadline_ms: Optional[float]
    status: str = PENDING
    pending: Optional[np.ndarray] = None  # row indices awaiting the store
    done_ms: float = 0.0
    degraded: bool = False
    stale_age_ms: float = 0.0  # max staleness this ticket was served (ms)

    def result(self) -> tuple[np.ndarray, np.ndarray]:
        return self.values, self.found


class ServingFront:
    def __init__(
        self,
        store: Union[OnlineStore, Callable[[], OnlineStore]],
        *,
        config: Optional[ServingConfig] = None,
        clock: Optional[Callable[[], int]] = None,
        request_clock: Optional[Callable[[], float]] = None,
        monitor: Optional[HealthMonitor] = None,
    ) -> None:
        self._store_ref = store if callable(store) else (lambda: store)
        self.config = config or ServingConfig()
        self.cache = HotKeyCache(self.config.cache_capacity)
        self._clock = clock
        self._rclock = request_clock or (lambda: time.perf_counter() * 1e3)
        self.monitor = monitor
        self._bound: Optional[OnlineStore] = None
        self._listener = None
        self._queues: dict[tuple, deque] = {}
        self._queued_keys: dict[tuple, int] = {}
        self._queued_total = 0
        # EMA of dispatch service rate (keys per request-clock ms); None
        # until the first dispatch measures one
        self._ema_keys_per_ms: Optional[float] = None
        self.max_stale_age_ms = 0.0
        self.counters = {
            "requests": 0,
            "keys": 0,
            "cache_hits": 0,
            "cache_misses": 0,
            "cache_fastpath": 0,
            "degraded": 0,
            "stale_keys_served": 0,
            "shed": 0,
            "dispatches": 0,
            "coalesced_keys": 0,
            "unique_keys": 0,
            "store_keys": 0,
        }

    # -- store binding -------------------------------------------------------
    def _bind(self) -> OnlineStore:
        """Resolve the store, migrating state if the reference re-pointed
        (geo failover): drop the cache (different region's planes), move the
        merge listener.  Queued tickets stay queued — the next flush serves
        them from the new store."""
        store = self._store_ref()
        if store is self._bound:
            return store
        if self._bound is not None and self._listener in self._bound.merge_listeners:
            self._bound.merge_listeners.remove(self._listener)
        self.cache.clear()

        def listener(spec, stats):
            self.cache.mark_stale(
                spec.key, stats["touched_keys"], stats["creation_ts"]
            )

        store.merge_listeners.append(listener)
        self._listener = listener
        self._bound = store
        return store

    # -- clocks / helpers ----------------------------------------------------
    def _data_now(self, now: Optional[int]) -> Optional[int]:
        if now is not None:
            return now
        return self._clock() if self._clock is not None else None

    def _obs(self, name: str, value: float) -> None:
        if self.monitor is not None:
            self.monitor.system.observe(name, value)

    def _inc(self, name: str, by: float = 1.0) -> None:
        self.counters[name] += by
        if self.monitor is not None:
            self.monitor.system.inc(f"serving/{name}", by)

    @staticmethod
    def _expired(entry: _Entry, now: Optional[int], ttl: Optional[int]) -> bool:
        return (
            entry.found
            and now is not None
            and ttl is not None
            and now - entry.creation_ts > ttl
        )

    def _fill_from_entry(self, t: Ticket, row: int, e: _Entry, now, ttl) -> None:
        """Serve one ticket row from a cache entry, applying record TTL the
        way the store would (expired -> miss, zero row)."""
        if e.found and not self._expired(e, now, ttl):
            t.values[row] = e.values
            t.found[row] = True
            t.creation_ts[row] = e.creation_ts

    def est_wait_ms(self, table: tuple, extra_keys: int = 0) -> float:
        """Projected queue wait for a table given the measured service rate
        (0 until the first dispatch calibrates one)."""
        if not self._ema_keys_per_ms:
            return 0.0
        queued = self._queued_keys.get(table, 0) + extra_keys
        return queued / self._ema_keys_per_ms

    # -- admission -----------------------------------------------------------
    def submit(
        self,
        name: str,
        version: int,
        id_columns: Optional[list] = None,
        *,
        ids: Optional[np.ndarray] = None,
        now: Optional[int] = None,
        deadline_ms: Optional[float] = None,
        _default_deadline: bool = True,
    ) -> Ticket:
        """Admit one GET.  Rows the cache can serve fresh are filled
        immediately; the residual enqueues for the next coalesced dispatch.
        Under overload the request degrades to bounded-staleness cache rows
        or is shed — it never joins a queue it cannot clear in time."""
        store = self._bind()
        tkey = (name, version)
        spec = store.spec(name, version)
        if ids is None:
            ids = encode_keys(id_columns)
        else:
            # the ticket outlives this call in self._queues until the next
            # flush; own the ids instead of aliasing the caller's buffer
            # (np.asarray is a no-copy view on dtype match — the PR-5
            # ReplicationLog bug class, enforced by fslint's aliasing rule)
            ids = np.array(ids, np.int64, copy=True)
        if deadline_ms is None and _default_deadline:
            deadline_ms = self.config.deadline_ms
        n = len(ids)
        d = len(spec.features)
        t = Ticket(
            table=tkey,
            ids=ids,
            values=np.zeros((n, d), np.float32),
            found=np.zeros(n, bool),
            creation_ts=np.zeros(n, np.int64),
            enqueued_ms=self._rclock(),
            deadline_ms=deadline_ms,
        )
        self._inc("requests")
        self._inc("keys", n)
        now_l = self._data_now(now)
        ttl = spec.materialization.online_ttl

        pending: list[int] = []
        if self.cache.capacity > 0:
            get = self.cache.get
            for i in range(n):
                e = get(tkey, int(ids[i]))
                if e is not None and e.stale_since is None:
                    e.ref = True
                    self._fill_from_entry(t, i, e, now_l, ttl)
                    self.counters["cache_hits"] += 1
                else:
                    pending.append(i)
                    self.counters["cache_misses"] += 1
        else:
            pending = list(range(n))

        if not pending:
            t.status = DONE
            t.done_ms = self._rclock()
            self._inc("cache_fastpath")
            self._obs("serving/request_us", (t.done_ms - t.enqueued_ms) * 1e3)
            return t

        residual = len(pending)
        overloaded = self._queued_total + residual > self.config.max_queue_keys
        if not overloaded and t.deadline_ms is not None:
            overloaded = self.est_wait_ms(tkey, residual) > t.deadline_ms
        if overloaded:
            return self._degrade_or_shed(t, pending, now_l, ttl)

        t.pending = np.asarray(pending, np.int64)
        self._queues.setdefault(tkey, deque()).append(t)
        self._queued_keys[tkey] = self._queued_keys.get(tkey, 0) + residual
        self._queued_total += residual
        if self._queued_keys[tkey] >= self.config.max_batch_keys:
            self.flush(name, version, now=now_l)
        return t

    def _degrade_or_shed(
        self, t: Ticket, pending: list[int], now_l, ttl
    ) -> Ticket:
        """Overload path: serve every missing row from a cache entry within
        the staleness bound, or shed the whole request.  All-or-nothing — a
        half-stale half-missing answer is not a serving mode."""
        bound = self.config.staleness_bound_ms
        entries = []
        max_age = 0.0
        for i in pending:
            e = self.cache.get(t.table, int(t.ids[i]))
            if e is None:
                entries = None
                break
            if e.stale_since is not None:
                if bound is None or now_l is None:
                    entries = None
                    break
                age = now_l - e.stale_since
                if age > bound:
                    entries = None
                    break
                max_age = max(max_age, float(age))
            entries.append((i, e))
        if entries is None:
            t.status = SHED
            t.done_ms = self._rclock()
            self._inc("shed")
            return t
        nstale = 0
        for i, e in entries:
            self._fill_from_entry(t, i, e, now_l, ttl)
            if e.stale_since is not None:
                nstale += 1
        t.status = DONE
        t.done_ms = self._rclock()
        t.degraded = True
        t.stale_age_ms = max_age
        self.max_stale_age_ms = max(self.max_stale_age_ms, max_age)
        self._inc("degraded")
        self._inc("stale_keys_served", nstale)
        if nstale and self.monitor is not None:
            self.monitor.record_serving_stale_age(max_age)
        self._obs("serving/request_us", (t.done_ms - t.enqueued_ms) * 1e3)
        return t

    # -- scheduling ----------------------------------------------------------
    def pump(self, now: Optional[int] = None, *, force: bool = False) -> int:
        """Dispatch every table whose oldest waiter can no longer afford to
        keep waiting (queue age + projected service time >= deadline).
        Deadline-less tickets are always due.  Returns dispatches run."""
        req_now = self._rclock()
        ran = 0
        for tkey in list(self._queues):
            q = self._queues[tkey]
            if not q:
                continue
            head = q[0]
            due = force or head.deadline_ms is None
            if not due:
                waited = req_now - head.enqueued_ms
                due = waited + self.est_wait_ms(tkey) >= head.deadline_ms
            if due:
                ran += self.flush(*tkey, now=now)
        return ran

    def flush(
        self,
        name: str,
        version: int,
        *,
        engine: Optional[str] = None,
        now: Optional[int] = None,
    ) -> int:
        """Drain a table's queue: coalesce queued tickets into dispatches of
        at most ``max_batch_keys`` keys each (a single over-sized ticket
        still dispatches whole).  Returns the number of dispatches."""
        store = self._bind()
        tkey = (name, version)
        q = self._queues.get(tkey)
        n_dispatch = 0
        cap = self.config.max_batch_keys
        while q:
            batch, nkeys = [], 0
            while q and (not batch or nkeys + len(q[0].pending) <= cap):
                t = q.popleft()
                batch.append(t)
                nkeys += len(t.pending)
            self._queued_keys[tkey] -= nkeys
            self._queued_total -= nkeys
            self._dispatch(store, tkey, batch, engine, now)
            n_dispatch += 1
        return n_dispatch

    def _dispatch(
        self,
        store: OnlineStore,
        tkey: tuple,
        tickets: list[Ticket],
        engine: Optional[str],
        now: Optional[int],
    ) -> None:
        """One coalesced store round-trip for a set of tickets: dedup ->
        cache re-probe -> ONE ``lookup_encoded`` for the residual -> scatter
        rows back -> refill the cache.  Per-stage wall latency is observed
        for every dispatch."""
        engine = engine or self.config.engine
        name, version = tkey
        spec = store.spec(name, version)
        ttl = spec.materialization.online_ttl
        now_l = self._data_now(now)
        d = len(spec.features)
        req_now = self._rclock()
        waits = [(req_now - t.enqueued_ms) * 1e3 for t in tickets]
        if self.monitor is not None:
            self.monitor.system.histograms["serving/queue_wait_us"].observe_batch(
                waits
            )

        t0 = time.perf_counter()
        all_ids = (
            tickets[0].ids[tickets[0].pending]
            if len(tickets) == 1
            else np.concatenate([t.ids[t.pending] for t in tickets])
        )
        uids, inverse = np.unique(all_ids, return_inverse=True)
        uvals = np.zeros((len(uids), d), np.float32)
        ufound = np.zeros(len(uids), bool)
        ucr = np.zeros(len(uids), np.int64)
        # re-probe: an earlier dispatch this flush may have refilled entries
        need: list[int] = []
        if self.cache.capacity > 0:
            get = self.cache.get
            for j in range(len(uids)):
                e = get(tkey, int(uids[j]))
                if e is not None and e.stale_since is None:
                    e.ref = True
                    if e.found and not self._expired(e, now_l, ttl):
                        uvals[j] = e.values
                        ufound[j] = True
                        ucr[j] = e.creation_ts
                else:
                    need.append(j)
        else:
            need = list(range(len(uids)))
        t1 = time.perf_counter()

        if need:
            miss = np.asarray(need, np.int64)
            vals, found, cr = store.lookup_encoded(
                name,
                version,
                uids[miss],
                now=now_l,
                use_kernel=(engine == "kernel"),
            )
            uvals[miss] = vals
            ufound[miss] = found
            ucr[miss] = cr
        t2 = time.perf_counter()

        if need and self.cache.capacity > 0:
            put = self.cache.put
            for j in need:
                put(tkey, int(uids[j]), uvals[j].copy(), int(ucr[j]), bool(ufound[j]))
        res_v = uvals[inverse]
        res_f = ufound[inverse]
        res_c = ucr[inverse]
        off = 0
        done_ms = self._rclock()
        for t in tickets:
            m = len(t.pending)
            t.values[t.pending] = res_v[off : off + m]
            t.found[t.pending] = res_f[off : off + m]
            t.creation_ts[t.pending] = res_c[off : off + m]
            t.pending = None
            t.status = DONE
            t.done_ms = done_ms
            off += m
        t3 = time.perf_counter()

        self._inc("dispatches")
        self._inc("coalesced_keys", len(all_ids))
        self._inc("unique_keys", len(uids))
        self._inc("store_keys", len(need))
        if self.monitor is not None:
            self.monitor.record_serving_stage("assembly", (t1 - t0) * 1e6)
            self.monitor.record_serving_stage("kernel", (t2 - t1) * 1e6)
            self.monitor.record_serving_stage("decode", (t3 - t2) * 1e6)
            self.monitor.system.histograms["serving/request_us"].observe_batch(
                [(done_ms - t.enqueued_ms) * 1e3 for t in tickets]
            )
        service_ms = (t3 - t0) * 1e3
        if service_ms > 0 and len(all_ids):
            rate = len(all_ids) / service_ms
            self._ema_keys_per_ms = (
                rate
                if self._ema_keys_per_ms is None
                else 0.7 * self._ema_keys_per_ms + 0.3 * rate
            )

    # -- synchronous conveniences -------------------------------------------
    def get(
        self,
        name: str,
        version: int,
        id_columns: Optional[list] = None,
        *,
        ids: Optional[np.ndarray] = None,
        now: Optional[int] = None,
        engine: Optional[str] = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Single-caller GET: submit + immediate flush of the table (no
        deadline — a synchronous caller is its own deadline), returning
        (values, found) exactly like ``OnlineStore.lookup``.  Concurrent
        tickets already queued for the table ride the same dispatch."""
        t = self.submit(
            name, version, id_columns, ids=ids, now=now, _default_deadline=False
        )
        if t.status == PENDING:
            self.flush(name, version, engine=engine, now=now)
        if t.status == SHED:
            raise RuntimeError(
                f"serving front shed a synchronous GET for {name}:v{version} "
                f"(queue {self._queued_total} keys over budget)"
            )
        return t.result()

    def stats(self) -> dict:
        keyed = self.counters["cache_hits"] + self.counters["cache_misses"]
        return {
            **self.counters,
            "cache_hit_rate": (
                self.counters["cache_hits"] / keyed if keyed else 0.0
            ),
            "cache_size": self.cache.size,
            "cache_evictions": self.cache.evictions,
            "cache_invalidations": self.cache.invalidations,
            "queued_keys": self._queued_total,
            "max_stale_age_ms": self.max_stale_age_ms,
            "est_keys_per_ms": self._ema_keys_per_ms,
        }
