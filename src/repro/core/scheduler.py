"""Materialization scheduling subsystem (paper §3.1.1, §4.3).

Tracks the two state machines the paper requires:

  * DATA STATE — per feature-set version, an interval set over the feature
    event timeline recording which windows are materialized.  Retrieval can
    therefore distinguish "window not materialized" from "window materialized
    but empty" (§4.3).
  * JOB STATE — queued/running/succeeded/failed jobs and the feature window
    each covers, with the invariant that CONCURRENT JOBS NEVER OVERLAP in
    feature window for the same feature-set version (§4.3: no
    nondeterministic store contents).

Context-aware scheduling (§3.1.1):
  * scheduled incremental jobs are generated on a cadence, each covering the
    next incremental window;
  * a backfill request SUSPENDS conflicting scheduled jobs (they resume —
    are regenerated — after the backfill window is covered);
  * backfill windows are partitioned into unit windows per the feature set's
    ``partition_window`` (customer-providable), skipping already-materialized
    sub-windows (coalescing).

Fault tolerance: job execution is delegated to runtime/supervisor with
retry/backoff; the whole scheduler state serializes to/from JSON so a
restarted runtime "safely resumes from where it left off without data loss"
(§3.1.2).
"""

from __future__ import annotations

import dataclasses
import enum
import json
from typing import Optional

from repro.core.transform import FeatureWindow

__all__ = ["IntervalSet", "JobState", "JobKind", "MaterializationJob", "Scheduler"]


class IntervalSet:
    """Sorted, disjoint, half-open [start, end) intervals over the timeline."""

    def __init__(self, intervals: Optional[list[tuple[int, int]]] = None):
        self._iv: list[tuple[int, int]] = []
        for s, e in intervals or []:
            self.add(s, e)

    def add(self, start: int, end: int) -> None:
        if end <= start:
            raise ValueError("empty interval")
        merged = []
        placed = False
        for s, e in self._iv:
            if e < start or s > end:  # disjoint (touching intervals merge)
                merged.append((s, e))
            else:
                start, end = min(start, s), max(end, e)
        for i, (s, e) in enumerate(merged):
            if start < s:
                merged.insert(i, (start, end))
                placed = True
                break
        if not placed:
            merged.append((start, end))
        self._iv = merged

    def subtract(self, start: int, end: int) -> None:
        out = []
        for s, e in self._iv:
            if e <= start or s >= end:
                out.append((s, e))
                continue
            if s < start:
                out.append((s, start))
            if e > end:
                out.append((end, e))
        self._iv = out

    def covers(self, start: int, end: int) -> bool:
        for s, e in self._iv:
            if s <= start and end <= e:
                return True
        return False

    def overlaps(self, start: int, end: int) -> bool:
        return any(s < end and start < e for s, e in self._iv)

    def gaps_within(self, start: int, end: int) -> list[tuple[int, int]]:
        """Sub-windows of [start,end) NOT covered (the coalescing primitive)."""
        gaps = []
        cur = start
        for s, e in self._iv:
            if e <= cur or s >= end:
                continue
            if s > cur:
                gaps.append((cur, min(s, end)))
            cur = max(cur, e)
            if cur >= end:
                break
        if cur < end:
            gaps.append((cur, end))
        return gaps

    @property
    def intervals(self) -> list[tuple[int, int]]:
        return list(self._iv)

    def total_length(self) -> int:
        return sum(e - s for s, e in self._iv)

    def to_json(self) -> list[list[int]]:
        return [[s, e] for s, e in self._iv]

    @staticmethod
    def from_json(data: list[list[int]]) -> "IntervalSet":
        out = IntervalSet()
        out._iv = [(int(s), int(e)) for s, e in data]
        return out


class JobState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    SUCCEEDED = "succeeded"
    FAILED = "failed"
    SUSPENDED = "suspended"
    CANCELLED = "cancelled"


class JobKind(enum.Enum):
    BACKFILL = "backfill"
    SCHEDULED = "scheduled"
    BOOTSTRAP = "bootstrap"


@dataclasses.dataclass
class MaterializationJob:
    job_id: int
    feature_set: str
    version: int
    window: FeatureWindow
    kind: JobKind
    state: JobState = JobState.QUEUED
    attempts: int = 0
    max_attempts: int = 3
    error: Optional[str] = None

    def to_json(self) -> dict:
        return {
            "job_id": self.job_id,
            "feature_set": self.feature_set,
            "version": self.version,
            "window": [self.window.start, self.window.end],
            "kind": self.kind.value,
            "state": self.state.value,
            "attempts": self.attempts,
            "max_attempts": self.max_attempts,
            "error": self.error,
        }

    @staticmethod
    def from_json(d: dict) -> "MaterializationJob":
        return MaterializationJob(
            job_id=d["job_id"],
            feature_set=d["feature_set"],
            version=d["version"],
            window=FeatureWindow(*d["window"]),
            kind=JobKind(d["kind"]),
            state=JobState(d["state"]),
            attempts=d["attempts"],
            max_attempts=d["max_attempts"],
            error=d.get("error"),
        )


class Scheduler:
    """Context-aware materialization scheduler for one feature store."""

    def __init__(self) -> None:
        self._next_job_id = 1
        self.jobs: dict[int, MaterializationJob] = {}
        # (name, version) -> materialized-data interval state
        self.data_state: dict[tuple[str, int], IntervalSet] = {}
        # (name, version) -> high-water mark of scheduled materialization
        self.schedule_cursor: dict[tuple[str, int], int] = {}
        # (name, version) -> cadence / unit window (from the spec)
        self._cadence: dict[tuple[str, int], int] = {}
        self._partition_window: dict[tuple[str, int], int] = {}
        self.alerts: list[str] = []

    # -- registration --------------------------------------------------------
    def register_feature_set(
        self,
        name: str,
        version: int,
        *,
        schedule_interval: Optional[int],
        partition_window: Optional[int],
        timeline_origin: int = 0,
    ) -> None:
        key = (name, version)
        self.data_state.setdefault(key, IntervalSet())
        if schedule_interval:
            self._cadence[key] = schedule_interval
            self.schedule_cursor.setdefault(key, timeline_origin)
        self._partition_window[key] = (
            partition_window or schedule_interval or 3_600_000
        )

    # -- invariants ------------------------------------------------------------
    def _active_jobs(self, key: tuple[str, int]) -> list[MaterializationJob]:
        return [
            j
            for j in self.jobs.values()
            if (j.feature_set, j.version) == key
            and j.state in (JobState.QUEUED, JobState.RUNNING)
        ]

    def _conflicts(self, key: tuple[str, int], window: FeatureWindow) -> list:
        return [j for j in self._active_jobs(key) if j.window.overlaps(window)]

    def _enqueue(
        self, key: tuple[str, int], window: FeatureWindow, kind: JobKind
    ) -> MaterializationJob:
        if self._conflicts(key, window):
            raise RuntimeError(
                f"scheduling invariant violated: overlapping active window "
                f"{window} for {key}"
            )
        job = MaterializationJob(self._next_job_id, key[0], key[1], window, kind)
        self._next_job_id += 1
        self.jobs[job.job_id] = job
        return job

    # -- scheduled incremental jobs (§4.3) --------------------------------------
    def tick(self, now: int) -> list[MaterializationJob]:
        """Generate scheduled incremental jobs up to ``now``.  Each job covers
        one cadence window [cursor, cursor + cadence)."""
        new_jobs = []
        for key, cadence in self._cadence.items():
            cursor = self.schedule_cursor[key]
            while cursor + cadence <= now:
                window = FeatureWindow(cursor, cursor + cadence)
                if self._conflicts(key, window):
                    # An active (likely backfill) job owns this span; stop
                    # generating until it completes (context-aware suspend).
                    break
                if self.data_state[key].covers(window.start, window.end):
                    cursor += cadence  # already materialized (by a backfill)
                    self.schedule_cursor[key] = cursor
                    continue
                new_jobs.append(self._enqueue(key, window, JobKind.SCHEDULED))
                cursor += cadence
                self.schedule_cursor[key] = cursor
        return new_jobs

    # -- backfill (§3.1.1, §4.3) --------------------------------------------------
    def request_backfill(
        self, name: str, version: int, window: FeatureWindow
    ) -> list[MaterializationJob]:
        """On-demand backfill: suspend conflicting queued scheduled jobs,
        partition the window into unit windows, skip covered sub-windows."""
        key = (name, version)
        suspended = 0
        for j in self._conflicts(key, window):
            if j.kind is JobKind.SCHEDULED and j.state is JobState.QUEUED:
                j.state = JobState.SUSPENDED
                suspended += 1
            else:
                raise RuntimeError(
                    f"backfill window {window} conflicts with running job "
                    f"{j.job_id}; retry after it completes"
                )
        unit = self._partition_window[key]
        jobs = []
        for gap_s, gap_e in self.data_state[key].gaps_within(window.start, window.end):
            cur = gap_s
            while cur < gap_e:
                jobs.append(
                    self._enqueue(
                        key,
                        FeatureWindow(cur, min(cur + unit, gap_e)),
                        JobKind.BACKFILL,
                    )
                )
                cur += unit
        return jobs

    def resume_suspended(self) -> list[MaterializationJob]:
        """Re-queue suspended scheduled jobs whose window is still needed."""
        resumed = []
        for j in self.jobs.values():
            if j.state is not JobState.SUSPENDED:
                continue
            key = (j.feature_set, j.version)
            if self.data_state[key].covers(j.window.start, j.window.end):
                j.state = JobState.CANCELLED  # backfill already covered it
            elif not self._conflicts(key, j.window):
                j.state = JobState.QUEUED
                resumed.append(j)
        return resumed

    # -- job lifecycle -------------------------------------------------------------
    def runnable_jobs(self) -> list[MaterializationJob]:
        return sorted(
            (j for j in self.jobs.values() if j.state is JobState.QUEUED),
            key=lambda j: (j.kind is not JobKind.BACKFILL, j.window.start),
        )

    def mark_running(self, job_id: int) -> None:
        self.jobs[job_id].state = JobState.RUNNING

    def mark_succeeded(self, job_id: int) -> None:
        j = self.jobs[job_id]
        j.state = JobState.SUCCEEDED
        self.data_state[(j.feature_set, j.version)].add(j.window.start, j.window.end)

    def mark_failed(self, job_id: int, error: str) -> bool:
        """Returns True if the job will be retried (back to QUEUED)."""
        j = self.jobs[job_id]
        j.attempts += 1
        j.error = error
        if j.attempts < j.max_attempts:
            j.state = JobState.QUEUED
            return True
        j.state = JobState.FAILED
        self.alerts.append(
            f"non-recoverable failure: job {job_id} ({j.feature_set}:"
            f"v{j.version} {j.window}) after {j.attempts} attempts: {error}"
        )
        return False

    # -- retrieval support (§4.3 disambiguation) ------------------------------------
    def materialized_intervals(self, name: str, version: int) -> list[tuple[int, int]]:
        """The §4.3 data-state view: which feature windows are materialized."""
        return self.data_state.get((name, version), IntervalSet()).intervals

    def is_materialized(self, name: str, version: int, start: int, end: int) -> bool:
        return self.data_state[(name, version)].covers(start, end)

    def staleness(self, name: str, version: int, now: int) -> Optional[int]:
        """Freshness metric (§2.1): ms between now and the newest materialized
        event time; None if nothing is materialized."""
        iv = self.data_state[(name, version)].intervals
        if not iv:
            return None
        return max(0, now - iv[-1][1])

    # -- persistence (resume without data loss, §3.1.2) -------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "next_job_id": self._next_job_id,
                "jobs": [j.to_json() for j in self.jobs.values()],
                "data_state": {
                    f"{k[0]}::{k[1]}": v.to_json()
                    for k, v in self.data_state.items()
                },
                "schedule_cursor": {
                    f"{k[0]}::{k[1]}": v for k, v in self.schedule_cursor.items()
                },
                "cadence": {
                    f"{k[0]}::{k[1]}": v for k, v in self._cadence.items()
                },
                "partition_window": {
                    f"{k[0]}::{k[1]}": v
                    for k, v in self._partition_window.items()
                },
                "alerts": self.alerts,
            }
        )

    @staticmethod
    def from_json(payload: str) -> "Scheduler":
        d = json.loads(payload)
        sched = Scheduler()
        sched._next_job_id = d["next_job_id"]
        for jd in d["jobs"]:
            job = MaterializationJob.from_json(jd)
            # A RUNNING job at checkpoint time was interrupted: requeue it.
            if job.state is JobState.RUNNING:
                job.state = JobState.QUEUED
            sched.jobs[job.job_id] = job

        def _k(s: str) -> tuple[str, int]:
            name, ver = s.rsplit("::", 1)
            return (name, int(ver))

        sched.data_state = {
            _k(k): IntervalSet.from_json(v) for k, v in d["data_state"].items()
        }
        sched.schedule_cursor = {
            _k(k): v for k, v in d["schedule_cursor"].items()
        }
        sched._cadence = {_k(k): v for k, v in d["cadence"].items()}
        sched._partition_window = {
            _k(k): v for k, v in d["partition_window"].items()
        }
        sched.alerts = list(d["alerts"])
        return sched
