"""Offline/online consistency machinery (paper §4.5.2, §4.5.4, §4.5.5).

  * ``check_consistency`` — the §4.5.2 invariant: for every ID the online
    store holds exactly the offline store's max(tuple(event_ts, creation_ts))
    record (modulo TTL).  This is the "no online/offline skew" test surface.
  * ``bootstrap_offline_to_online`` — read latest-per-ID from offline, dump
    to online (cheap direction).
  * ``bootstrap_online_to_offline`` — dump everything online into offline.

Both bootstraps reuse the Algorithm-2 merges, so they are idempotent and
safe to retry — consistent with the §4.5.4 eventual-consistency story.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.offline_store import CREATION_TS, EVENT_TS, OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.table import Table

__all__ = [
    "ConsistencyReport",
    "check_consistency",
    "bootstrap_offline_to_online",
    "bootstrap_online_to_offline",
]


@dataclasses.dataclass
class ConsistencyReport:
    consistent: bool
    checked_ids: int
    missing_online: list[int]
    stale_online: list[int]
    missing_offline: list[int]

    def summary(self) -> str:
        if self.consistent:
            return f"consistent ({self.checked_ids} ids)"
        return (
            f"INCONSISTENT: missing_online={len(self.missing_online)} "
            f"stale_online={len(self.stale_online)} "
            f"missing_offline={len(self.missing_offline)}"
        )


def check_consistency(
    spec: FeatureSetSpec, offline: OfflineStore, online: OnlineStore
) -> ConsistencyReport:
    latest = offline.latest_per_key(spec.name, spec.version)
    online_dump = online.dump_all(spec.name, spec.version)
    on_map = {
        int(k): (int(ev), int(cr))
        for k, ev, cr in zip(
            online_dump["__key__"], online_dump[EVENT_TS], online_dump[CREATION_TS]
        )
    }
    missing_online, stale_online = [], []
    off_keys = set()
    for i in range(len(latest)):
        k = int(latest["__key__"][i])
        off_keys.add(k)
        want = (int(latest[EVENT_TS][i]), int(latest[CREATION_TS][i]))
        got = on_map.get(k)
        if got is None:
            missing_online.append(k)
        elif got != want:
            stale_online.append(k)
    missing_offline = [k for k in on_map if k not in off_keys]
    ok = not (missing_online or stale_online or missing_offline)
    return ConsistencyReport(
        ok, len(off_keys), missing_online, stale_online, missing_offline
    )


def bootstrap_offline_to_online(
    spec: FeatureSetSpec, offline: OfflineStore, online: OnlineStore, now: int
) -> int:
    """§4.5.5: for each ID take max(tuple(event_ts, creation_ts)) from the
    offline history and merge into the online store.  The merge preserves the
    ORIGINAL creation timestamps (a bootstrap is a copy, not a new
    materialization), replayed in creation order so Algorithm 2 semantics
    hold even against records already present online."""
    latest = offline.latest_per_key(spec.name, spec.version)
    online.register(spec)
    n = 0
    # Replay grouped by creation_ts so each merge call has one creation time.
    for cr in np.unique(latest[CREATION_TS]) if len(latest) else []:
        sub = latest.filter(latest[CREATION_TS] == cr)
        frame = _as_feature_frame(spec, sub)
        online.merge(spec, frame, int(cr))
        n += len(sub)
    return n


def bootstrap_online_to_offline(
    spec: FeatureSetSpec, offline: OfflineStore, online: OnlineStore
) -> int:
    """§4.5.5: dump everything in the online store into the offline store."""
    dump = online.dump_all(spec.name, spec.version)
    offline.register(spec)
    n = 0
    for cr in np.unique(dump[CREATION_TS]) if len(dump) else []:
        sub = dump.filter(dump[CREATION_TS] == cr)
        frame = _as_feature_frame(spec, sub)
        offline.merge(spec, frame, int(cr))
        n += len(sub)
    return n


def _as_feature_frame(spec: FeatureSetSpec, records: Table) -> Table:
    """Records (with __key__/event_ts) -> the transform-output frame shape.

    Only valid for single-join-key specs whose key is the raw ID; composite
    keys cannot be inverted from the surrogate, so bootstraps for them carry
    the surrogate key column through (documented limitation of the codec)."""
    cols = {}
    if len(spec.index_columns) == 1:
        cols[spec.index_columns[0]] = records["__key__"]
    else:  # surrogate passthrough
        for c in spec.index_columns:
            cols[c] = records["__key__"]
    cols[spec.timestamp_col] = records[EVENT_TS]
    for f in spec.features:
        cols[f.name] = records[f.name]
    return Table(cols)
