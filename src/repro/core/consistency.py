"""Offline/online consistency machinery (paper §4.5.2, §4.5.4, §4.5.5).

  * ``check_consistency`` — the §4.5.2 invariant: for every ID the online
    store holds exactly the offline store's max(tuple(event_ts, creation_ts))
    record (modulo TTL).  This is the "no online/offline skew" test surface.
  * ``bootstrap_offline_to_online`` — read latest-per-ID from offline, dump
    to online (cheap direction).
  * ``bootstrap_online_to_offline`` — dump everything online into offline.

Both bootstraps reuse the Algorithm-2 merges, so they are idempotent and
safe to retry — consistent with the §4.5.4 eventual-consistency story.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.assets import FeatureSetSpec
from repro.core.offline_store import CREATION_TS, EVENT_TS, OfflineStore
from repro.core.online_store import OnlineStore
from repro.core.table import Table

__all__ = [
    "ConsistencyReport",
    "check_consistency",
    "bootstrap_offline_to_online",
    "bootstrap_online_to_offline",
]


@dataclasses.dataclass
class ConsistencyReport:
    consistent: bool
    checked_ids: int
    missing_online: list[int]
    stale_online: list[int]
    missing_offline: list[int]

    def summary(self) -> str:
        if self.consistent:
            return f"consistent ({self.checked_ids} ids)"
        return (
            f"INCONSISTENT: missing_online={len(self.missing_online)} "
            f"stale_online={len(self.stale_online)} "
            f"missing_offline={len(self.missing_offline)}"
        )


def check_consistency(
    spec: FeatureSetSpec, offline: OfflineStore, online: OnlineStore
) -> ConsistencyReport:
    """Vectorized sorted-set comparison: ``latest_per_key`` (lexsorted) and
    ``dump_all`` (index order) are both ascending in ``__key__``, so skew
    checks are searchsorted alignments, not per-id dict probes."""
    latest = offline.latest_per_key(spec.name, spec.version)
    online_dump = online.dump_all(spec.name, spec.version)
    off_k = latest["__key__"] if len(latest) else np.empty(0, np.int64)
    on_k = online_dump["__key__"] if len(online_dump) else np.empty(0, np.int64)
    missing_online = np.setdiff1d(off_k, on_k, assume_unique=True)
    missing_offline = np.setdiff1d(on_k, off_k, assume_unique=True)
    common, off_i, on_i = np.intersect1d(
        off_k, on_k, assume_unique=True, return_indices=True
    )
    stale = (
        (latest[EVENT_TS][off_i] != online_dump[EVENT_TS][on_i])
        | (latest[CREATION_TS][off_i] != online_dump[CREATION_TS][on_i])
        if len(common)
        else np.zeros(0, bool)
    )
    stale_online = common[stale]
    ok = not (len(missing_online) or len(stale_online) or len(missing_offline))
    return ConsistencyReport(
        ok,
        len(off_k),
        [int(k) for k in missing_online],
        [int(k) for k in stale_online],
        [int(k) for k in missing_offline],
    )


def bootstrap_offline_to_online(
    spec: FeatureSetSpec, offline: OfflineStore, online: OnlineStore, now: int
) -> int:
    """§4.5.5: for each ID take max(tuple(event_ts, creation_ts)) from the
    offline history and merge into the online store.  The merge preserves the
    ORIGINAL creation timestamps (a bootstrap is a copy, not a new
    materialization), replayed in creation order so Algorithm 2 semantics
    hold even against records already present online."""
    latest = offline.latest_per_key(spec.name, spec.version)
    online.register(spec)
    n = 0
    # Replay grouped by creation_ts so each merge call has one creation time.
    for cr in np.unique(latest[CREATION_TS]) if len(latest) else []:
        sub = latest.filter(latest[CREATION_TS] == cr)
        frame = _as_feature_frame(spec, sub)
        online.merge(spec, frame, int(cr))
        n += len(sub)
    return n


def bootstrap_online_to_offline(
    spec: FeatureSetSpec, offline: OfflineStore, online: OnlineStore
) -> int:
    """§4.5.5: dump everything in the online store into the offline store."""
    dump = online.dump_all(spec.name, spec.version)
    offline.register(spec)
    n = 0
    for cr in np.unique(dump[CREATION_TS]) if len(dump) else []:
        sub = dump.filter(dump[CREATION_TS] == cr)
        frame = _as_feature_frame(spec, sub)
        offline.merge(spec, frame, int(cr))
        n += len(sub)
    return n


def _as_feature_frame(spec: FeatureSetSpec, records: Table) -> Table:
    """Records (with __key__/event_ts) -> the transform-output frame shape.

    Only valid for single-join-key specs whose key is the raw ID; composite
    keys cannot be inverted from the surrogate, so bootstraps for them carry
    the surrogate key column through (documented limitation of the codec)."""
    cols = {}
    if len(spec.index_columns) == 1:
        cols[spec.index_columns[0]] = records["__key__"]
    else:  # surrogate passthrough
        for c in spec.index_columns:
            cols[c] = records["__key__"]
    cols[spec.timestamp_col] = records[EVENT_TS]
    for f in spec.features:
        cols[f.name] = records[f.name]
    return Table(cols)
