"""Wire format for replica-bound ``ReplicatedBatch``es (ROADMAP: WAN
transport realism).

Until now the replication log handed replicas live in-process numpy
references: shipped-byte numbers were estimates (``ReplicatedBatch.nbytes``)
and a replica could in principle alias the publisher's buffers.  This module
is the actual transport encoding — every batch a replica receives has been
serialized into one contiguous byte buffer and decoded back out, exactly
what a multi-process deployment would put on the WAN — so shipped bytes are
MEASURED (``len(frame.data)``), compression is real (zlib, level
configurable, ratio recorded), and replicas physically cannot share memory
with the home store (decoded arrays are read-only views of the received
buffer).

Frame layout (little-endian throughout)
---------------------------------------
One FRAME carries one or more batches (a coalesced run shares a single
header and a single compression stream)::

    magic "FW" | u8 version | u8 flags (bit0: zlib) | u32 batch_count
    | u64 raw_payload_len | u32 crc32 | payload

``crc32`` (wire version 2, ISSUE 7) is the checksum of the WHOLE frame as
shipped — the header with the crc field zeroed, then the payload exactly
as transmitted (post-compression).  The magic/length checks catch
truncation and framing damage but passed silently-corrupted raw payload
arrays straight into replica state, and a payload-only checksum leaves
the header's own bytes unprotected (a flipped ``flags`` bit nothing
validates decodes "successfully"), so the decoder verifies the frame
checksum right after the magic/version gate and rejects any mismatch
with ``WireFormatError`` — a fault-injected (or real) WAN bit-flip
ANYWHERE in the frame surfaces as a detected delivery failure the
publisher retries, never as divergent replica bytes.  ``batch_count == 0``
is a valid frame (``encode_probe``): an empty payload the delivery state
machine uses to re-probe a DEAD replica's link without touching any store.

``payload`` is the concatenation of batch records, zlib-compressed when
flags bit0 is set.  Each batch record::

    i64 seq | i64 creation_ts | u8 plane (0=online, 1=offline)
    | u8 has_columns | u16 table_name_len | table_name utf8
    | u32 table_version
    | array keys | array event_ts | array values
    | if has_columns: u32 n_cols, then per column:
        u16 name_len | name utf8 | array

and an ARRAY is dtype-tagged and shape-prefixed::

    u16 dtype_len | numpy dtype.str utf8 | u8 ndim | u32 dims[ndim]
    | raw C-order bytes

The dtype tag carries the full numpy dtype string (``"<i8"``, ``"<f4"``,
...), so offline batches ship their record-schema columns in NATIVE dtypes
and decode bit-exact.  ``seq == -1`` marks an out-of-log frame (delta-
bootstrap chunks, which are not replication-log entries and are never
acked).

Coalescing
----------
``coalesce`` groups a replica's pending batches into maximal runs of
adjacent same-plane same-table batches; ``encode_run`` packs one run into
one frame (one header, one zlib stream over the concatenated records — the
cross-batch redundancy is what the shared stream exploits).  Decoding a
coalesced frame yields the constituent batches in sequence order, each with
its own ``seq``, so the replica acks exactly the same per-batch sequence it
would have acked un-coalesced.
"""

from __future__ import annotations

import dataclasses
import json
import struct
import zlib
from typing import Iterable, Optional, Sequence

import numpy as np

# DEFAULT_COMPRESS_LEVEL lives in replication.py (the first module of the
# replication<->wire pair to finish importing) and is re-exported here as
# the codec's canonical knob: zlib levels 1..9 trade cpu for ratio, 0/None
# ships raw.
from repro.core.replication import DEFAULT_COMPRESS_LEVEL, ReplicatedBatch

__all__ = [
    "ACK_APPLY_ERROR",
    "ACK_CORRUPT",
    "ACK_OK",
    "Ack",
    "DEFAULT_COMPRESS_LEVEL",
    "HEADER_SIZE",
    "MAX_MESSAGE_BYTES",
    "StreamDecoder",
    "StreamEvent",
    "WireFrame",
    "WireFormatError",
    "coalesce",
    "decode_ack",
    "decode_batch",
    "decode_control",
    "decode_frame",
    "encode_ack",
    "encode_batch",
    "encode_control",
    "encode_probe",
    "encode_run",
    "frame_message",
]

MAGIC = b"FW"
#: v2 (ISSUE 7): +u32 crc32 of the shipped frame (zeroed-crc header +
#: payload) in the header; v1 frames (no checksum) are rejected — silent
#: corruption is worse than a loud version mismatch on a mixed-version link
VERSION = 2
FLAG_ZLIB = 0x01
#: out-of-log sentinel: bootstrap chunks ship over the wire but are not
#: replication-log entries and must never be acked
BOOTSTRAP_SEQ = -1
#: table tag on zero-batch probe frames (never registered, never applied)
PROBE_TABLE = ("__probe__", 0)

_HEADER = struct.Struct("<2sBBIQI")
#: fixed per-frame envelope cost — what break-even accounting must add to
#: the raw payload when comparing against wire bytes
HEADER_SIZE = _HEADER.size
_BATCH_HEAD = struct.Struct("<qqBBH")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_PLANE_CODE = {"online": 0, "offline": 1}
_PLANE_NAME = {v: k for k, v in _PLANE_CODE.items()}


class WireFormatError(ValueError):
    """Malformed or foreign bytes handed to the decoder."""


@dataclasses.dataclass(frozen=True)
class WireFrame:
    """One encoded wire message plus its shipping ledger.

    ``data`` is the only thing that crosses the (modeled) WAN;
    ``raw_nbytes``/``wire_nbytes`` are the measured sizes the shipping
    accounting and the bandwidth cost model consume."""

    data: bytes
    raw_nbytes: int  # serialized payload before compression
    seqs: tuple[int, ...]
    rows: int
    plane: str
    table: tuple[str, int]

    @property
    def wire_nbytes(self) -> int:
        return len(self.data)

    @property
    def compression_ratio(self) -> float:
        """raw/wire for the payload+header actually shipped (>= 1.0 when
        compression wins; ~1.0 when disabled or incompressible)."""
        return (self.raw_nbytes + _HEADER.size) / max(self.wire_nbytes, 1)


# -- encode -------------------------------------------------------------------


def _frame_crc(flags: int, batch_count: int, raw_len: int, payload: bytes) -> int:
    """crc32 over the whole frame with the header's crc field zeroed —
    the checksum covers the header's own fields, so a flipped flag bit or
    length byte is as loudly rejected as a flipped payload byte."""
    head = _HEADER.pack(MAGIC, VERSION, flags, batch_count, raw_len, 0)
    return zlib.crc32(payload, zlib.crc32(head))


def _encode_array(out: list[bytes], a: np.ndarray) -> None:
    a = np.ascontiguousarray(a)
    tag = a.dtype.str.encode()
    out.append(_U16.pack(len(tag)))
    out.append(tag)
    out.append(struct.pack("<B", a.ndim))
    out.append(struct.pack(f"<{a.ndim}I", *a.shape))
    out.append(a.tobytes())


def _encode_record(batch: ReplicatedBatch) -> bytes:
    name = batch.table[0].encode()
    out: list[bytes] = [
        _BATCH_HEAD.pack(
            batch.seq,
            batch.creation_ts,
            _PLANE_CODE[batch.plane],
            1 if batch.columns is not None else 0,
            len(name),
        ),
        name,
        _U32.pack(batch.table[1]),
    ]
    _encode_array(out, batch.keys)
    _encode_array(out, batch.event_ts)
    _encode_array(out, batch.values)
    if batch.columns is not None:
        out.append(_U32.pack(len(batch.columns)))
        for cname, col in batch.columns.items():
            cb = cname.encode()
            out.append(_U16.pack(len(cb)))
            out.append(cb)
            _encode_array(out, col)
    return b"".join(out)


def encode_run(
    batches: Sequence[ReplicatedBatch],
    *,
    compress_level: Optional[int] = DEFAULT_COMPRESS_LEVEL,
) -> WireFrame:
    """Serialize a run of same-plane same-table batches into ONE frame.

    The run shares a single header and a single compression stream; pass a
    single batch for the un-coalesced path.  ``compress_level`` 0/None
    ships the payload raw (the flag bit tells the decoder which)."""
    if not batches:
        raise ValueError("cannot encode an empty run")
    plane, table = batches[0].plane, batches[0].table
    for b in batches[1:]:
        if b.plane != plane or b.table != table:
            raise ValueError(
                f"coalesced run must share (plane, table): "
                f"{(plane, table)} vs {(b.plane, b.table)}"
            )
    payload = b"".join(_encode_record(b) for b in batches)
    raw_len = len(payload)
    flags = 0
    if compress_level:
        packed = zlib.compress(payload, compress_level)
        # incompressible payloads ship raw rather than paying the zlib
        # envelope for nothing; the flag bit keeps decode unambiguous
        if len(packed) < raw_len:
            payload, flags = packed, FLAG_ZLIB
    # checksum the frame AS SHIPPED (header with the crc field zeroed +
    # post-compression payload): the receiver verifies it before touching
    # zlib or the record structure, so WAN corruption anywhere in the
    # frame — header fields included — is rejected at the door instead of
    # decoded into state
    crc = _frame_crc(flags, len(batches), raw_len, payload)
    head = _HEADER.pack(MAGIC, VERSION, flags, len(batches), raw_len, crc)
    return WireFrame(
        data=head + payload,
        raw_nbytes=raw_len,
        seqs=tuple(b.seq for b in batches),
        rows=sum(b.rows for b in batches),
        plane=plane,
        table=table,
    )


def encode_batch(
    batch: ReplicatedBatch,
    *,
    compress_level: Optional[int] = DEFAULT_COMPRESS_LEVEL,
) -> WireFrame:
    """Serialize one batch (either plane) into one contiguous buffer."""
    return encode_run([batch], compress_level=compress_level)


def encode_probe() -> WireFrame:
    """A zero-batch frame: the smallest well-formed wire message.  The
    delivery state machine transmits it to test whether a DEAD replica's
    link carries bytes again — decoding yields no batches, so applying a
    probe touches no store and acks nothing."""
    head = _HEADER.pack(MAGIC, VERSION, 0, 0, 0, _frame_crc(0, 0, 0, b""))
    return WireFrame(
        data=head, raw_nbytes=0, seqs=(), rows=0, plane="online", table=PROBE_TABLE
    )


# -- decode -------------------------------------------------------------------


class _Reader:
    def __init__(self, data: bytes) -> None:
        self.view = memoryview(data)
        self.pos = 0

    def take(self, n: int) -> memoryview:
        if self.pos + n > len(self.view):
            raise WireFormatError(
                f"truncated frame: need {n} bytes at offset {self.pos}, "
                f"have {len(self.view) - self.pos}"
            )
        out = self.view[self.pos : self.pos + n]
        self.pos += n
        return out

    def unpack(self, s: struct.Struct) -> tuple:
        return s.unpack(self.take(s.size))


def _decode_array(r: _Reader) -> np.ndarray:
    (tag_len,) = r.unpack(_U16)
    dtype = np.dtype(bytes(r.take(tag_len)).decode())
    (ndim,) = struct.unpack("<B", r.take(1))
    shape = struct.unpack(f"<{ndim}I", r.take(4 * ndim))
    count = int(np.prod(shape)) if ndim else 1
    a = np.frombuffer(r.take(count * dtype.itemsize), dtype, count)
    return a.reshape(shape)


def _decode_record(r: _Reader) -> ReplicatedBatch:
    seq, creation_ts, plane_code, has_cols, name_len = r.unpack(_BATCH_HEAD)
    if plane_code not in _PLANE_NAME:
        raise WireFormatError(f"unknown plane code {plane_code}")
    name = bytes(r.take(name_len)).decode()
    (version,) = r.unpack(_U32)
    keys = _decode_array(r)
    event_ts = _decode_array(r)
    values = _decode_array(r)
    columns: Optional[dict[str, np.ndarray]] = None
    if has_cols:
        (n_cols,) = r.unpack(_U32)
        columns = {}
        for _ in range(n_cols):
            (cn_len,) = r.unpack(_U16)
            cname = bytes(r.take(cn_len)).decode()
            columns[cname] = _decode_array(r)
    return ReplicatedBatch(
        seq=seq,
        table=(name, version),
        creation_ts=creation_ts,
        keys=keys,
        event_ts=event_ts,
        values=values,
        plane=_PLANE_NAME[plane_code],
        columns=columns,
    )


def decode_frame(data: bytes) -> list[ReplicatedBatch]:
    """Decode one frame back into its batches, in encoded order.

    Decoded arrays are READ-ONLY zero-copy views of the (decompressed)
    received buffer — the replica-side guarantee that applied state can
    never alias, or be corrupted through, publisher memory."""
    if len(data) < _HEADER.size:
        raise WireFormatError(f"frame shorter than header: {len(data)} bytes")
    magic, version, flags, batch_count, raw_len, crc = _HEADER.unpack(
        data[: _HEADER.size]
    )
    if magic != MAGIC:
        raise WireFormatError(f"bad magic {magic!r}")
    if version != VERSION:
        raise WireFormatError(f"unsupported wire version {version}")
    payload = data[_HEADER.size :]
    # verify the checksum over the frame AS SHIPPED (header fields
    # included), before zlib or any record parsing runs: corrupted bytes
    # are rejected at the door
    got = _frame_crc(flags, batch_count, raw_len, payload)
    if got != crc:
        raise WireFormatError(
            f"frame checksum mismatch: crc32 {got:#010x} != declared {crc:#010x}"
        )
    if flags & ~FLAG_ZLIB:
        # belt over the crc's braces: a sender that stamps a valid
        # checksum over flag bits this version doesn't define is a
        # protocol error, not something to silently ignore
        raise WireFormatError(f"unknown flag bits {flags:#04x}")
    if flags & FLAG_ZLIB:
        dec = zlib.decompressobj()
        try:
            payload = dec.decompress(payload)
        except zlib.error as e:
            raise WireFormatError(f"bad zlib payload: {e}") from None
        if dec.unused_data or dec.unconsumed_tail:
            raise WireFormatError("trailing bytes after compressed payload")
    if len(payload) != raw_len:
        raise WireFormatError(f"payload length {len(payload)} != declared {raw_len}")
    r = _Reader(payload)
    try:
        batches = [_decode_record(r) for _ in range(batch_count)]
    except WireFormatError:
        raise
    except (TypeError, ValueError, UnicodeDecodeError, struct.error) as e:
        # a corrupted dtype tag, non-UTF8 name, or impossible shape must
        # surface as the module's contractual rejection error, not leak the
        # numpy/codec internals to the receiver
        raise WireFormatError(f"malformed frame payload: {e}") from None
    if r.pos != len(payload):
        raise WireFormatError(f"{len(payload) - r.pos} trailing bytes in frame")
    return batches


def decode_batch(data: bytes) -> ReplicatedBatch:
    """Decode a single-batch frame (the un-coalesced fast path)."""
    batches = decode_frame(data)
    if len(batches) != 1:
        raise WireFormatError(f"expected 1 batch in frame, got {len(batches)}")
    return batches[0]


# -- coalescing ---------------------------------------------------------------


def coalesce(
    batches: Iterable[ReplicatedBatch],
) -> list[list[ReplicatedBatch]]:
    """Group pending batches into maximal runs of ADJACENT same-plane
    same-table batches — the unit ``encode_run`` ships as one frame.

    Adjacency (not global grouping) preserves the log's total order on the
    wire: batches arrive and are acked in exactly the sequence the home
    appended them, coalesced or not."""
    runs: list[list[ReplicatedBatch]] = []
    for b in batches:
        if runs and runs[-1][0].plane == b.plane and runs[-1][0].table == b.table:
            runs[-1].append(b)
        else:
            runs.append([b])
    return runs


# -- stream framing -----------------------------------------------------------
#
# A WireFrame is self-checksummed but NOT self-delimiting: the v2 header
# carries the RAW payload length, not the post-compression length, so a
# byte stream of concatenated frames cannot be split without decompressing.
# The socket carrier (core/daemon.py) therefore wraps every message in a
# u32 little-endian length prefix:
#
#     u32 payload_len | payload
#
# and the payload's first two bytes name its kind:
#
#     "FW"  a wire frame (header + payload as produced by encode_run)
#     "FC"  a control message: "FC" | u32 crc32(body) | body (UTF-8 JSON)
#     "FA"  an ack:            "FA" | u32 crc32(body) | body (see _ACK_HEAD)
#
# StreamDecoder reassembles messages from arbitrary recv() chunkings —
# partial reads, messages split across chunks, many messages in one chunk —
# and stays on the air through damage: a message whose envelope is intact
# but whose checksum rejects is surfaced as a "corrupt" event (the
# publisher-visible NACK path), while a torn envelope (bad length or
# unknown magic) triggers a resync scan to the next plausible message
# boundary, counting the bytes skipped.

CONTROL_MAGIC = b"FC"
ACK_MAGIC = b"FA"
_STREAM_MAGICS = (MAGIC, CONTROL_MAGIC, ACK_MAGIC)
#: envelope sanity bound — a length prefix beyond this is treated as framing
#: damage (resync), not as a request to buffer gigabytes
MAX_MESSAGE_BYTES = 1 << 28

#: ack status codes: OK (all batches applied), CORRUPT (frame checksum or
#: structure rejected — the publisher's crc_rejected path), APPLY_ERROR
#: (frame decoded but a batch failed to apply; ``seqs`` holds the applied
#: prefix so prefix acks are never lost)
ACK_OK = 0
ACK_CORRUPT = 1
ACK_APPLY_ERROR = 2

#: u8 status | u32 msg_crc (crc32 of the message payload being acked,
#: exactly as received — the correlation token) | i64 rows | u32 n_seqs
_ACK_HEAD = struct.Struct("<BIqI")


@dataclasses.dataclass(frozen=True)
class Ack:
    """A replica's receipt for one stream message.

    ``msg_crc`` echoes crc32 of the exact payload bytes the replica
    received, which is how the publisher correlates acks to in-flight
    sends (retried frames re-encode to identical bytes, so a late ack
    from a timed-out send resolves the retry — the log's per-seq dedup
    makes that safe)."""

    status: int
    msg_crc: int
    rows: int
    seqs: tuple[int, ...]

    @property
    def ok(self) -> bool:
        return self.status == ACK_OK


def frame_message(payload: bytes) -> bytes:
    """Wrap one message payload in the u32 length-prefix envelope."""
    if len(payload) < 2 or len(payload) > MAX_MESSAGE_BYTES:
        raise WireFormatError(f"message payload of {len(payload)} bytes")
    return _U32.pack(len(payload)) + payload


def encode_ack(status: int, msg_crc: int, rows: int, seqs: Sequence[int]) -> bytes:
    """Encode an ack message payload (pass through ``frame_message``)."""
    body = _ACK_HEAD.pack(status, msg_crc & 0xFFFFFFFF, rows, len(seqs))
    body += struct.pack(f"<{len(seqs)}q", *seqs)
    return ACK_MAGIC + _U32.pack(zlib.crc32(body)) + body


def decode_ack(payload: bytes) -> Ack:
    if payload[:2] != ACK_MAGIC:
        raise WireFormatError(f"bad ack magic {payload[:2]!r}")
    (crc,) = _U32.unpack_from(payload, 2)
    body = payload[6:]
    if zlib.crc32(body) != crc:
        raise WireFormatError("ack checksum mismatch")
    if len(body) < _ACK_HEAD.size:
        raise WireFormatError("truncated ack body")
    status, msg_crc, rows, n_seqs = _ACK_HEAD.unpack_from(body, 0)
    want = _ACK_HEAD.size + 8 * n_seqs
    if len(body) != want:
        raise WireFormatError(f"ack body {len(body)} bytes, expected {want}")
    seqs = struct.unpack_from(f"<{n_seqs}q", body, _ACK_HEAD.size)
    return Ack(status=status, msg_crc=msg_crc, rows=rows, seqs=tuple(seqs))


def encode_control(obj: dict) -> bytes:
    """Encode a control message payload (JSON body, crc-protected)."""
    body = json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()
    return CONTROL_MAGIC + _U32.pack(zlib.crc32(body)) + body


def decode_control(payload: bytes) -> dict:
    if payload[:2] != CONTROL_MAGIC:
        raise WireFormatError(f"bad control magic {payload[:2]!r}")
    (crc,) = _U32.unpack_from(payload, 2)
    body = payload[6:]
    if zlib.crc32(body) != crc:
        raise WireFormatError("control checksum mismatch")
    try:
        obj = json.loads(body.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireFormatError(f"malformed control body: {e}") from None
    if not isinstance(obj, dict):
        raise WireFormatError("control body must be a JSON object")
    return obj


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One decoded stream message (or the carcass of a corrupted one).

    ``kind`` is "frame" / "control" / "ack" / "corrupt"; exactly one of
    ``batches`` / ``control`` / ``ack`` is set for the first three.
    ``msg_crc`` is crc32 of the payload AS RECEIVED — for corrupt events
    it identifies the damaged message so the receiver can NACK it."""

    kind: str
    msg_crc: int
    nbytes: int
    batches: Optional[list[ReplicatedBatch]] = None
    control: Optional[dict] = None
    ack: Optional[Ack] = None
    error: Optional[str] = None


def _plausible_length(n: int) -> bool:
    return 2 <= n <= MAX_MESSAGE_BYTES


class StreamDecoder:
    """Incremental message reassembly over an unreliable byte stream.

    Feed it whatever ``recv`` returns; it yields complete messages and
    never raises on damage.  Counters: ``messages`` (complete envelopes
    consumed), ``corrupt_messages`` (intact envelope, rejected payload),
    ``resyncs`` / ``skipped_bytes`` (torn envelopes scanned past)."""

    def __init__(self) -> None:
        self._buf = bytearray()
        self.messages = 0
        self.corrupt_messages = 0
        self.resyncs = 0
        self.skipped_bytes = 0

    @property
    def buffered_bytes(self) -> int:
        return len(self._buf)

    def feed(self, data: bytes) -> list[StreamEvent]:
        self._buf += data
        events: list[StreamEvent] = []
        while True:
            ev = self._next()
            if ev is None:
                break
            if ev is not _NO_EVENT:
                events.append(ev)
        return events

    def _next(self):
        buf = self._buf
        if len(buf) < 4:
            return None
        (n,) = _U32.unpack_from(buf, 0)
        if not _plausible_length(n):
            return self._resync()
        if len(buf) >= 6 and bytes(buf[4:6]) not in _STREAM_MAGICS:
            return self._resync()
        if len(buf) < 4 + n:
            return None
        payload = bytes(buf[4 : 4 + n])
        del buf[: 4 + n]
        self.messages += 1
        return self._dispatch(payload)

    def _dispatch(self, payload: bytes) -> StreamEvent:
        crc = zlib.crc32(payload)
        magic = payload[:2]
        try:
            if magic == MAGIC:
                return StreamEvent(
                    "frame", crc, len(payload), batches=decode_frame(payload)
                )
            if magic == CONTROL_MAGIC:
                return StreamEvent(
                    "control", crc, len(payload), control=decode_control(payload)
                )
            return StreamEvent("ack", crc, len(payload), ack=decode_ack(payload))
        except WireFormatError as e:
            self.corrupt_messages += 1
            return StreamEvent("corrupt", crc, len(payload), error=str(e))

    def _resync(self):
        """The envelope itself is torn: scan forward for the next offset
        that looks like a message boundary (plausible u32 length followed
        by a known magic) and drop everything before it."""
        buf = self._buf
        self.resyncs += 1
        for i in range(1, len(buf) - 5):
            (n,) = _U32.unpack_from(buf, i)
            if _plausible_length(n) and bytes(buf[i + 4 : i + 6]) in _STREAM_MAGICS:
                self.skipped_bytes += i
                del buf[:i]
                return _NO_EVENT
        # no boundary in sight: keep a 5-byte tail (a prefix of the next
        # envelope may straddle the chunk edge) and wait for more bytes
        keep = min(len(buf), 5)
        self.skipped_bytes += len(buf) - keep
        del buf[: len(buf) - keep]
        return None


#: sentinel: the decoder made progress (dropped garbage) without yielding
_NO_EVENT = StreamEvent("none", 0, 0)
