"""Feature–model lineage subsystem (paper §4.6).

Challenges named by the paper, and how this module answers them:
  * scalability — a model may use hundreds+ of features: adjacency is kept
    as indexed sets both ways, so queries are O(degree), and registration is
    batched;
  * cross-region lineage — models deploy to any region while the feature
    store lives in one: edges carry the consuming deployment's region, and
    ``global_view`` aggregates across regions.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Iterable

__all__ = ["LineageGraph", "ModelNode"]


@dataclasses.dataclass(frozen=True)
class ModelNode:
    name: str
    version: int
    region: str


class LineageGraph:
    def __init__(self) -> None:
        # feature ref = "<feature_set>:v<version>:<feature>"
        self._models_of_feature: dict[str, set[ModelNode]] = defaultdict(set)
        self._features_of_model: dict[ModelNode, set[str]] = defaultdict(set)

    def register_model(self, model: ModelNode, feature_refs: Iterable[str]) -> None:
        refs = set(feature_refs)
        self._features_of_model[model] |= refs
        for r in refs:
            self._models_of_feature[r].add(model)

    def features_of_model(self, model: ModelNode) -> set[str]:
        return set(self._features_of_model.get(model, set()))

    def models_of_feature(self, feature_ref: str) -> set[ModelNode]:
        return set(self._models_of_feature.get(feature_ref, set()))

    def models_by_region(self, feature_ref: str) -> dict[str, int]:
        out: dict[str, int] = defaultdict(int)
        for m in self._models_of_feature.get(feature_ref, set()):
            out[m.region] += 1
        return dict(out)

    def impact_of_feature_set(self, name: str, version: int) -> set[ModelNode]:
        """Every model touching any feature of the given feature-set version —
        the blast-radius query behind safe archival."""
        prefix = f"{name}:v{version}:"
        out: set[ModelNode] = set()
        for ref, models in self._models_of_feature.items():
            if ref.startswith(prefix):
                out |= models
        return out

    def global_view(self) -> dict:
        regions: dict[str, int] = defaultdict(int)
        for m in self._features_of_model:
            regions[m.region] += 1
        return {
            "num_models": len(self._features_of_model),
            "num_features": len(self._models_of_feature),
            "num_edges": sum(
                len(v) for v in self._features_of_model.values()
            ),
            "models_per_region": dict(regions),
        }
