"""Feature calculation flow — the paper's Algorithm 1, verbatim dataflow.

    source_window_start = feature_window_start - source_lookback
    df1 = source.read(...).filter(source_window)
    df2 = transform(df1)
    feature_df = df2.filter(feature_window)

The same flow is used by materialization jobs (incremental and backfill) and
by on-the-fly offline joins of non-materialized feature sets (§4.2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Protocol

import numpy as np

from repro.core.assets import FeatureSetSpec, validate_feature_frame
from repro.core.table import Table

__all__ = ["SourceProtocol", "FeatureWindow", "compute_feature_window"]


class SourceProtocol(Protocol):
    """A time-addressable source system (paper Fig. 2 'data sources')."""

    name: str

    def read(self, start_ts: int, end_ts: int) -> Table:
        """Rows with start_ts <= ts < end_ts."""
        ...


@dataclasses.dataclass(frozen=True, order=True)
class FeatureWindow:
    """Half-open [start, end) window on the feature event timeline."""

    start: int
    end: int

    def __post_init__(self) -> None:
        if self.end <= self.start:
            raise ValueError(f"empty window [{self.start}, {self.end})")

    def overlaps(self, other: "FeatureWindow") -> bool:
        return self.start < other.end and other.start < self.end

    @property
    def length(self) -> int:
        return self.end - self.start


def compute_feature_window(
    spec: FeatureSetSpec,
    source: SourceProtocol,
    window: FeatureWindow,
    context: dict[str, Any] | None = None,
) -> Table:
    """Algorithm 1: read lookback-extended source, transform, clip to window."""
    if source.name != spec.source_name:
        raise ValueError(
            f"feature set {spec.name} is bound to source {spec.source_name!r}, "
            f"got {source.name!r}"
        )
    ctx = dict(context or {})
    ctx.setdefault("feature_window", window)

    source_start = window.start - spec.source_lookback
    df1 = source.read(source_start, window.end)

    df2 = spec.transform(df1, ctx)
    df2 = validate_feature_frame(spec, df2)

    ts = df2[spec.timestamp_col].astype(np.int64)
    feature_df = df2.filter((ts >= window.start) & (ts < window.end))
    return feature_df
