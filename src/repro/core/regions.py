"""Geo-distribution (paper §2.1 "Regional presence", §3.1.2–3.1.3, §4.1.2).

Two access mechanisms for an asset living in one region, consumed in another:
  * CROSS-REGION ACCESS — data stays where created; remote reads traverse the
    inter-region link (the paper's implemented mechanism).
  * GEO-REPLICATION — assets replicated into consumer regions for local-read
    latency (the paper's road-map mechanism; ruled out where geo-fencing /
    data-compliance forbids it).

On the TPU substrate, regions map to the production mesh's ``pod`` axis
(launch/mesh.py): replication = replicated sharding over ``pod``; cross-
region access = collectives over ``pod``.  This module is the control plane:
placement, replication policy, compliance fencing, health, fail-over, and a
latency cost model so benchmarks can contrast the two mechanisms with the
same numbers a WAN deployment would reason about.

The geo-replication DATA plane lives in core/replication.py: every home
``OnlineStore.merge`` appends its reduced winner rows — and every home
``OfflineStore.merge`` its inserted rows — to a ``ReplicationLog`` (one
monotone sequence spanning both planes, one cursor per replica), an async
applier drains the log into replica stores, and ``GeoPlacement.failover``
here decides WHICH replica gets promoted — the nearest healthy one by this
topology's latency model — after which the applier replays that replica's
un-acked log suffix.  Replay is safe because both planes' merges are
idempotent: Algorithm 2's commutative latest-wins join on (event_ts,
creation_ts) online, full-key insert-if-absent offline.  A failed ex-home
leaves the serving set at promotion (``remove_replica``) and re-enters it
when recovered via ``add_replica`` — the control-plane half of
``GeoFeatureStore.rejoin``'s delta bootstrap.

``GeoTopology`` supports per-link latency overrides (``link_latency_ms``)
on top of the two-tier local/WAN default, so "nearest" is a real choice
between replicas rather than a constant.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence

import numpy as np

from repro.core.keys import KEY_SPACE_BITS, shard_coordinate

__all__ = [
    "ReplicationPolicy",
    "Region",
    "GeoTopology",
    "GeoPlacement",
    "ShardMap",
    "RegionDownError",
    "ComplianceError",
]


class ReplicationPolicy(enum.Enum):
    CROSS_REGION_ACCESS = "cross_region_access"  # paper's current mechanism
    GEO_REPLICATED = "geo_replicated"  # paper's road-map mechanism


class RegionDownError(RuntimeError):
    pass


class ComplianceError(RuntimeError):
    pass


@dataclasses.dataclass
class Region:
    name: str
    healthy: bool = True
    #: geo-fenced regions may not export data (compliance, §4.1.2)
    geo_fenced: bool = False


@dataclasses.dataclass
class GeoTopology:
    """Static latency/bandwidth model between regions (ICI vs DCN tiers).

    ``link_latency_ms`` optionally refines the flat WAN tier with symmetric
    per-pair one-way latencies, e.g. ``{("westus2", "eastus"): 32.0}``;
    pairs not listed fall back to ``cross_region_latency_ms``.
    ``cross_region_gbps`` models WAN link bandwidth so replication shipping
    cost can be charged per byte, not just per message."""

    regions: dict[str, Region]
    local_latency_ms: float = 1.0
    cross_region_latency_ms: float = 60.0
    link_latency_ms: dict[tuple[str, str], float] = dataclasses.field(
        default_factory=dict
    )
    cross_region_gbps: float = 1.0
    #: MEASURED per-link round-trip gauges (EWMA), fed by real carriers
    #: (``core/daemon.py``'s ``SocketChannel`` observes every ack RTT).
    #: Deliberately separate from the static ``latency()`` model: the
    #: deterministic routing/shipping gates price the model, while these
    #: gauges report what the wire actually did.
    measured_rtt_ms: dict[tuple[str, str], float] = dataclasses.field(
        default_factory=dict
    )
    #: EWMA smoothing factor for ``observe_rtt`` (weight of the new sample)
    rtt_alpha: float = 0.2

    def latency(self, src: str, dst: str) -> float:
        if src == dst:
            return self.local_latency_ms
        for pair in ((src, dst), (dst, src)):
            if pair in self.link_latency_ms:
                return self.link_latency_ms[pair]
        return self.cross_region_latency_ms

    def transfer_ms(self, src: str, dst: str, nbytes: int) -> float:
        """Modeled one-way shipping time for ``nbytes``: link latency plus
        serialization at the WAN bandwidth (local transfers are free)."""
        if src == dst:
            return 0.0
        return self.latency(src, dst) + nbytes * 8 / (self.cross_region_gbps * 1e6)

    # -- measured link gauges ---------------------------------------------------
    def observe_rtt(self, src: str, dst: str, rtt_ms: float) -> float:
        """Fold one measured round-trip into the per-link EWMA gauge and
        return the updated estimate.  Purely observational — ``latency()``
        and ``transfer_ms()`` stay on the static model."""
        key = (src, dst)
        prev = self.measured_rtt_ms.get(key)
        est = (
            rtt_ms
            if prev is None
            else prev + self.rtt_alpha * (rtt_ms - prev)
        )
        self.measured_rtt_ms[key] = est
        return est

    def measured_latency(self, src: str, dst: str) -> Optional[float]:
        """The link's measured RTT EWMA, or None when nothing real has
        crossed it yet (symmetric lookup, like ``latency``)."""
        for pair in ((src, dst), (dst, src)):
            if pair in self.measured_rtt_ms:
                return self.measured_rtt_ms[pair]
        return None

    # -- health ----------------------------------------------------------------
    # Health lives on the topology so DETECTED failure (the delivery state
    # machine's DEAD transition, core/replication.py) and operator flips
    # (GeoPlacement.mark_down) drive the same flag read routing checks.
    def mark_down(self, region: str) -> None:
        self.regions[region].healthy = False

    def mark_up(self, region: str) -> None:
        self.regions[region].healthy = True


class GeoPlacement:
    """Placement + replication + fail-over for one feature store's assets."""

    def __init__(
        self,
        topology: GeoTopology,
        home_region: str,
        policy: ReplicationPolicy = ReplicationPolicy.CROSS_REGION_ACCESS,
    ) -> None:
        if home_region not in topology.regions:
            raise ValueError(f"unknown region {home_region}")
        self.topology = topology
        self.home_region = home_region
        self.policy = policy
        self.replicas: set[str] = {home_region}
        self.read_log: list[tuple[str, str, float]] = []  # (from, served_by, ms)

    # -- replication --------------------------------------------------------
    def add_replica(self, region: str) -> None:
        if self.policy is not ReplicationPolicy.GEO_REPLICATED:
            raise ComplianceError("replicas require the GEO_REPLICATED policy (§4.1.2)")
        home = self.topology.regions[self.home_region]
        if home.geo_fenced:
            raise ComplianceError(
                f"region {self.home_region} is geo-fenced; assets may not be "
                f"replicated out (data-compliance, §4.1.2)"
            )
        if region not in self.topology.regions:
            raise ValueError(f"unknown region {region}")
        self.replicas.add(region)

    def remove_replica(self, region: str) -> None:
        """Drop a region from the serving set — e.g. a failed ex-home whose
        store was lost at promotion; it may rejoin later via add_replica."""
        if region == self.home_region:
            raise ValueError("cannot remove the home region")
        self.replicas.discard(region)

    # -- routing ---------------------------------------------------------------
    def route_read(
        self, consumer_region: str, candidates: Optional[list[str]] = None
    ) -> tuple[str, float]:
        """Pick the serving region for a read issued from ``consumer_region``.
        Returns (region, modeled latency ms).  Raises RegionDownError when no
        healthy serving region exists.  ``candidates`` optionally restricts
        the serving set further (the geo data plane passes only IN-SYNC
        replicas); health is always re-checked here."""
        if candidates is None:
            candidates = list(self.replicas)
        candidates = [r for r in candidates if self.topology.regions[r].healthy]
        if not candidates:
            raise RegionDownError(
                f"no healthy replica of store homed in {self.home_region}"
            )
        if consumer_region in candidates:
            serving = consumer_region
        else:
            serving = min(
                candidates,
                key=lambda r: (self.topology.latency(consumer_region, r), r),
            )
        ms = self.topology.latency(consumer_region, serving)
        self.read_log.append((consumer_region, serving, ms))
        return serving, ms

    # -- failure handling (§3.1.2: cross-region resources for HA) ---------------
    def mark_down(self, region: str) -> None:
        self.topology.mark_down(region)

    def mark_up(self, region: str) -> None:
        self.topology.mark_up(region)

    def failover(self) -> Optional[str]:
        """If the home region is down, promote the nearest healthy replica to
        primary — nearest by the topology's latency model from the FAILED
        home (ties broken by name for determinism), so the promoted primary
        keeps write traffic on the cheapest link once the region recovers.
        Returns the new primary (or None if nothing to do).

        This only re-points placement; the data-plane half of a fail-over —
        replaying the promoted replica's un-acked replication-log suffix so
        its store converges to the home's pre-failure state — is
        ``GeoReplicator.promote`` (core/replication.py)."""
        if self.topology.regions[self.home_region].healthy:
            return None
        healthy = [
            r
            for r in self.replicas
            if r != self.home_region and self.topology.regions[r].healthy
        ]
        if not healthy:
            raise RegionDownError("home region down and no healthy replica")
        prev = self.home_region
        self.home_region = min(
            healthy, key=lambda r: (self.topology.latency(prev, r), r)
        )
        return self.home_region


class ShardMap:
    """Hash-range partition of the encoded entity keyspace onto home
    regions — the placement half of active-active multi-home writes.

    ``keys.encode_keys`` mixes every entity key uniformly into
    ``[0, 2**KEY_SPACE_BITS)``; this map cuts that interval into contiguous
    ranges (``bounds`` holds the interior cut points) and assigns each range
    a HOME region (``owners``).  Ownership is a pure function of the encoded
    key — ``searchsorted`` over the fixed bounds — so every writer in every
    region routes a key identically with no placement table to consult.

    The bounds are FIXED at construction; rebalance (region join/leave,
    per-shard failover) only rewrites ``owners`` and bumps ``version``, so
    ownership of every key outside the moved range is stable across any
    sequence of reassignments — the property the shard-routing suite sweeps.
    """

    KEY_SPACE = 1 << KEY_SPACE_BITS

    def __init__(self, bounds: Sequence[int], owners: Sequence[str]) -> None:
        self.bounds = np.asarray(list(bounds), np.uint64)
        self.owners = list(owners)
        if len(self.owners) != len(self.bounds) + 1:
            raise ValueError(
                f"{len(self.bounds)} interior bounds need "
                f"{len(self.bounds) + 1} owners, got {len(self.owners)}"
            )
        if len(self.bounds):
            b = self.bounds.astype(object)
            if min(b) <= 0 or max(b) >= self.KEY_SPACE:
                raise ValueError("bounds must lie strictly inside the keyspace")
            if any(x >= y for x, y in zip(b, b[1:])):
                raise ValueError("bounds must be strictly ascending")
        self.version = 0

    @classmethod
    def even(cls, regions: Sequence[str], num_shards: Optional[int] = None):
        """Equal-width ranges, one per region round-robin (the default:
        ``num_shards == len(regions)`` gives each region exactly one
        range)."""
        regions = list(regions)
        if not regions:
            raise ValueError("need at least one region")
        n = num_shards if num_shards is not None else len(regions)
        if n < 1:
            raise ValueError("need at least one shard")
        step = cls.KEY_SPACE // n
        bounds = [step * i for i in range(1, n)]
        owners = [regions[i % len(regions)] for i in range(n)]
        return cls(bounds, owners)

    @property
    def num_shards(self) -> int:
        return len(self.owners)

    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        """Shard id of each encoded key — one ``searchsorted`` over the
        fixed interior bounds, in the uniform ``keys.shard_coordinate``
        space (raw encoded keys cluster low when ids are small; the
        coordinate never does)."""
        keys = np.asarray(keys, np.int64)
        if len(keys) and keys.min() < 0:
            raise ValueError("shard routing requires encoded (non-negative) keys")
        return np.searchsorted(self.bounds, shard_coordinate(keys), side="right")

    def owner_of(self, shard: int) -> str:
        return self.owners[shard]

    def shard_range(self, shard: int) -> tuple[int, int]:
        """Half-open ``[lo, hi)`` range of one shard, in the
        ``keys.shard_coordinate`` space (the same space ``bounds`` cuts and
        the delta-bootstrap ``key_range`` filter masks on)."""
        lo = int(self.bounds[shard - 1]) if shard > 0 else 0
        hi = (
            int(self.bounds[shard])
            if shard < len(self.bounds)
            else self.KEY_SPACE
        )
        return lo, hi

    def owned_shards(self, region: str) -> list[int]:
        return [i for i, o in enumerate(self.owners) if o == region]

    def regions(self) -> list[str]:
        """Distinct owner regions, in first-shard order."""
        seen: list[str] = []
        for o in self.owners:
            if o not in seen:
                seen.append(o)
        return seen

    def assign(self, shard: int, region: str) -> None:
        """Reassign one range to a new home — the ShardMap cutover step of
        rebalance/per-shard failover.  Bounds never move; only this shard's
        ownership changes."""
        if not 0 <= shard < self.num_shards:
            raise ValueError(f"no shard {shard}")
        self.owners[shard] = region
        self.version += 1

    def split_by_owner(self, keys: np.ndarray) -> dict[str, np.ndarray]:
        """Row indices of ``keys`` grouped by owning region — the write-path
        splitter: each group is the slice the writer applies locally (its
        own region) or forwards to the range's home."""
        shards = self.shard_of(keys)
        out: dict[str, np.ndarray] = {}
        for sid in np.unique(shards):
            region = self.owners[int(sid)]
            idx = np.flatnonzero(shards == sid)
            out[region] = (
                np.concatenate([out[region], idx]) if region in out else idx
            )
        # a region owning several ranges gets ONE slice in arrival order, so
        # the forwarded sub-batch replays the caller's row order exactly
        return {r: np.sort(idx) for r, idx in out.items()}

    def as_dict(self) -> dict:
        return {
            "bounds": [int(b) for b in self.bounds],
            "owners": list(self.owners),
            "version": self.version,
        }
