"""Geo-distribution (paper §2.1 "Regional presence", §3.1.2–3.1.3, §4.1.2).

Two access mechanisms for an asset living in one region, consumed in another:
  * CROSS-REGION ACCESS — data stays where created; remote reads traverse the
    inter-region link (the paper's implemented mechanism).
  * GEO-REPLICATION — assets replicated into consumer regions for local-read
    latency (the paper's road-map mechanism; ruled out where geo-fencing /
    data-compliance forbids it).

On the TPU substrate, regions map to the production mesh's ``pod`` axis
(launch/mesh.py): replication = replicated sharding over ``pod``; cross-
region access = collectives over ``pod``.  This module is the control plane:
placement, replication policy, compliance fencing, health, fail-over, and a
latency cost model so benchmarks can contrast the two mechanisms with the
same numbers a WAN deployment would reason about.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

__all__ = [
    "ReplicationPolicy",
    "Region",
    "GeoTopology",
    "GeoPlacement",
    "RegionDownError",
    "ComplianceError",
]


class ReplicationPolicy(enum.Enum):
    CROSS_REGION_ACCESS = "cross_region_access"  # paper's current mechanism
    GEO_REPLICATED = "geo_replicated"            # paper's road-map mechanism


class RegionDownError(RuntimeError):
    pass


class ComplianceError(RuntimeError):
    pass


@dataclasses.dataclass
class Region:
    name: str
    healthy: bool = True
    #: geo-fenced regions may not export data (compliance, §4.1.2)
    geo_fenced: bool = False


@dataclasses.dataclass
class GeoTopology:
    """Static latency/bandwidth model between regions (ICI vs DCN tiers)."""

    regions: dict[str, Region]
    local_latency_ms: float = 1.0
    cross_region_latency_ms: float = 60.0

    def latency(self, src: str, dst: str) -> float:
        return self.local_latency_ms if src == dst else self.cross_region_latency_ms


class GeoPlacement:
    """Placement + replication + fail-over for one feature store's assets."""

    def __init__(
        self,
        topology: GeoTopology,
        home_region: str,
        policy: ReplicationPolicy = ReplicationPolicy.CROSS_REGION_ACCESS,
    ) -> None:
        if home_region not in topology.regions:
            raise ValueError(f"unknown region {home_region}")
        self.topology = topology
        self.home_region = home_region
        self.policy = policy
        self.replicas: set[str] = {home_region}
        self.read_log: list[tuple[str, str, float]] = []  # (from, served_by, ms)

    # -- replication --------------------------------------------------------
    def add_replica(self, region: str) -> None:
        if self.policy is not ReplicationPolicy.GEO_REPLICATED:
            raise ComplianceError(
                "replicas require the GEO_REPLICATED policy (§4.1.2)"
            )
        home = self.topology.regions[self.home_region]
        if home.geo_fenced:
            raise ComplianceError(
                f"region {self.home_region} is geo-fenced; assets may not be "
                f"replicated out (data-compliance, §4.1.2)"
            )
        if region not in self.topology.regions:
            raise ValueError(f"unknown region {region}")
        self.replicas.add(region)

    # -- routing ---------------------------------------------------------------
    def route_read(self, consumer_region: str) -> tuple[str, float]:
        """Pick the serving region for a read issued from ``consumer_region``.
        Returns (region, modeled latency ms).  Raises RegionDownError when no
        healthy serving region exists."""
        candidates = [
            r for r in self.replicas if self.topology.regions[r].healthy
        ]
        if not candidates:
            raise RegionDownError(
                f"no healthy replica of store homed in {self.home_region}"
            )
        if consumer_region in candidates:
            serving = consumer_region
        else:
            serving = min(
                candidates,
                key=lambda r: self.topology.latency(consumer_region, r),
            )
        ms = self.topology.latency(consumer_region, serving)
        self.read_log.append((consumer_region, serving, ms))
        return serving, ms

    # -- failure handling (§3.1.2: cross-region resources for HA) ---------------
    def mark_down(self, region: str) -> None:
        self.topology.regions[region].healthy = False

    def mark_up(self, region: str) -> None:
        self.topology.regions[region].healthy = True

    def failover(self) -> Optional[str]:
        """If the home region is down, promote the nearest healthy replica to
        primary.  Returns the new primary (or None if nothing to do)."""
        if self.topology.regions[self.home_region].healthy:
            return None
        healthy = [
            r
            for r in self.replicas
            if r != self.home_region and self.topology.regions[r].healthy
        ]
        if not healthy:
            raise RegionDownError("home region down and no healthy replica")
        self.home_region = healthy[0]
        return self.home_region
