"""Vectorized Algorithm-2 merge engine (paper §4.5) — shared batch reduction.

Both stores' write paths funnel a materialization frame through the same
pre-reduction: group the batch by entity id (stable, preserving arrival
order within each id), find each id's latest-wins winner, and derive the
EXACT per-row insert/override/no-op decisions the sequential Algorithm-2
loop would have made — without running it row by row.

The decision rule being vectorized (online branch, one batch shares a single
``creation_ts``):

  * first row of an id absent from the store          -> insert
  * row whose event_ts exceeds the running maximum
    (store record, then every earlier batch row)      -> override
  * row tying the STORE record's event_ts before any
    batch row improved it, with newer creation_ts     -> override (tie rule)
  * everything else                                   -> no-op

``segmented_exclusive_prefix_max`` provides the running maximum per id via a
log-step Hillis–Steele scan, so a B-row batch reduces in O(B log B) numpy ops
regardless of duplicate structure.
"""

from __future__ import annotations

import dataclasses
import sys
from typing import Callable, Optional

import numpy as np

__all__ = [
    "INT64_MIN",
    "OnlineBatchPlan",
    "argsort_ids",
    "merge_sorted",
    "plan_online_batch",
    "segmented_exclusive_prefix_max",
]


def argsort_ids(a: np.ndarray) -> np.ndarray:
    """Stable ascending argsort for NON-NEGATIVE int64 keys via 4-pass
    16-bit radix.

    numpy's ``kind="stable"`` falls back to comparison mergesort for 64-bit
    ints (radix only kicks in at <=16 bits), costing ~16ms per 100k keys;
    ``np.lexsort`` over the four little-endian uint16 digit planes runs a
    stable radix pass per plane (~4x faster) and yields the same order
    because every key is non-negative (entity keys are sign-bit-cleared by
    the codec, full-key hashes by ``encode_full_keys``).
    """
    if len(a) < 2048 or sys.byteorder != "little":
        return np.argsort(a, kind="stable")  # radix setup doesn't pay / BE
    digits = np.ascontiguousarray(a).view(np.uint16).reshape(-1, 4)
    # little-endian: plane 0 least significant; lexsort's LAST key is primary
    return np.lexsort((digits[:, 0], digits[:, 1], digits[:, 2], digits[:, 3]))

INT64_MIN = np.int64(np.iinfo(np.int64).min)


def segmented_exclusive_prefix_max(
    seg_ids: np.ndarray, values: np.ndarray
) -> np.ndarray:
    """Running max of every PRIOR element within each segment.

    ``seg_ids`` must be non-decreasing (rows grouped by segment); the first
    row of each segment gets ``INT64_MIN``.  Hillis–Steele doubling: each
    step is a full-width vector max, so the scan is O(n log n) element ops
    with no Python-level per-row work.
    """
    n = len(values)
    out = np.empty(n, np.int64)
    if n == 0:
        return out
    out[0] = INT64_MIN
    out[1:] = values[:-1]
    seg_first = np.empty(n, bool)
    seg_first[0] = True
    seg_first[1:] = seg_ids[1:] != seg_ids[:-1]
    out[seg_first] = INT64_MIN
    # the scan saturates once the doubling shift covers the LONGEST segment,
    # which for merge batches (few duplicates per id) is typically 2-4 rows —
    # so this usually runs 1-2 passes, not log2(n)
    starts = np.flatnonzero(seg_first)
    max_run = int(np.diff(np.append(starts, n)).max())
    shift = 1
    while shift < max_run:
        same = seg_ids[shift:] == seg_ids[:-shift]
        out[shift:] = np.where(same, np.maximum(out[shift:], out[:-shift]), out[shift:])
        shift *= 2
    return out


def merge_sorted(
    a_list: list[np.ndarray],
    b_list: list[np.ndarray],
    pos: Optional[np.ndarray] = None,
) -> list[np.ndarray]:
    """Merge sorted-key parallel arrays ``b_list`` into ``a_list``.

    ``a_list[0]``/``b_list[0]`` are the sorted keys; trailing arrays are
    payloads permuted identically.  ``pos`` (``searchsorted(a0, b0)``) can be
    passed in when the caller already computed it for a membership probe —
    the merge is then three vectorized scatters, an order of magnitude
    cheaper than per-array ``np.insert``.
    """
    a0, b0 = a_list[0], b_list[0]
    if pos is None:
        pos = np.searchsorted(a0, b0)
    k, m = len(a0), len(b0)
    new_at = pos + np.arange(m)
    old_at = np.ones(k + m, bool)
    old_at[new_at] = False
    out = []
    for a, b in zip(a_list, b_list):
        merged = np.empty(k + m, a.dtype)
        merged[new_at] = b
        merged[old_at] = a
        out.append(merged)
    return out


@dataclasses.dataclass
class OnlineBatchPlan:
    """Per-unique-id reduction of one merge batch + exact Algorithm-2 tallies.

    Arrays are aligned on the batch's unique ids in ascending id order
    (``uids``); ``winner_row`` indexes back into the ORIGINAL frame.
    """

    uids: np.ndarray  # (G,) int64, ascending
    winner_row: np.ndarray  # (G,) int64 — original row of the winning record
    winner_ev: np.ndarray  # (G,) int64 — the id's max event_ts in the batch
    first_row: np.ndarray  # (G,) int64 — original row of first occurrence
    # beat is the write mask: True exactly where the store state changes
    # (fresh inserts and winners beating the stored record).  The per-batch
    # stats a merge returns (tallies + touched-slot coords) are this plan
    # masked down — nothing is re-derived from store state after the apply,
    # which is what lets the device-resident engine skip pulling planes back.
    beat: np.ndarray  # (G,) bool — store record must be (re)written
    is_new: np.ndarray  # (G,) bool — id absent from the store
    inserts: int
    overrides: int
    noops: int


def plan_online_batch(
    ids: np.ndarray,
    event_ts: np.ndarray,
    creation_ts: int,
    resolve: Callable[[np.ndarray], tuple[np.ndarray, np.ndarray, np.ndarray]],
) -> OnlineBatchPlan:
    """Reduce a batch to per-id winners + exact sequential-loop counters.

    ``resolve(uids)`` returns ``(old_ev, old_cr, found)`` — the store's
    current record per unique id (ascending id order); ``old_ev``/``old_cr``
    entries where ``found`` is False are ignored.  Taking a callback keeps
    the batch's single stable id-sort HERE (the store would otherwise pay a
    second full sort for ``np.unique``).
    """
    n = len(ids)
    if n == 0:
        empty = np.empty(0, np.int64)
        return OnlineBatchPlan(
            uids=empty, winner_row=empty, winner_ev=empty, first_row=empty,
            beat=np.empty(0, bool), is_new=np.empty(0, bool),
            inserts=0, overrides=0, noops=0,
        )
    order = argsort_ids(ids)  # groups ids, keeps arrival order (stable)
    sid = ids[order]
    sev = event_ts[order].astype(np.int64)

    seg_first = np.empty(n, bool)
    seg_first[0] = True
    seg_first[1:] = sid[1:] != sid[:-1]
    # int32 segment labels: halves the scan's compare traffic vs int64
    seg_idx = np.cumsum(seg_first, dtype=np.int32) - 1
    starts = np.flatnonzero(seg_first)

    uids = sid[starts]
    old_ev, old_cr, found = resolve(uids)
    gmax = np.maximum.reduceat(sev, starts)
    # winner = FIRST batch row reaching the group max (later ties are no-ops)
    cand = np.where(sev == gmax[seg_idx], np.arange(n), n)
    winner_row = order[np.minimum.reduceat(cand, starts)]
    first_row = order[starts]

    pm = segmented_exclusive_prefix_max(seg_idx, sev)
    found_r = found[seg_idx]
    old_ev_r = np.where(found_r, old_ev[seg_idx], INT64_MIN)
    old_cr_r = np.where(found_r, old_cr[seg_idx], INT64_MIN)

    insert_r = seg_first & ~found_r
    # override: beats the running max (store record folded in), or the
    # one-shot creation-ts tie against the untouched store record
    ev_gt = sev > np.maximum(pm, old_ev_r)
    tie = found_r & (sev == old_ev_r) & (pm < old_ev_r) & (creation_ts > old_cr_r)
    override_r = (ev_gt | tie) & ~insert_r

    beat = np.where(
        found,
        (gmax > old_ev) | ((gmax == old_ev) & (creation_ts > old_cr)),
        True,
    )
    n_ins = int(insert_r.sum())
    n_ovr = int(override_r.sum())
    return OnlineBatchPlan(
        uids=uids,
        winner_row=winner_row,
        winner_ev=gmax,
        first_row=first_row,
        beat=beat,
        is_new=~found,
        inserts=n_ins,
        overrides=n_ovr,
        noops=n - n_ins - n_ovr,
    )
