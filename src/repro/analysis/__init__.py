"""fslint — this repo's invariant checker (``python -m repro.analysis``).

A stdlib-only static-analysis framework whose rules are this codebase's own
recurring bug classes, promoted from one-off satellite fixes into enforced
invariants: publisher-buffer aliasing (PR 5), substring gauge-key matching
(PR 9), vacuous bench gates (PR 8), wall-clock/unseeded RNG on the
byte-replayable chaos surface (PR 7's determinism contract), use-after-donate
on the device plane (PR 2), wire-format endianness/dispatch discipline, and
bare-dict stats returns (PR 9's typed-stats refactor).  A tokenize-based
format probe additionally EXECUTES the line-length/quote/trailing-whitespace
portion of the ruff format gate that the build container could only
approximate.

See README.md in this directory for the rule catalog, suppression syntax,
and how to add a rule.
"""

from .engine import (  # noqa: F401
    FileContext,
    Finding,
    ProjectContext,
    RunResult,
    run,
)
from .registry import RULES, Rule, rule  # noqa: F401
