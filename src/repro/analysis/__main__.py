"""CLI for fslint: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean; 1 findings, unused suppressions, or stale baseline
entries; 2 usage error.  ``--format=json`` prints one machine-readable
object (what CI archives); the default human format prints one
``path:line:col: [rule] message`` line per finding, ruff/gcc style.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import DEFAULT_BASELINE, run
from .registry import RULES, active_rules


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__.splitlines()[0],
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files/directories to analyze (repo-relative; default: whole tree)",
    )
    ap.add_argument("--format", choices=("human", "json"), default="human")
    ap.add_argument(
        "--select",
        help="comma-separated rule names to run (default: all)",
    )
    ap.add_argument(
        "--no-scope",
        action="store_true",
        help="apply selected rules to every analyzed file, ignoring per-rule "
        "path scopes (fixture/debug use)",
    )
    ap.add_argument(
        "--baseline",
        default=str(DEFAULT_BASELINE),
        help="baseline JSON (pass '' to disable baseline subtraction)",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="rewrite the baseline to accept every current finding (a "
        "deliberate act: the diff shows exactly what debt was taken on)",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        # force registration
        from . import rules as _rules  # noqa: F401

        for r in RULES.values():
            print(f"{r.name:16s} {r.description}")
            for pat in r.scope:
                print(f"{'':16s}   scope: {pat}")
        return 0

    select = args.select.split(",") if args.select else None
    try:
        active_rules(select)
    except KeyError as e:
        print(e.args[0], file=sys.stderr)
        return 2

    baseline = Path(args.baseline) if args.baseline else None
    result = run(
        args.paths or None,
        select=select,
        ignore_scope=args.no_scope,
        # when rewriting the baseline, capture ALL current findings — the old
        # baseline must not subtract entries out of the rewrite
        baseline=None if args.write_baseline else baseline,
    )

    if args.write_baseline:
        if baseline is None:
            print("--write-baseline needs --baseline", file=sys.stderr)
            return 2
        entries = [
            {"rule": f.rule, "path": f.path, "message": f.message}
            for f in result.findings
        ]
        baseline.write_text(
            json.dumps({"version": 1, "findings": entries}, indent=1) + "\n"
        )
        print(f"wrote {len(entries)} baseline entries to {baseline}")
        return 0

    if args.format == "json":
        print(json.dumps(result.as_dict(), indent=1))
    else:
        for f in result.findings:
            print(f.render())
        for s in result.unused_suppressions:
            print(
                f"{s.path}:{s.line}:1: [unused-suppression] disable="
                f"{','.join(s.rules)} suppressed nothing — delete it"
            )
        for fp in result.stale_baseline:
            print(f"baseline: stale entry {fp!r} — finding no longer exists")
        n = len(result.findings)
        print(
            f"fslint: {result.files_scanned} files, "
            f"{len(result.rules_run)} rules, {n} finding(s), "
            f"{len(result.unused_suppressions)} unused suppression(s), "
            f"{len(result.stale_baseline)} stale baseline entr(ies)"
        )
    return 0 if result.clean else 1


if __name__ == "__main__":
    sys.exit(main())
