"""Rule ``format``: the executable slice of the ruff-format gate.

History: since PR 3 the CI workflow has declared ``ruff format --check``
over an ever-widening tree, but ruff cannot install in the build container,
so every PR verified the gate "best-effort" with hand-rolled approximations
— the declared-vs-executed gap ROADMAP's standing CI item admits.  This
tokenize-based probe EXECUTES the mechanically-checkable portion of that
gate everywhere Python runs, scoped to exactly the trees the workflow's
``ruff format --check`` step claims (``src/repro/core``,
``src/repro/kernels``, ``src/repro/models``, ``benchmarks/``):

* line length <= 88 (``pyproject.toml`` ``line-length``) — stricter than
  the formatter itself, which leaves long comments/strings alone, so the
  ruff-format gate could pass a line this probe flags; the repo's
  convention is 88 for those too, and the pragma escape exists for the
  rare unsplittable literal;
* double quotes for string literals (``quote-style = "double"``), except
  strings whose body contains a double quote — ruff keeps single quotes
  there to avoid escaping;
* no trailing whitespace.
"""

from __future__ import annotations

import tokenize

from .. import registry

_MAX_LEN = 88
_PREFIX_CHARS = "rbfuRBFU"


@registry.rule(
    "format",
    scope=(
        "src/repro/core/*.py",
        "src/repro/kernels/*.py",
        "src/repro/kernels/*/*.py",
        "src/repro/models/*.py",
        "benchmarks/*.py",
    ),
    description="executed format gate for the ruff-format-claimed trees: "
    "<=88-char lines, double quotes, no trailing whitespace",
)
def check(ctx, project):
    for i, line in enumerate(ctx.lines, start=1):
        if len(line) > _MAX_LEN:
            yield ctx.finding(
                "format",
                i,
                f"line is {len(line)} chars (> {_MAX_LEN}); wrap it "
                f"(ruff line-length)",
                col=_MAX_LEN,
            )
        if line != line.rstrip():
            yield ctx.finding(
                "format",
                i,
                "trailing whitespace",
                col=len(line.rstrip()),
            )
    for tok in ctx.tokens:
        if tok.type != tokenize.STRING:
            continue
        body = tok.string.lstrip(_PREFIX_CHARS)
        if body.startswith("'"):
            quote = "'''" if body.startswith("'''") else "'"
            inner = body[len(quote) : -len(quote)]
            if '"' not in inner:
                yield ctx.finding(
                    "format",
                    tok.start[0],
                    "single-quoted string; the format gate's quote-style "
                    'is "double"',
                    col=tok.start[1],
                )
