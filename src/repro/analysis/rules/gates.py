"""Rule ``vacuous-gate``: a CI gate that cannot fail is worse than no gate.

History: PR 8 found the standalone bench-regression CI step passing
vacuously whenever ``results/bench_fast.json`` was missing — the exact
failure mode (bench smoke silently dead upstream) the gate existed to
catch — and the upload step was configured to ignore the same absence.
MLOps mapping studies call this the declared-vs-executed quality-gate gap;
this rule closes the Python side of it for the gate surfaces
(``benchmarks/`` and ``scripts/``):

* an ``except`` that swallows broadly — bare / ``Exception`` /
  ``BaseException`` with a body that is only ``pass`` — hides the crash
  that should have failed the gate (narrow except-pass is fine: killing an
  already-dead pid legitimately ignores ``ProcessLookupError``);
* ``continue`` or ``return True``/``return 0`` as the entire body of ANY
  except handler silently skips the section that just failed;
* ``return True`` guarded by a file-absence test (``.exists()`` /
  ``.is_file()`` / ``os.path.exists``/``isfile``) passes the gate exactly
  when its input is missing;
* ``assert <constant>`` asserts nothing.
"""

from __future__ import annotations

import ast

from .. import registry
from ._ast_util import terminal_attr

_BROAD = {"Exception", "BaseException"}
_EXISTENCE = {"exists", "is_file", "isfile", "is_dir", "isdir"}


def _handler_types(h: ast.ExceptHandler) -> list[str]:
    t = h.type
    if t is None:
        return ["<bare>"]
    elts = t.elts if isinstance(t, ast.Tuple) else [t]
    return [terminal_attr(e) or "<expr>" for e in elts]


def _only(body: list[ast.stmt], kind) -> ast.stmt | None:
    real = [s for s in body if not _is_docstring(s)]
    if len(real) == 1 and isinstance(real[0], kind):
        return real[0]
    return None


def _is_docstring(s: ast.stmt) -> bool:
    return (
        isinstance(s, ast.Expr)
        and isinstance(s.value, ast.Constant)
        and isinstance(s.value.value, str)
    )


def _is_vacuous_return(s: ast.stmt) -> bool:
    if not (isinstance(s, ast.Return) and isinstance(s.value, ast.Constant)):
        return False
    v = s.value.value
    # NOT `v in (True, 0)`: False == 0, and `return False` is a loud failure
    return v is True or (type(v) is int and v == 0)


def _mentions_existence_check(test: ast.AST) -> bool:
    for n in ast.walk(test):
        if isinstance(n, ast.Call) and terminal_attr(n.func) in _EXISTENCE:
            return True
    return False


@registry.rule(
    "vacuous-gate",
    scope=("benchmarks/*.py", "scripts/*.py"),
    description="gate code must fail loudly: no swallow-and-continue "
    "excepts, no pass-on-missing-artifact, no constant asserts "
    "(the PR-8 vacuous bench-regression step)",
)
def check(ctx, project):
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ExceptHandler):
            types = _handler_types(node)
            swallowed = _only(node.body, ast.Pass)
            if swallowed is not None and (set(types) & _BROAD or "<bare>" in types):
                yield ctx.finding(
                    "vacuous-gate",
                    node,
                    f"except {'/'.join(types)} swallowed with bare 'pass' — "
                    f"the crash this hides is exactly what the gate should "
                    f"report; narrow the exception or handle it loudly",
                )
            skipper = _only(node.body, (ast.Continue, ast.Return))
            if skipper is not None and (
                isinstance(skipper, ast.Continue) or _is_vacuous_return(skipper)
            ):
                what = (
                    "continue"
                    if isinstance(skipper, ast.Continue)
                    else f"return {skipper.value.value!r}"
                )
                yield ctx.finding(
                    "vacuous-gate",
                    node,
                    f"except {'/'.join(types)} answers failure with "
                    f"'{what}' — the gated section is silently skipped on "
                    f"error; record a failure instead",
                )
        elif isinstance(node, ast.If) and _mentions_existence_check(node.test):
            for branch in (node.body, node.orelse):
                for s in branch:
                    if _is_vacuous_return(s):
                        yield ctx.finding(
                            "vacuous-gate",
                            s,
                            "a file-existence test guards a success return — "
                            "a missing artifact makes this gate pass "
                            "vacuously; fail loudly when the input is absent",
                        )
        elif isinstance(node, ast.Assert):
            t = node.test
            if isinstance(t, ast.Constant) or (
                isinstance(t, ast.Tuple) and t.elts
            ):
                yield ctx.finding(
                    "vacuous-gate",
                    node,
                    "assert on a constant can never fail (or always fails); "
                    "assert the measured quantity instead",
                )
