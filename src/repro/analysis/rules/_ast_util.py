"""Small shared AST helpers for the rule modules."""

from __future__ import annotations

import ast
from typing import Iterator, Optional


def dotted_name(node: ast.AST) -> Optional[str]:
    """``np.random.default_rng`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def terminal_attr(node: ast.AST) -> Optional[str]:
    """The last attribute segment of a call target (``x.y.pack`` -> ``pack``;
    bare ``pack`` -> ``pack``)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def attr_root(node: ast.AST) -> Optional[str]:
    """The leftmost name of an attribute chain (``self.x.y`` -> ``self``)."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = (
            node.value
            if isinstance(node, (ast.Attribute, ast.Subscript))
            else node.func
        )
    if isinstance(node, ast.Name):
        return node.id
    return None


def functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def statements_in_order(fn: ast.FunctionDef) -> list[ast.stmt]:
    """Every statement lexically inside ``fn`` (excluding nested function
    bodies), in source order — the linear approximation the local dataflow
    rules (aliasing, donation) walk."""
    out: list[ast.stmt] = []

    def visit(body: list[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            out.append(stmt)
            for field in ("body", "orelse", "finalbody"):
                visit(getattr(stmt, field, []) or [])
            for h in getattr(stmt, "handlers", []) or []:
                visit(h.body)

    visit(fn.body)
    return sorted(out, key=lambda s: (s.lineno, s.col_offset))


def names_loaded(node: ast.AST) -> set[str]:
    return {
        n.id
        for n in ast.walk(node)
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
    }


def names_stored(stmt: ast.stmt) -> set[str]:
    return {
        n.id
        for n in ast.walk(stmt)
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)
    }
