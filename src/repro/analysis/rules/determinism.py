"""Rule ``determinism``: no wall clock, no unseeded RNG on the replay surface.

History: PR 7's whole chaos design rests on byte-for-byte replay from one
integer seed — every fault decision is a pure splitmix64 hash, backoff
jitter runs over LOGICAL drain ticks, and the bench gates every chaos count
EXACTLY.  One ``time.time()`` or module-state RNG call on that surface turns
the deterministic ledger into flaky noise.  The surface is the replication
data plane (channel/replication/wire/multihome), the daemon's protocol
module, and the chaos/shard test suites.

Banned: ``time.time``, ``datetime.now``/``utcnow``/``today``, any
``np.random.*`` except a seeded ``default_rng(seed)`` / explicit
``Generator``/bit-generator construction, and every module-level
``random.*`` call (``random.Random(seed)`` instances are fine — they carry
their seed).  Deliberately NOT banned: ``time.monotonic``/``perf_counter``/
``sleep`` — the daemon times out real sockets with real clocks; wall-clock
*measurement* is fine, wall-clock *decision input to replayed logic* is not
(timeouts on a real link are already outside the replay boundary).
"""

from __future__ import annotations

import ast

from .. import registry
from ._ast_util import dotted_name

_WALL_CLOCK = {
    "time.time": "wall clock",
    "time.time_ns": "wall clock",
    "datetime.now": "wall clock",
    "datetime.utcnow": "wall clock",
    "datetime.today": "wall clock",
    "datetime.datetime.now": "wall clock",
    "datetime.datetime.utcnow": "wall clock",
    "datetime.datetime.today": "wall clock",
    "datetime.date.today": "wall clock",
}

#: np.random attributes that construct an explicitly-seeded generator (the
#: seed argument is checked separately for default_rng)
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64", "Philox"}


@registry.rule(
    "determinism",
    scope=(
        "src/repro/core/channel.py",
        "src/repro/core/replication.py",
        "src/repro/core/wire.py",
        "src/repro/core/daemon.py",
        "src/repro/core/multihome.py",
        "tests/core/test_chaos.py",
        "tests/core/test_shards.py",
    ),
    description="no wall clock / unseeded RNG on the deterministic-replay "
    "surface (PR 7's byte-replayable chaos contract)",
)
def check(ctx, project):
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name is None:
            continue
        if name in _WALL_CLOCK:
            yield ctx.finding(
                "determinism",
                node,
                f"{name}() is a wall clock on the deterministic-replay "
                f"surface; derive times from the logical clock / modeled "
                f"latency instead",
            )
        elif name.startswith(("np.random.", "numpy.random.")):
            attr = name.rsplit(".", 1)[1]
            if attr not in _NP_RANDOM_OK:
                yield ctx.finding(
                    "determinism",
                    node,
                    f"{name}() draws from numpy's module-level RNG state; "
                    f"use an explicitly seeded np.random.default_rng(seed)",
                )
            elif attr == "default_rng" and not (node.args or node.keywords):
                yield ctx.finding(
                    "determinism",
                    node,
                    "np.random.default_rng() without a seed is entropy-"
                    "seeded; pass the scenario seed explicitly",
                )
        elif name.startswith("random."):
            attr = name.split(".", 1)[1]
            if attr == "Random" and (node.args or node.keywords):
                continue  # seeded instance carries its seed
            yield ctx.finding(
                "determinism",
                node,
                f"{name}() uses process-global RNG state on the "
                f"deterministic-replay surface; use a seeded "
                f"np.random.default_rng(seed) or random.Random(seed)",
            )
