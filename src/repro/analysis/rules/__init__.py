"""Rule modules.  Importing this package registers every rule.

Each module owns one invariant and opens with the history that made it a
rule — the PR whose bug (or whose design contract) it locks in.  Add a new
rule by dropping a module here, decorating its checker with
``@registry.rule(...)``, and importing it below; the fixture suite in
``tests/analysis`` expects every rule to ship a positive fixture (the bug,
reproduced) and a negative fixture (the shipped fix).
"""

from . import (  # noqa: F401
    aliasing,
    determinism,
    donation,
    formatting,
    gates,
    gauges,
    stats,
    wire_format,
)
