"""Rule ``frozen-stats``: public stats surfaces return typed frozen objects.

History: PR 9 replaced the loose stats dicts threaded through the tree
(merge stats, lag, ship ledgers) with frozen dataclasses — ``MergeStats``,
``LagStats``/``PlaneLag``, ``ShipLedger``/``PlaneShip`` — because every
stringly-keyed dict consumer was one typo away from a silent ``KeyError``/
``None`` and none of it was discoverable.  This rule locks the refactor in:
a public ``core/`` function may not return a bare dict literal whose keys
reproduce the fields of an existing frozen stats dataclass — that is the
typed result, downgraded.

Mechanics: the project pre-pass collects every ``@dataclass(frozen=True)``
under ``src/repro`` with its field names.  A ``return {...}`` in a public
function (no leading underscore, not a serialization boundary —
``snapshot``/``to_dict``/``as_dict``/``to_json`` names are exempt, dicts
are their job) whose literal has >= 3 constant string keys ALL drawn from
one frozen dataclass's fields is flagged with the dataclass it shadows.
"""

from __future__ import annotations

import ast

from .. import registry
from ._ast_util import functions

_SERIALIZATION_NAMES = {"snapshot", "to_dict", "as_dict", "to_json", "as_json"}
_MIN_KEYS = 3


@registry.rule(
    "frozen-stats",
    scope=("src/repro/core/*.py",),
    description="public core/ functions return the frozen stats dataclass, "
    "not a bare dict literal shadowing its fields (PR-9 "
    "typed-stats refactor)",
)
def check(ctx, project):
    if not project.frozen_dataclasses:
        return
    for fn in functions(ctx.tree):
        if fn.name.startswith("_") or fn.name in _SERIALIZATION_NAMES:
            continue
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Return) and isinstance(node.value, ast.Dict)):
                continue
            d = node.value
            keys = []
            for k in d.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    keys.append(k.value)
                else:
                    keys = None  # dynamic/**-expanded keys: not a bare literal
                    break
            if not keys or len(keys) < _MIN_KEYS:
                continue
            keyset = set(keys)
            for name, fields in project.frozen_dataclasses.items():
                if keyset <= fields:
                    yield ctx.finding(
                        "frozen-stats",
                        node,
                        f"{fn.name}() returns a bare dict whose keys "
                        f"({', '.join(sorted(keyset))}) are fields of the "
                        f"frozen dataclass {name}; return {name} instead",
                    )
                    break
