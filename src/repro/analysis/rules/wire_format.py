"""Rule ``wire-format``: explicit endianness; every magic is dispatched.

History: the wire codec (PR 5) and the stream framing (PR 8) are the bytes
that cross machines.  ``struct`` formats without a byte-order prefix use
NATIVE order and alignment — a frame encoded on one architecture stops
decoding on another, and native alignment silently pads records.  Every
format string on the wire surface must therefore be little-endian-explicit
(``<``).  And every frame-kind magic (``MAGIC``/``CONTROL_MAGIC``/
``ACK_MAGIC``-style constants) must be dispatched by ``StreamDecoder`` —
a kind that encodes but never decodes is a frame the replica drops on the
floor after a resync (the decoder treats unknown magics as torn-stream
garbage, which is correct exactly because this rule guarantees there are
no legitimate unknown kinds).
"""

from __future__ import annotations

import ast

from .. import registry
from ._ast_util import dotted_name

_STRUCT_FNS = {"pack", "unpack", "pack_into", "unpack_from", "calcsize", "iter_unpack"}


def _format_arg(call: ast.Call) -> ast.AST | None:
    """The format-string argument of a struct call, if this call carries
    one: ``struct.pack(fmt, ...)`` / ``struct.Struct(fmt)``.  Method calls
    on a prebuilt Struct instance (``_U32.pack(...)``) carry no format and
    are governed at their construction site."""
    fn = call.func
    name = dotted_name(fn)
    if name is not None and name.startswith("struct."):
        tail = name.rsplit(".", 1)[1]
        if tail in _STRUCT_FNS or tail == "Struct":
            return call.args[0] if call.args else None
    return None


def _format_is_little_endian(arg: ast.AST) -> bool | None:
    """True/False when the first character is statically known, else None."""
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return arg.value.startswith("<")
    if isinstance(arg, ast.JoinedStr) and arg.values:
        head = arg.values[0]
        if isinstance(head, ast.Constant) and isinstance(head.value, str):
            return head.value.startswith("<")
    return None


@registry.rule(
    "wire-format",
    scope=("src/repro/core/wire.py", "src/repro/core/daemon.py"),
    description="struct formats on the wire surface must be little-endian-"
    "explicit ('<'), and every frame kind magic must appear in "
    "StreamDecoder's dispatch",
)
def check(ctx, project):
    # -- endianness -----------------------------------------------------------
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fmt = _format_arg(node)
        if fmt is None:
            continue
        verdict = _format_is_little_endian(fmt)
        if verdict is False:
            yield ctx.finding(
                "wire-format",
                fmt,
                f"struct format {ast.unparse(fmt)} has no '<' byte-order "
                f"prefix — native order/alignment does not survive the "
                f"wire; make it little-endian-explicit",
            )
        elif verdict is None:
            yield ctx.finding(
                "wire-format",
                fmt,
                f"struct format {ast.unparse(fmt)} is dynamic and its "
                f"byte-order prefix cannot be checked; start it with a "
                f"literal '<'",
            )

    # -- magic dispatch (only meaningful where StreamDecoder lives) ----------
    magics: dict[str, ast.Assign] = {}
    tuples: dict[str, list[str]] = {}
    decoder: ast.ClassDef | None = None
    for node in ctx.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if not isinstance(tgt, ast.Name):
                continue
            if "MAGIC" in tgt.id and isinstance(node.value, ast.Constant) and isinstance(
                node.value.value, bytes
            ):
                magics[tgt.id] = node
            elif isinstance(node.value, (ast.Tuple, ast.List)):
                names = [
                    e.id for e in node.value.elts if isinstance(e, ast.Name)
                ]
                if names:
                    tuples[tgt.id] = names
        elif isinstance(node, ast.ClassDef) and node.name == "StreamDecoder":
            decoder = node
    if decoder is None or not magics:
        return
    referenced = {
        n.id
        for n in ast.walk(decoder)
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
    }
    # expand one level of indirection: a tuple of magics referenced by the
    # decoder (e.g. _STREAM_MAGICS) dispatches its members
    for tup, members in tuples.items():
        if tup in referenced:
            referenced.update(members)
    for name, assign in magics.items():
        if name not in referenced:
            yield ctx.finding(
                "wire-format",
                assign,
                f"frame kind magic {name} is never dispatched by "
                f"StreamDecoder — frames of this kind are dropped as torn-"
                f"stream garbage on the receive path; add it to the "
                f"decoder's dispatch (and its magic tuple)",
            )
