"""Rule ``gauge-keys``: metric keys are /-segmented; match them segment-wise.

History: PR 9.  ``HealthMonitor.clear_replica_gauges`` matched the replica
name as a raw substring/suffix of gauge keys, so clearing ``r1`` touched
``r11``'s gauges (the substring trap) while per-shard keys that put the
replica MID-path (``replication/shard_lag_batches/{replica}/{shard}``)
were missed entirely — a rejoined region resurrected its pre-eviction lag
readings.  The shipped fix splits the key on ``/`` and matches the replica
as a full segment.  Two sub-checks lock that in:

* keys handed to ``set_gauge``/``inc``/``observe``/``observe_batch`` must be
  string literals or f-strings (the /-segmented shapes the monitor
  documents), never ``+``/``%``/``.format`` concatenations — those are how
  un-segmentable keys get minted;
* any identity test against a metric-key loop variable (a variable iterating
  ``gauges``/``counters``/``histograms``) must be segment-wise: bare
  ``x in key`` substring membership and ``key.startswith/endswith(<dynamic>)``
  are flagged (``key.split("/")`` membership and literal namespace prefixes
  like ``"replication/"`` pass).
"""

from __future__ import annotations

import ast

from .. import registry
from ._ast_util import terminal_attr

_RECORDERS = {"set_gauge", "inc", "observe", "observe_batch"}
_METRIC_STORES = {"gauges", "counters", "histograms"}


def _metric_key_vars(tree: ast.AST) -> dict[str, ast.AST]:
    """Loop/comprehension variables that iterate a metrics mapping."""
    out: dict[str, ast.AST] = {}

    def iter_mentions_store(it: ast.AST) -> bool:
        for n in ast.walk(it):
            name = terminal_attr(n) if isinstance(n, (ast.Attribute, ast.Name)) else None
            if name in _METRIC_STORES:
                return True
        return False

    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            if iter_mentions_store(node.iter) and isinstance(node.target, ast.Name):
                out[node.target.id] = node
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp, ast.DictComp)):
            for gen in node.generators:
                if iter_mentions_store(gen.iter) and isinstance(gen.target, ast.Name):
                    out[gen.target.id] = node
    return out


@registry.rule(
    "gauge-keys",
    scope=(
        "src/repro/core/monitoring.py",
        "src/repro/core/replication.py",
        "src/repro/core/multihome.py",
        "src/repro/core/serving.py",
        "src/repro/core/regions.py",
    ),
    description="metric keys are /-segmented literals/f-strings and are "
    "matched segment-wise, never by substring (the PR-9 r1-vs-r11 "
    "clear_replica_gauges trap)",
)
def check(ctx, project):
    key_vars = _metric_key_vars(ctx.tree)

    for node in ast.walk(ctx.tree):
        # -- sub-check 1: key construction at the recorder call site --------
        if isinstance(node, ast.Call):
            meth = terminal_attr(node.func)
            if (
                meth in _RECORDERS
                and isinstance(node.func, ast.Attribute)
                and node.args
            ):
                key = node.args[0]
                if isinstance(key, ast.BinOp) or (
                    isinstance(key, ast.Call)
                    and terminal_attr(key.func) == "format"
                ):
                    yield ctx.finding(
                        "gauge-keys",
                        key,
                        f"metric key for .{meth}() is built by concatenation/"
                        f".format(); use a /-segmented literal or f-string so "
                        f"segment-wise matching stays possible",
                    )
        # -- sub-check 2: identity tests on metric-key variables -------------
        if isinstance(node, ast.Compare):
            for op, comparator in zip(node.ops, node.comparators):
                if not isinstance(op, (ast.In, ast.NotIn)):
                    continue
                if (
                    isinstance(comparator, ast.Name)
                    and comparator.id in key_vars
                ):
                    yield ctx.finding(
                        "gauge-keys",
                        node,
                        f"substring membership on metric key "
                        f"{comparator.id!r} confuses 'r1' with 'r11'; match "
                        f"full segments: x in {comparator.id}.split(\"/\")",
                    )
        if isinstance(node, ast.Call):
            meth = terminal_attr(node.func)
            if meth in ("startswith", "endswith") and isinstance(
                node.func, ast.Attribute
            ):
                target = node.func.value
                if (
                    isinstance(target, ast.Name)
                    and target.id in key_vars
                    and node.args
                ):
                    arg = node.args[0]
                    is_literal = isinstance(arg, ast.Constant) and isinstance(
                        arg.value, str
                    )
                    anchored = is_literal and (
                        arg.value.endswith("/")
                        if meth == "startswith"
                        else arg.value.startswith("/")
                    )
                    if not anchored:
                        dyn = "dynamic value" if not is_literal else repr(arg.value)
                        yield ctx.finding(
                            "gauge-keys",
                            node,
                            f"{meth}({dyn}) on metric key {target.id!r} is "
                            f"not segment-anchored (PR-9: suffix matching "
                            f"missed mid-path replica segments); match "
                            f"against {target.id}.split(\"/\") or anchor the "
                            f"literal with '/'",
                        )
