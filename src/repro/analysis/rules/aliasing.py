"""Rule ``aliasing``: long-lived ``self.*`` state must not alias caller arrays.

History: PR 5.  ``ReplicationLog.append`` stored ``np.asarray(keys, ...)``
in the logged batch — ``asarray`` is a no-copy view when dtype already
matches, so the log aliased the publisher's LIVE merge buffers, and a
publisher reusing its arrays rewrote history that replicas had yet to
drain.  The fix (``_frozen_copy``) copies and sets ``writeable=False``.
This rule is that bug as an invariant on the retention surfaces (the
replication log and the serving front's queues/cache): an array that flows
into ``self.*`` state — directly, or via an object appended to a ``self.*``
container — must be defensively copied, not ``asarray``'d, and never a bare
parameter store.

Detection is a linear per-function taint walk (source order, one pass —
deliberately simple; the suppression pragma exists for code the walk
misjudges, and the fixture suite pins the PR-5 shape verbatim):

* taint sources: ``np.asarray`` / ``np.frombuffer`` / ``np.ascontiguousarray``
  calls (alias-on-match constructors), and function parameters annotated as
  arrays (``np.ndarray`` / ``ArrayLike``);
* taint flows through assignment when the RHS contains a tainted name or a
  taint source (one constructor call deep — the ``ReplicatedBatch(keys=
  np.asarray(...))`` shape), and clears when a name is rebound clean;
* sinks: ``self.X = <tainted>``, ``self....append/add/appendleft(<tainted>)``,
  ``self....[k] = <tainted>``.
"""

from __future__ import annotations

import ast

from .. import registry
from ._ast_util import (
    attr_root,
    dotted_name,
    functions,
    names_loaded,
    names_stored,
    statements_in_order,
    terminal_attr,
)

_ALIAS_CTORS = {
    "np.asarray",
    "numpy.asarray",
    "np.frombuffer",
    "numpy.frombuffer",
    "np.ascontiguousarray",
    "numpy.ascontiguousarray",
}
_ARRAYISH_ANNOTATIONS = ("ndarray", "ArrayLike")
_APPEND_METHODS = {"append", "appendleft", "add", "insert"}


def _alias_calls(node: ast.AST) -> list[ast.Call]:
    return [
        n
        for n in ast.walk(node)
        if isinstance(n, ast.Call) and dotted_name(n.func) in _ALIAS_CTORS
    ]


def _is_self_target(node: ast.AST) -> bool:
    """``self.x``, ``self.x.y``, ``self.x[k]`` as an assignment target."""
    if isinstance(node, (ast.Attribute, ast.Subscript)):
        return attr_root(node) == "self"
    return False


def _array_params(fn: ast.FunctionDef) -> set[str]:
    out = set()
    args = fn.args
    for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs):
        ann = a.annotation
        if ann is None:
            continue
        text = ast.unparse(ann)
        if any(tag in text for tag in _ARRAYISH_ANNOTATIONS):
            out.add(a.arg)
    return out


@registry.rule(
    "aliasing",
    scope=(
        "src/repro/core/replication.py",
        "src/repro/core/serving.py",
    ),
    description="retained self.* state must copy caller arrays, not alias "
    "them via np.asarray / bare parameter stores (the PR-5 "
    "ReplicationLog.append bug)",
)
def check(ctx, project):
    for fn in functions(ctx.tree):
        arr_params = _array_params(fn)
        tainted: dict[str, str] = {}  # name -> why
        for p in arr_params:
            tainted[p] = f"parameter {p!r} (array-annotated caller buffer)"
        for stmt in statements_in_order(fn):
            # -- sinks first: flag uses, then update taint for this stmt ----
            if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (
                    stmt.targets
                    if isinstance(stmt, ast.Assign)
                    else [stmt.target]
                )
                value = stmt.value
                if value is not None:
                    for tgt in targets:
                        if not _is_self_target(tgt):
                            continue
                        for call in _alias_calls(value):
                            yield ctx.finding(
                                "aliasing",
                                call,
                                f"{dotted_name(call.func)} result stored in "
                                f"long-lived {ast.unparse(tgt)} aliases the "
                                f"caller's buffer; copy it (np.array(..., "
                                f"copy=True) / a frozen-copy constructor)",
                            )
                        why = _tainted_reason(value, tainted)
                        if not _alias_calls(value) and why:
                            yield ctx.finding(
                                "aliasing",
                                stmt,
                                f"{ast.unparse(tgt)} retains {why} without a "
                                f"defensive copy; the caller can mutate it "
                                f"after publish",
                            )
            elif isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
                call = stmt.value
                meth = terminal_attr(call.func)
                if (
                    meth in _APPEND_METHODS
                    and isinstance(call.func, ast.Attribute)
                    and attr_root(call.func.value) == "self"
                ):
                    for a in call.args:
                        why = _tainted_reason(a, tainted)
                        if why:
                            yield ctx.finding(
                                "aliasing",
                                call,
                                f"self-container .{meth}() retains {why} "
                                f"without a defensive copy; the caller can "
                                f"mutate it after publish",
                            )
            # -- taint update ----------------------------------------------
            if isinstance(stmt, ast.Assign) and stmt.value is not None:
                reason = _taint_of(stmt.value, tainted)
                for tgt in stmt.targets:
                    for name in _simple_store_names(tgt):
                        if reason:
                            tainted[name] = reason
                        else:
                            tainted.pop(name, None)
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                reason = _taint_of(stmt.value, tainted)
                for name in _simple_store_names(stmt.target):
                    if reason:
                        tainted[name] = reason
                    else:
                        tainted.pop(name, None)
            else:
                for name in names_stored(stmt):
                    # loop vars / with targets / etc.: conservatively clean
                    if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                        tainted.pop(name, None)


def _simple_store_names(tgt: ast.AST) -> list[str]:
    if isinstance(tgt, ast.Name):
        return [tgt.id]
    if isinstance(tgt, (ast.Tuple, ast.List)):
        return [e.id for e in tgt.elts if isinstance(e, ast.Name)]
    return []


#: calls that reduce an array to a scalar/fresh object — taint stops here
_SCALAR_FNS = {"len", "int", "float", "bool", "str", "sum", "abs", "repr", "round"}


def _taint_of(value: ast.AST, tainted: dict[str, str]) -> str | None:
    """Why the RHS is tainted, or None.  ``.copy()`` anywhere in the RHS is
    treated as the cleansing act (np.array() copies by default too)."""
    text = ast.unparse(value)
    if ".copy()" in text or "copy=True" in text or "_frozen_copy" in text:
        return None
    calls = _alias_calls(value)
    if calls:
        return f"an un-copied {dotted_name(calls[0].func)} view"
    return _tainted_reason(value, tainted)


def _tainted_reason(node: ast.AST, tainted: dict[str, str]) -> str | None:
    """Taint propagates only through VALUE-PRESERVING expression shapes — a
    bare name, a view of it (subscript/attribute), a container literal
    holding it, or a call retaining it as a direct argument.  Arithmetic,
    comparisons, and scalar builtins (``len(ids)``) produce fresh objects
    and stop the taint."""
    if isinstance(node, ast.Name):
        return tainted.get(node.id)
    if isinstance(node, (ast.Subscript, ast.Attribute, ast.Starred)):
        return _tainted_reason(node.value, tainted)
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for el in node.elts:
            why = _tainted_reason(el, tainted)
            if why:
                return why
        return None
    if isinstance(node, ast.Dict):
        for v in node.values:
            why = _tainted_reason(v, tainted)
            if why:
                return why
        return None
    if isinstance(node, ast.IfExp):
        return _tainted_reason(node.body, tainted) or _tainted_reason(
            node.orelse, tainted
        )
    if isinstance(node, ast.NamedExpr):
        return _tainted_reason(node.value, tainted)
    if isinstance(node, ast.Call):
        fn = terminal_attr(node.func)
        if fn in _SCALAR_FNS:
            return None
        for a in list(node.args) + [kw.value for kw in node.keywords]:
            why = _tainted_reason(a, tainted)
            if why:
                return why
    return None
