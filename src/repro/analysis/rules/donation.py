"""Rule ``donation``: never touch a buffer after passing it in a donated slot.

History: PR 2 made device memory the source of truth — the online-merge
scatter is jitted with ``donate_argnums`` so XLA rewrites the table planes
in place.  Donation invalidates the caller's handle: reading a donated jax
array afterwards raises (at best) or silently reads garbage in dispatch
paths that skip the check.  PR 2 left the discipline implicit in the call
sites; this rule makes it structural for the device-plane modules
(``core/online_store.py`` and the kernels tree).

Mechanics: the engine's project pre-pass records every function jitted with
literal ``donate_argnums`` (decorator ``@functools.partial(jax.jit,
donate_argnums=...)`` or ``g = jax.jit(f, donate_argnums=...)``).  At each
call site of a known donating function, any plain-name argument in a
donated position is dead after the call statement: a later load of that
name in the same function — before a rebinding — is flagged.  Non-name
donated arguments (``jnp.asarray(x)``, ``*splat``) are fresh temporaries
the caller cannot re-touch and are skipped.
"""

from __future__ import annotations

import ast

from .. import registry
from ._ast_util import (
    functions,
    names_loaded,
    names_stored,
    statements_in_order,
    terminal_attr,
)


@registry.rule(
    "donation",
    scope=(
        "src/repro/core/online_store.py",
        "src/repro/kernels/*/*.py",
        "src/repro/kernels/*.py",
    ),
    description="no use of a variable after it was passed in a "
    "donate_argnums position (use-after-donate reads freed "
    "device memory)",
)
def check(ctx, project):
    if not project.donated:
        return
    for fn in functions(ctx.tree):
        stmts = statements_in_order(fn)
        # donated name -> (donating callee, call line) awaiting a later use
        dead: dict[str, tuple[str, int]] = {}
        for stmt in stmts:
            # a later *load* of a dead name is the violation; check before
            # this statement's own donations/rebinds take effect
            loaded = names_loaded(stmt)
            for name in sorted(dead.keys() & loaded):
                callee, line = dead[name]
                yield ctx.finding(
                    "donation",
                    stmt,
                    f"{name!r} was donated to {callee}() on line {line} "
                    f"(donate_argnums); its buffer no longer exists — "
                    f"rebind it from the call's result or copy before the "
                    f"call",
                )
                del dead[name]  # one report per donation is enough
            for call in ast.walk(stmt):
                if not isinstance(call, ast.Call):
                    continue
                callee = terminal_attr(call.func)
                if callee not in project.donated:
                    continue
                for pos in project.donated[callee]:
                    if pos < len(call.args):
                        arg = call.args[pos]
                        if isinstance(arg, ast.Name):
                            dead[arg.id] = (callee, call.lineno)
            # stores clear LAST: ``x = donating(x)`` rebinds the name to the
            # call's result, which is exactly how a caller revives a handle
            for name in names_stored(stmt):
                dead.pop(name, None)
