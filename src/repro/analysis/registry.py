"""Rule registry: names, scopes, and the decorator that wires a checker in.

A rule is a plain function ``check(ctx: FileContext, project: ProjectContext)
-> Iterable[Finding]`` plus metadata: a stable short name (what suppressions
and the baseline refer to), a one-line description (what ``--list-rules``
prints), and a SCOPE — repo-relative glob patterns naming the only files the
rule runs on.  Scoping is the precision lever: every rule here encodes an
invariant of a specific subsystem (the deterministic-replay surface, the
wire codec, the bench gates), and running it outside that subsystem would
manufacture false positives, so the default run applies each rule exactly
where its invariant holds.  ``repro.analysis.engine`` can override scoping
for fixture tests (``ignore_scope=True``).
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Callable, Iterable, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .engine import FileContext, Finding, ProjectContext

CheckFn = Callable[["FileContext", "ProjectContext"], Iterable["Finding"]]


@dataclasses.dataclass(frozen=True)
class Rule:
    """One registered invariant checker."""

    name: str
    description: str
    scope: tuple[str, ...]
    check: CheckFn

    def applies_to(self, rel_path: str) -> bool:
        return any(fnmatch.fnmatch(rel_path, pat) for pat in self.scope)


#: name -> Rule; populated at import time by the ``rule`` decorator below.
RULES: dict[str, Rule] = {}


def rule(name: str, *, scope: tuple[str, ...], description: str):
    """Register ``fn`` as the checker for rule ``name``.

    ``scope`` patterns are repo-relative posix paths matched with fnmatch
    (``src/repro/core/wire.py``, ``benchmarks/*.py``, ``src/repro/kernels/*``).
    """

    def deco(fn: CheckFn) -> CheckFn:
        if name in RULES:
            raise ValueError(f"duplicate rule name: {name}")
        RULES[name] = Rule(
            name=name, description=description, scope=scope, check=fn
        )
        return fn

    return deco


def active_rules(select: Iterable[str] | None = None) -> list[Rule]:
    """The rule set for one run, in registration order.

    ``select`` (names) narrows the set; unknown names raise so a typo in
    ``--select`` cannot silently skip the check it meant to run.
    """
    if select is None:
        return list(RULES.values())
    chosen = []
    for name in select:
        if name not in RULES:
            known = ", ".join(sorted(RULES))
            raise KeyError(f"unknown rule {name!r} (known: {known})")
        chosen.append(RULES[name])
    return chosen
