"""fslint engine: file walking, rule dispatch, suppressions, baseline.

Stdlib-only by design: the CI lint job runs this before any project
dependency is installed, so nothing in ``repro.analysis`` may import numpy,
jax, or any other third-party module — the checker must run anywhere a bare
CPython runs (this is the whole point: the ruff gate was "best-effort
verified, not executed" because ruff cannot install in the build container;
fslint executes).

Pipeline per run:

1. Walk the requested roots for ``*.py`` files (skipping ``__pycache__``,
   hidden directories, ``results/``, and the deliberately-broken fixture
   corpus under ``tests/analysis/fixtures``).
2. Pre-pass: build a ``ProjectContext`` over ALL scanned files — the
   cross-file facts rules need (frozen dataclass field sets for the
   frozen-stats rule; ``donate_argnums`` positions for the donation rule).
3. Per file: parse once (AST + tokens), run every rule whose scope matches,
   drop findings suppressed by an inline ``# fslint: disable=<rule>`` on the
   finding's line (or on a comment-only line directly above it).
4. Report unused suppressions — a disable comment whose rule ran on the file
   but suppressed nothing is dead weight that will hide a future regression,
   so it fails the run just like a finding.
5. Subtract the baseline (committed at ``src/repro/analysis/baseline.json``,
   EMPTY — the tree owes zero findings; the mechanism exists so a future
   emergency can land with a deliberate, visible debt).  Baseline entries
   that no longer match anything are reported as stale: the debt was paid,
   delete the entry.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Iterable, Optional

from .registry import Rule, active_rules

#: repo root inferred from this file living at src/repro/analysis/engine.py
REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_ROOTS = ("src", "benchmarks", "scripts", "tests", "examples")
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"
#: subtrees never scanned: the fixture corpus is deliberately-buggy code
#: (every rule's positive exemplar lives there), and results/ holds
#: generated artifacts
EXCLUDED_PARTS = ("__pycache__", "results")
EXCLUDED_SUBTREES = ("tests/analysis/fixtures",)

_SUPPRESS_RE = re.compile(r"#\s*fslint:\s*disable=([A-Za-z0-9_,\- ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str  # repo-relative posix path
    line: int  # 1-based
    col: int  # 0-based
    message: str

    def fingerprint(self) -> str:
        """Baseline identity: deliberately line-number-free so unrelated
        edits above a baselined finding do not churn the baseline."""
        return f"{self.rule}::{self.path}::{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: [{self.rule}] {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One inline ``# fslint: disable=rule[,rule...]`` comment."""

    path: str
    line: int  # line the comment sits on
    rules: tuple[str, ...]
    covers: tuple[int, ...]  # lines whose findings it silences


class FileContext:
    """Everything a rule may inspect about one file, parsed exactly once."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=rel)
        self.tokens = list(
            tokenize.generate_tokens(io.StringIO(source).readline)
        )

    def finding(self, rule: str, node_or_line, message: str, col: int = 0) -> Finding:
        """Build a Finding anchored at an AST node (preferred) or line no."""
        if isinstance(node_or_line, int):
            line = node_or_line
        else:
            line = getattr(node_or_line, "lineno", 1)
            col = getattr(node_or_line, "col_offset", col)
        return Finding(rule=rule, path=self.rel, line=line, col=col, message=message)


class ProjectContext:
    """Cross-file facts, built in one pre-pass over every scanned file.

    ``frozen_dataclasses``: dataclass name -> frozenset of field names, for
    every ``@dataclass(frozen=True)`` under ``src/repro`` — the frozen-stats
    rule matches returned dict literals against these.

    ``donated``: function name -> donated positional indices, for every
    definition jitted with ``donate_argnums`` (decorator form
    ``@functools.partial(jax.jit, donate_argnums=(...))`` or assignment form
    ``g = jax.jit(f, donate_argnums=(...))``) — the donation rule flags uses
    of a variable after it was passed in one of these positions.
    """

    def __init__(self) -> None:
        self.frozen_dataclasses: dict[str, frozenset[str]] = {}
        self.donated: dict[str, tuple[int, ...]] = {}

    # -- collection -----------------------------------------------------------
    def collect(self, ctx: FileContext) -> None:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef) and _is_frozen_dataclass(node):
                fields = frozenset(
                    t.target.id
                    for t in node.body
                    if isinstance(t, ast.AnnAssign) and isinstance(t.target, ast.Name)
                )
                if fields:
                    self.frozen_dataclasses[node.name] = fields
            elif isinstance(node, ast.FunctionDef):
                for deco in node.decorator_list:
                    pos = _donate_argnums(deco)
                    if pos is not None:
                        self.donated[node.name] = pos
            elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                pos = _donate_argnums(node.value)
                if pos is not None:
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            self.donated[tgt.id] = pos


def _is_frozen_dataclass(node: ast.ClassDef) -> bool:
    for deco in node.decorator_list:
        call = deco if isinstance(deco, ast.Call) else None
        if call is None:
            continue
        fn = call.func
        name = fn.attr if isinstance(fn, ast.Attribute) else getattr(fn, "id", "")
        if name != "dataclass":
            continue
        for kw in call.keywords:
            if (
                kw.arg == "frozen"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is True
            ):
                return True
    return False


def _donate_argnums(call: ast.AST) -> Optional[tuple[int, ...]]:
    """Donated positions from a ``jax.jit(..., donate_argnums=...)`` or
    ``functools.partial(jax.jit, donate_argnums=...)`` call, when the
    positions are literal ints (non-literal forms are ignored — the rule
    cannot reason about them statically)."""
    if not isinstance(call, ast.Call):
        return None
    mentions_jit = any(
        isinstance(n, (ast.Name, ast.Attribute))
        and (getattr(n, "id", None) == "jit" or getattr(n, "attr", None) == "jit")
        for n in ast.walk(call)
    )
    if not mentions_jit:
        return None
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)):
            out = []
            for el in v.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, int):
                    out.append(el.value)
                else:
                    return None
            return tuple(out)
    return None


# -- suppressions -------------------------------------------------------------


def parse_suppressions(ctx: FileContext) -> list[Suppression]:
    """Inline disables.  A comment on a code line covers that line; a
    comment standing alone on its own line covers the line below it (for
    statements where appending the pragma would fight the formatter)."""
    out = []
    for tok in ctx.tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        rules = tuple(
            r.strip() for r in m.group(1).split(",") if r.strip()
        )
        line = tok.start[0]
        comment_only = ctx.lines[line - 1].lstrip().startswith("#")
        covers = (line, line + 1) if comment_only else (line,)
        out.append(
            Suppression(path=ctx.rel, line=line, rules=rules, covers=covers)
        )
    return out


# -- baseline -----------------------------------------------------------------


def load_baseline(path: Path) -> list[dict]:
    data = json.loads(path.read_text())
    if not isinstance(data, dict) or "findings" not in data:
        raise SystemExit(f"{path}: baseline must be a JSON object with 'findings'")
    return data["findings"]


def baseline_fingerprints(entries: list[dict]) -> set[str]:
    return {
        f"{e['rule']}::{e['path']}::{e['message']}" for e in entries
    }


# -- walking ------------------------------------------------------------------


def iter_python_files(root: Path, paths: Iterable[str]) -> list[Path]:
    """Walk ``paths`` for ``*.py``.  Exclusions apply to the WALK only: a
    file named explicitly is always analyzed (that is how the fixture tests
    point the engine at the deliberately-buggy corpus the walk skips)."""
    seen: dict[Path, None] = {}
    for p in paths:
        base = (root / p) if not Path(p).is_absolute() else Path(p)
        if base.is_file():
            seen[base.resolve()] = None
            continue
        for f in sorted(base.rglob("*.py")):
            rel = _relpath(f.resolve(), root)
            parts = Path(rel).parts
            if set(parts) & set(EXCLUDED_PARTS):
                continue
            if any(part.startswith(".") for part in parts):
                continue
            if any(rel.startswith(sub + "/") for sub in EXCLUDED_SUBTREES):
                continue
            seen.setdefault(f.resolve(), None)
    return sorted(seen)


def _relpath(f: Path, root: Path) -> str:
    try:
        return f.relative_to(root).as_posix()
    except ValueError:
        return f.as_posix()


# -- the run ------------------------------------------------------------------


@dataclasses.dataclass
class RunResult:
    findings: list[Finding]
    unused_suppressions: list[Suppression]
    stale_baseline: list[str]
    files_scanned: int
    rules_run: list[str]

    @property
    def clean(self) -> bool:
        return not (
            self.findings or self.unused_suppressions or self.stale_baseline
        )

    def as_dict(self) -> dict:
        return {
            "clean": self.clean,
            "files_scanned": self.files_scanned,
            "rules_run": self.rules_run,
            "findings": [f.as_dict() for f in self.findings],
            "unused_suppressions": [
                {"path": s.path, "line": s.line, "rules": list(s.rules)}
                for s in self.unused_suppressions
            ],
            "stale_baseline": self.stale_baseline,
        }


def run(
    paths: Iterable[str] | None = None,
    *,
    root: Path | None = None,
    select: Iterable[str] | None = None,
    ignore_scope: bool = False,
    baseline: Path | None = DEFAULT_BASELINE,
) -> RunResult:
    """Analyze ``paths`` (repo-relative; default: the whole tree) and return
    every unsuppressed, unbaselined finding plus suppression/baseline
    hygiene failures."""
    # rule modules register on import; deferred so engine import stays cheap
    from . import rules as _rules  # noqa: F401

    root = root or REPO_ROOT
    rules = active_rules(select)
    files = iter_python_files(root, paths or DEFAULT_ROOTS)

    contexts: list[FileContext] = []
    project = ProjectContext()
    findings: list[Finding] = []
    for f in files:
        rel = _relpath(f, root)
        try:
            ctx = FileContext(f, rel, f.read_text())
        except (SyntaxError, tokenize.TokenError, UnicodeDecodeError) as e:
            findings.append(
                Finding("parse-error", rel, 1, 0, f"cannot parse: {e}")
            )
            continue
        contexts.append(ctx)
        project.collect(ctx)

    unused: list[Suppression] = []
    for ctx in contexts:
        applicable = [
            r for r in rules if ignore_scope or r.applies_to(ctx.rel)
        ]
        if not applicable:
            continue
        raw = []
        for r in applicable:
            raw.extend(r.check(ctx, project))
        sups = parse_suppressions(ctx)
        used: set[int] = set()
        active_names = {r.name for r in applicable}
        for fd in sorted(raw, key=lambda f: (f.line, f.col)):
            hit = next(
                (
                    i
                    for i, s in enumerate(sups)
                    if fd.rule in s.rules and fd.line in s.covers
                ),
                None,
            )
            if hit is None:
                findings.append(fd)
            else:
                used.add(hit)
        for i, s in enumerate(sups):
            # a suppression is dead only relative to rules that actually ran
            # here; --select subsets must not misreport the others as unused
            checkable = [r for r in s.rules if r in active_names]
            if checkable and i not in used:
                unused.append(s)

    stale: list[str] = []
    if baseline is not None and baseline.exists():
        entries = load_baseline(baseline)
        allowed = baseline_fingerprints(entries)
        live = {f.fingerprint() for f in findings}
        findings = [f for f in findings if f.fingerprint() not in allowed]
        stale = sorted(allowed - live)

    return RunResult(
        findings=findings,
        unused_suppressions=unused,
        stale_baseline=stale,
        files_scanned=len(contexts),
        rules_run=[r.name for r in rules],
    )
