"""Mamba2 blocks via the SSD (state-space duality) chunked algorithm
(arXiv:2405.21060), adapted to TPU: the chunked form is matmul-dominated
(MXU-friendly) — intra-chunk terms are Q×Q attention-like einsums and
inter-chunk state passing is a short lax.scan over chunks, exactly the
decomposition the SSD paper motivates for "tensor-core" hardware.

Shapes (per block):
  x_in (B, L, D) -> in_proj -> z (B,L,DI), xBC (B,L,DI+2GN), dt (B,L,H)
  conv1d width W over xBC (causal), silu
  SSD over x (B,L,H,P), A (H,), B/C (B,L,G,N), dt (B,L,H)
  gated RMSNorm, out_proj (DI, D)

Decode keeps (conv ring state (B, W-1, DI+2GN), ssm state (B,H,P,N)) —
O(1) per token, the reason mamba2/zamba2 own the long_500k cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, reduce_boundary, rms_norm

__all__ = [
    "mamba_init",
    "mamba_forward",
    "mamba_decode",
    "init_mamba_state",
    "ssd_reference",
]


def _conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def mamba_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d, di, h = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    cdim = _conv_dim(cfg)
    ks = jax.random.split(key, 5)
    return {
        "w_in": dense_init(
            ks[0], (d, 2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + h), dtype=dtype
        ),
        "conv_w": dense_init(
            ks[1], (cfg.ssm_conv_width, cdim), fan_in=cfg.ssm_conv_width, dtype=dtype
        ),
        "conv_b": jnp.zeros((cdim,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log) = -1
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),   # softplus(-2) ~ 0.12
        "d_skip": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.zeros((di,), dtype),
        "w_out": dense_init(ks[4], (di, d), fan_in=di, dtype=dtype),
    }


def _split_proj(params, x, cfg: ModelConfig):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    proj = x @ params["w_in"]
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * g * n]
    dt = proj[..., di + di + 2 * g * n :].astype(jnp.float32)
    return z, xbc, dt


def _causal_conv(params, xbc, cfg: ModelConfig):
    """Depthwise causal conv, width W: y_t = sum_w w[w]*x[t-W+1+w] + b."""
    w = cfg.ssm_conv_width
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * params["conv_w"][i][None, None, :]
        for i in range(w)
    )
    return jax.nn.silu((out + params["conv_b"]).astype(jnp.float32)).astype(xbc.dtype)


def _split_xbc(xbc, cfg: ModelConfig):
    b, l, _ = xbc.shape
    di, g, n, h, p = (
        cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim,
    )
    xs = xbc[..., :di].reshape(b, l, h, p)
    bs = xbc[..., di : di + g * n].reshape(b, l, g, n)
    cs = xbc[..., di + g * n :].reshape(b, l, g, n)
    return xs, bs, cs


def _ssd_chunked(xs, dt, a, bs, cs, cfg: ModelConfig):
    """SSD: xs (B,L,H,P) fp32, dt (B,L,H) fp32 (post-softplus), a (H,)
    negative, bs/cs (B,L,G,N) fp32.  Returns y (B,L,H,P) fp32 and the final
    state (B,H,P,N)."""
    b, l, h, p = xs.shape
    g, n = bs.shape[2], bs.shape[3]
    q = min(cfg.ssm_chunk, l)
    assert l % q == 0, f"L={l} % chunk={q}"
    nc = l // q
    rep = h // g

    da = dt * a[None, None, :]                          # (B,L,H) <= 0
    xdt = xs * dt[..., None]                            # input scaled by dt

    # chunked views
    da_c = da.reshape(b, nc, q, h)
    x_c = xdt.reshape(b, nc, q, h, p)
    b_c = bs.reshape(b, nc, q, g, n)
    c_c = cs.reshape(b, nc, q, g, n)

    cum = jnp.cumsum(da_c, axis=2)                      # (B,NC,Q,H) inclusive
    total = cum[:, :, -1:, :]                           # (B,NC,1,H)

    # -- intra-chunk (attention-like, MXU) ---------------------------------
    # decay[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,NC,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcign,bcjgn->bcgij", c_c, b_c)          # (B,NC,G,Qi,Qj)
    cb = jnp.repeat(cb, rep, axis=2)                          # (B,NC,H,Qi,Qj)
    scores = cb * jnp.moveaxis(decay, -1, 2)                  # (B,NC,H,Qi,Qj)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores, x_c)

    # -- chunk states -------------------------------------------------------
    # S_c = sum_j exp(total - cum_j) B_j (x_j dt_j)
    w_state = jnp.exp(total - cum)                            # (B,NC,Q,H)
    b_h = jnp.repeat(b_c, rep, axis=3)                        # (B,NC,Q,H,N)
    s_c = jnp.einsum("bcjhn,bcjhp,bcjh->bchpn", b_h, x_c, w_state)

    # -- inter-chunk scan ------------------------------------------------------
    chunk_decay = jnp.exp(total[:, :, 0, :])                  # (B,NC,H)

    def step(carry, inp):
        s_prev = carry
        s_new, dec = inp
        s = s_prev * dec[0][..., None, None] + s_new
        return s, s_prev

    s_c_t = jnp.moveaxis(s_c, 1, 0)                           # (NC,B,H,P,N)
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)[:, None]          # (NC,1,B,H)
    init = jnp.zeros((b, h, p, n), jnp.float32)
    final_state, s_prevs = jax.lax.scan(step, init, (s_c_t, dec_t))
    s_prev = jnp.moveaxis(s_prevs, 0, 1)                      # (B,NC,H,P,N)

    # y_inter[i] = exp(cum_i) * C_i . S_prev
    c_h = jnp.repeat(c_c, rep, axis=3)                        # (B,NC,Q,H,N)
    y_inter = jnp.einsum(
        "bcihn,bchpn,bcih->bcihp", c_h, s_prev, jnp.exp(cum)
    )
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, final_state


def ssd_reference(xs, dt, a, bs, cs):
    """Naive O(L) recurrence oracle (fp32): the ground truth for tests."""
    b, l, h, p = xs.shape
    g, n = bs.shape[2], bs.shape[3]
    rep = h // g
    da = dt * a[None, None, :]
    xdt = xs * dt[..., None]
    b_h = jnp.repeat(bs, rep, axis=2)
    c_h = jnp.repeat(cs, rep, axis=2)

    def step(state, inp):
        x_t, da_t, b_t, c_t = inp
        state = state * jnp.exp(da_t)[..., None, None] + jnp.einsum(
            "bhn,bhp->bhpn", b_t, x_t
        )
        y_t = jnp.einsum("bhn,bhpn->bhp", c_t, state)
        return state, y_t

    init = jnp.zeros((b, h, p, n), jnp.float32)
    xs_t = jnp.moveaxis(xdt, 1, 0)
    da_t = jnp.moveaxis(da, 1, 0)
    bs_t = jnp.moveaxis(b_h, 1, 0)
    cs_t = jnp.moveaxis(c_h, 1, 0)
    final, ys = jax.lax.scan(step, init, (xs_t, da_t, bs_t, cs_t))
    return jnp.moveaxis(ys, 0, 1), final


def mamba_forward(
    params: dict, x: jnp.ndarray, cfg: ModelConfig
) -> jnp.ndarray:
    """Full-sequence Mamba2 block (train / prefill)."""
    z, xbc, dt = _split_proj(params, x, cfg)
    xbc = _causal_conv(params, xbc, cfg)
    xs, bs, cs = _split_xbc(xbc, cfg)
    dt = jax.nn.softplus(dt + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    y, _ = _ssd_chunked(
        xs.astype(jnp.float32), dt, a,
        bs.astype(jnp.float32), cs.astype(jnp.float32), cfg,
    )
    y = y + params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    b, l = x.shape[:2]
    y = y.reshape(b, l, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["gate_norm"], cfg.norm_eps)
    return reduce_boundary(y, x.dtype) @ params["w_out"]


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, _conv_dim(cfg)), dtype),
        "ssm": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
    }


def mamba_decode(
    params: dict, x: jnp.ndarray, state: dict, cfg: ModelConfig
) -> tuple[jnp.ndarray, dict]:
    """One-token recurrent step.  x (B, 1, D)."""
    z, xbc_new, dt = _split_proj(params, x, cfg)
    # conv over ring buffer: window = [conv_state ; xbc_new]
    window = jnp.concatenate([state["conv"], xbc_new], axis=1)  # (B, W, C)
    conv = (
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   params["conv_w"].astype(jnp.float32))
        + params["conv_b"].astype(jnp.float32)
    )
    xbc = jax.nn.silu(conv)[:, None, :].astype(x.dtype)          # (B,1,C)
    xs, bs, cs = _split_xbc(xbc, cfg)
    dt = jax.nn.softplus(dt + params["dt_bias"])                  # (B,1,H)
    a = -jnp.exp(params["a_log"])
    rep = cfg.ssm_heads // cfg.ssm_groups

    da = (dt[:, 0] * a[None, :]).astype(jnp.float32)              # (B,H)
    xdt = xs[:, 0].astype(jnp.float32) * dt[:, 0][..., None]
    b_h = jnp.repeat(bs[:, 0].astype(jnp.float32), rep, axis=1)   # (B,H,N)
    c_h = jnp.repeat(cs[:, 0].astype(jnp.float32), rep, axis=1)
    ssm = state["ssm"] * jnp.exp(da)[..., None, None] + jnp.einsum(
        "bhn,bhp->bhpn", b_h, xdt
    )
    y = jnp.einsum("bhn,bhpn->bhp", c_h, ssm)
    y = y + params["d_skip"][None, :, None] * xs[:, 0].astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["gate_norm"], cfg.norm_eps)
    out = y @ params["w_out"]
    return out, {"conv": window[:, 1:, :], "ssm": ssm}
