"""GQA/MQA attention with causal + sliding-window masks and KV-cache decode.

Covers: phi3 (GQA), gemma-2b (MQA, head_dim 256), qwen1.5 (MHA + QKV bias),
gemma3 (5:1 local:global sliding window, ring-buffer local caches), pixtral
backbone (GQA, attn_out_dim != d_model), zamba2's shared attention block, and
the whisper encoder/decoder (bidirectional / cross attention).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, reduce_boundary, rope

__all__ = [
    "attn_init",
    "attention",
    "attention_decode",
    "init_kv_cache",
    "cross_attention",
]

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (h * hd, d), fan_in=h * hd, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _project_qkv(params, x, cfg: ModelConfig):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (
        q.reshape(b, s, h, hd),
        k.reshape(b, s, kv, hd),
        v.reshape(b, s, kv, hd),
    )


def _softcap(scores: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap > 0:
        return cap * jnp.tanh(scores / cap)
    return scores


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q (B,S,H,hd), k/v (B,T,KV,hd), mask (B|1, S, T) bool -> (B,S,H*hd).
    fp32 scores; GQA via head grouping."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(float(hd))
    scores = _softcap(scores, cfg.attn_logit_softcap)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h * hd).astype(q.dtype)


def make_mask(
    q_pos: jnp.ndarray,
    k_pos: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    is_global=True,
    k_valid: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """(B|1, S, T) boolean mask.  ``is_global`` may be a traced scalar —
    local/global layer selection stays branch-free inside layer scans."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = kp <= qp if causal else jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if window:
        local = (qp - kp) < window
        glob = jnp.asarray(is_global, bool)
        m = m & (local | glob)
    if k_valid is not None:
        m = m & k_valid[..., None, :]
    if m.ndim == 2:
        m = m[None]
    return m


def attention(
    params: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    is_global=True,
    causal: bool = True,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill).  positions (B, S) or (S,)."""
    q, k, v = _project_qkv(params, x, cfg)
    cos, sin = rope(positions, cfg.head_dim, cfg.rope_theta)
    if positions.ndim == 1:
        cos, sin = cos[None], sin[None]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    # flash path: plain causal attention only (windowed/softcap/cross fall
    # back to the einsum path — see kernels/flash_attn)
    if (
        cfg.attn_impl == "pallas_flash"
        and causal
        and not cfg.sliding_window
        and not cfg.attn_logit_softcap
        and positions.ndim == 1
    ):
        from repro.kernels.flash_attn.ops import flash_attention

        b, s = q.shape[:2]
        out = flash_attention(q, k, v, causal=True)
        out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
        return reduce_boundary(out, x.dtype) @ params["wo"]

    pos2 = positions if positions.ndim == 2 else positions[None]
    mask = make_mask(
        pos2, pos2, causal=causal, window=cfg.sliding_window, is_global=is_global
    )
    return reduce_boundary(_sdpa(q, k, v, mask, cfg), x.dtype) @ params["wo"]


# -- decode with KV cache -----------------------------------------------------
def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, *, window_cache: bool = False,
    dtype=jnp.bfloat16,
) -> dict:
    """Per-layer cache pytree (stacked over layers by the caller).

    window_cache=True allocates a ring buffer of the sliding window size —
    the sub-quadratic memory plan for local layers at 500k context."""
    size = (
        min(max_len, cfg.sliding_window)
        if window_cache and cfg.sliding_window
        else max_len
    )
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, size, kv, hd), dtype),
        "v": jnp.zeros((batch, size, kv, hd), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),  # -1 = empty slot
    }


def attention_decode(
    params: dict,
    x: jnp.ndarray,
    cache: dict,
    t: jnp.ndarray,
    cfg: ModelConfig,
    *,
    is_global=True,
) -> tuple[jnp.ndarray, dict]:
    """One-token decode.  x (B, 1, D); t scalar int32 (current position).
    Returns (out (B, 1, D), updated cache)."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, cfg)
    pos_new = jnp.full((b, 1), t, jnp.int32)
    cos, sin = rope(pos_new, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)

    size = cache["k"].shape[1]
    slot = jnp.mod(t, size)  # ring semantics; == t when size == max_len
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(cache["pos"], pos_new, (0, slot))

    mask = make_mask(
        pos_new,
        pos,
        causal=True,
        window=cfg.sliding_window,
        is_global=is_global,
        k_valid=pos >= 0,
    )
    out = reduce_boundary(_sdpa(q, k, v, mask, cfg), x.dtype) @ params["wo"]
    return out, {"k": k, "v": v, "pos": pos}


# -- cross attention (whisper decoder) ------------------------------------------
def cross_attn_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, h * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, h * hd), dtype=dtype),
        "wo": dense_init(ks[3], (h * hd, d), fan_in=h * hd, dtype=dtype),
    }


def cross_attention(
    params: dict, x: jnp.ndarray, memory: jnp.ndarray, cfg: ModelConfig
) -> jnp.ndarray:
    """x (B,S,D) attends to encoder memory (B,T,D); no positions (whisper
    applies learned/sinusoidal pos upstream)."""
    b, s, _ = x.shape
    t = memory.shape[1]
    h, hd = cfg.num_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (memory @ params["wk"]).reshape(b, t, h, hd)
    v = (memory @ params["wv"]).reshape(b, t, h, hd)
    mask = jnp.ones((1, s, t), bool)
    return reduce_boundary(_sdpa(q, k, v, mask, cfg), x.dtype) @ params["wo"]
