"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, T_enc, D).  The backbone is
faithful: pre-LayerNorm transformer encoder (bidirectional), decoder with
causal self-attention + cross-attention, learned decoder positions,
sinusoidal encoder positions, GELU MLPs, tied embedding/output head.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.attention import _sdpa, make_mask
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, layer_norm, mlp_apply, mlp_init
from repro.models.losses import next_token_loss
from repro.models.pspec import BATCH, constrain, scan_unroll

__all__ = ["init_params", "train_loss", "init_cache", "decode_step", "encode"]


def _ln_init(d: int, dtype) -> dict:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _attn_nope_init(key, cfg: ModelConfig, dtype) -> dict:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, h * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, h * hd), dtype=dtype),
        "wo": dense_init(ks[3], (h * hd, d), fan_in=h * hd, dtype=dtype),
    }


def _attn_nope(params, x, cfg: ModelConfig, *, causal: bool) -> jnp.ndarray:
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, h, hd)
    v = (x @ params["wv"]).reshape(b, s, h, hd)
    pos = jnp.arange(s)[None]
    mask = make_mask(pos, pos, causal=causal)
    return _sdpa(q, k, v, mask, cfg) @ params["wo"]


def _cross(params, x, mem_k, mem_v, cfg: ModelConfig) -> jnp.ndarray:
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    mask = jnp.ones((1, s, mem_k.shape[1]), bool)
    return _sdpa(q, mem_k, mem_v, mask, cfg) @ params["wo"]


def _sinusoid(length: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10_000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_params(key, cfg: ModelConfig, *, max_pos: int) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = list(jax.random.split(key, cfg.encoder_layers + cfg.num_layers + 4))

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": _ln_init(d, dtype),
            "attn": _attn_nope_init(k1, cfg, dtype),
            "ln2": _ln_init(d, dtype),
            "mlp": mlp_init(k2, d, cfg.d_ff, "gelu", dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": _ln_init(d, dtype),
            "self_attn": _attn_nope_init(k1, cfg, dtype),
            "ln2": _ln_init(d, dtype),
            "cross_attn": _attn_nope_init(k2, cfg, dtype),
            "ln3": _ln_init(d, dtype),
            "mlp": mlp_init(k3, d, cfg.d_ff, "gelu", dtype),
        }

    stack = lambda trees: jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    return {
        "embed": dense_init(ks.pop(), (cfg.vocab_size, d), fan_in=d, dtype=dtype),
        "pos_dec": dense_init(ks.pop(), (max_pos, d), fan_in=d, dtype=dtype),
        "enc": stack([enc_layer(ks.pop()) for _ in range(cfg.encoder_layers)]),
        "enc_ln": _ln_init(d, dtype),
        "dec": stack([dec_layer(ks.pop()) for _ in range(cfg.num_layers)]),
        "dec_ln": _ln_init(d, dtype),
    }


def encode(params: dict, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames (B, T_enc, D) from the stub frontend -> encoder memory."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cdt) + _sinusoid(frames.shape[1], cfg.d_model).astype(cdt)

    def body(x, lp):
        x = constrain(x, BATCH, None, None)
        h = layer_norm(x, lp["ln1"]["g"], lp["ln1"]["b"])
        x = x + _attn_nope(lp["attn"], h, cfg, causal=False)
        h = layer_norm(x, lp["ln2"]["g"], lp["ln2"]["b"])
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"],
                        unroll=scan_unroll(cfg.encoder_layers))
    return layer_norm(x, params["enc_ln"]["g"], params["enc_ln"]["b"])


def _decode_full(params, memory, tokens, cfg: ModelConfig) -> jnp.ndarray:
    cdt = jnp.dtype(cfg.compute_dtype)
    s = tokens.shape[1]
    x = params["embed"][tokens].astype(cdt) + params["pos_dec"][:s].astype(cdt)

    def body(x, lp):
        x = constrain(x, BATCH, None, None)
        h = layer_norm(x, lp["ln1"]["g"], lp["ln1"]["b"])
        x = x + _attn_nope(lp["self_attn"], h, cfg, causal=True)
        h = layer_norm(x, lp["ln2"]["g"], lp["ln2"]["b"])
        b, t = memory.shape[:2]
        hh, hd = cfg.num_heads, cfg.head_dim
        mem_k = (memory @ lp["cross_attn"]["wk"]).reshape(b, t, hh, hd)
        mem_v = (memory @ lp["cross_attn"]["wv"]).reshape(b, t, hh, hd)
        x = x + _cross(lp["cross_attn"], h, mem_k, mem_v, cfg)
        h = layer_norm(x, lp["ln3"]["g"], lp["ln3"]["b"])
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec"],
                        unroll=scan_unroll(cfg.num_layers))
    x = layer_norm(x, params["dec_ln"]["g"], params["dec_ln"]["b"])
    return constrain(x @ params["embed"].T, BATCH, None, "model")


def train_loss(params: dict, batch: dict, cfg: ModelConfig):
    memory = encode(params, batch["frames"], cfg)
    logits = _decode_full(params, memory, batch["tokens"], cfg)
    loss = next_token_loss(logits, batch["tokens"])
    return loss, {"lm_loss": loss, "total_loss": loss}


# -- serving -------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = jnp.dtype(cfg.compute_dtype)
    h, hd, L = cfg.num_heads, cfg.head_dim, cfg.num_layers
    t_enc = cfg.encoder_seq
    return {
        "t": jnp.zeros((), jnp.int32),
        "self_k": jnp.zeros((L, batch, max_len, h, hd), dtype),
        "self_v": jnp.zeros((L, batch, max_len, h, hd), dtype),
        "mem_k": jnp.zeros((L, batch, t_enc, h, hd), dtype),
        "mem_v": jnp.zeros((L, batch, t_enc, h, hd), dtype),
    }


def precompute_cross(
    params: dict, memory: jnp.ndarray, cfg: ModelConfig, cache: dict
) -> dict:
    b, t = memory.shape[:2]
    h, hd = cfg.num_heads, cfg.head_dim

    def per_layer(lp):
        mk = (memory @ lp["cross_attn"]["wk"]).reshape(b, t, h, hd)
        mv = (memory @ lp["cross_attn"]["wv"]).reshape(b, t, h, hd)
        return mk, mv

    mks, mvs = jax.lax.map(per_layer, params["dec"])
    return {**cache, "mem_k": mks, "mem_v": mvs}


def decode_step(params: dict, cache: dict, tokens_new: jnp.ndarray,
                cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    cdt = jnp.dtype(cfg.compute_dtype)
    t = cache["t"]
    b = tokens_new.shape[0]
    h, hd = cfg.num_heads, cfg.head_dim
    x = params["embed"][tokens_new].astype(cdt) + jax.lax.dynamic_slice(
        params["pos_dec"], (t, 0), (1, cfg.d_model)
    ).astype(cdt)[None]

    max_len = cache["self_k"].shape[2]
    kpos = jnp.arange(max_len)[None]
    mask = (kpos <= t)[:, None, :]

    def body(x, inp):
        lp, sk, sv, mk, mv = inp
        hdn = layer_norm(x, lp["ln1"]["g"], lp["ln1"]["b"])
        q = (hdn @ lp["self_attn"]["wq"]).reshape(b, 1, h, hd)
        k1 = (hdn @ lp["self_attn"]["wk"]).reshape(b, 1, h, hd)
        v1 = (hdn @ lp["self_attn"]["wv"]).reshape(b, 1, h, hd)
        sk = jax.lax.dynamic_update_slice(sk, k1, (0, t, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, v1, (0, t, 0, 0))
        x = x + _sdpa(q, sk, sv, mask, cfg) @ lp["self_attn"]["wo"]
        hdn = layer_norm(x, lp["ln2"]["g"], lp["ln2"]["b"])
        x = x + _cross(lp["cross_attn"], hdn, mk, mv, cfg)
        hdn = layer_norm(x, lp["ln3"]["g"], lp["ln3"]["b"])
        x = x + mlp_apply(lp["mlp"], hdn, "gelu")
        return x, (sk, sv)

    x, (sks, svs) = jax.lax.scan(
        body, x, (params["dec"], cache["self_k"], cache["self_v"],
                  cache["mem_k"], cache["mem_v"]),
        unroll=scan_unroll(cfg.num_layers),
    )
    x = layer_norm(x, params["dec_ln"]["g"], params["dec_ln"]["b"])
    logits = x @ params["embed"].T
    return logits, {**cache, "t": t + 1, "self_k": sks, "self_v": svs}
