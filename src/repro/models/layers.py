"""Shared neural-net layers: norms, rotary embeddings, MLP variants, inits.

Pure-function JAX (param pytrees of jnp arrays) — no framework dependency,
which keeps pjit sharding rules a simple path->PartitionSpec map.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import ad_barrier

__all__ = [
    "rms_norm",
    "layer_norm",
    "rope",
    "apply_rope",
    "mlp_apply",
    "mlp_init",
    "dense_init",
    "reduce_boundary",
    "Param",
]


def reduce_boundary(x: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Pin the operand of a row-parallel (TP) matmul to a compact dtype.

    XLA folds ``convert(f32->bf16)`` into downstream dots, silently running
    the dot — and therefore the partial-sum all-reduce over ``model`` — in
    f32: 2x wire bytes (measured: 47 GiB of f32 all-reduce on a 5-layer ds3
    probe, §Perf iter-4).  An optimization barrier on the bf16 value keeps
    the reduction bf16.  AD passes cotangents through the barrier, so the
    backward dot's all-reduce is bf16 too (the gradient-compression lever)."""
    return ad_barrier(x.astype(dtype))


def dense_init(key, shape, fan_in: Optional[int] = None, dtype=jnp.bfloat16):
    fan = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(fan, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(
    x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(x.dtype)


# -- rotary position embeddings ------------------------------------------------
def rope(
    positions: jnp.ndarray, dim: int, theta: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,) -> (cos, sin) of shape (..., dim//2), float32."""
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim
    )
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, D) with cos/sin (..., S, D//2) — rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x32_1 * c - x32_2 * s, x32_2 * c + x32_1 * s], axis=-1
    ).astype(x.dtype)


# -- MLPs ---------------------------------------------------------------------
def mlp_init(key, d_model: int, d_ff: int, variant: str, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    if variant in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }


def mlp_apply(params: dict, x: jnp.ndarray, variant: str) -> jnp.ndarray:
    from repro.models.pspec import BATCH, constrain  # local: avoid cycle

    if variant in ("swiglu", "geglu"):
        act = jax.nn.silu if variant == "swiglu" else functools.partial(
            jax.nn.gelu, approximate=True
        )
        g = act(x @ params["w_gate"])
        h = g * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"], approximate=True)
    # Pin the hidden's F dim to the TP axis: without this anchor GSPMD may
    # materialize the full-width hidden per device (observed on the gemma
    # train cell: f32[B/dp, S, 16384] instead of [.., 1024]).
    h = constrain(h, *((BATCH,) + (None,) * (h.ndim - 2) + ("model",)))
    return reduce_boundary(h, x.dtype) @ params["w_down"]


Param = jnp.ndarray
