"""Uniform model API over the two assemblies (decoder-only LM / enc-dec).

Everything downstream (launchers, dry-run, benchmarks, tests) talks to
these five functions; the family dispatch lives here and nowhere else.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.config import ModelConfig

__all__ = [
    "init_params",
    "train_loss",
    "forward_logits",
    "init_cache",
    "decode_step",
    "make_dummy_batch",
]


def init_params(key, cfg: ModelConfig, *, max_decode_len: int = 4096) -> dict:
    if cfg.encoder_decoder:
        return encdec.init_params(key, cfg, max_pos=max_decode_len)
    return lm.init_params(key, cfg)


def train_loss(params: dict, batch: dict, cfg: ModelConfig):
    if cfg.encoder_decoder:
        return encdec.train_loss(params, batch, cfg)
    return lm.train_loss(params, batch, cfg)


def forward_logits(params: dict, batch: dict, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence logits (the prefill-throughput path)."""
    if cfg.encoder_decoder:
        memory = encdec.encode(params, batch["frames"], cfg)
        return encdec._decode_full(params, memory, batch["tokens"], cfg)
    _, logits, _ = lm.forward(params, batch, cfg)
    return logits


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    if cfg.encoder_decoder:
        return encdec.init_cache(cfg, batch, max_len)
    return lm.init_cache(cfg, batch, max_len)


def decode_step(params: dict, cache: dict, tokens_new: jnp.ndarray,
                cfg: ModelConfig):
    if cfg.encoder_decoder:
        return encdec.decode_step(params, cache, tokens_new, cfg)
    return lm.decode_step(params, cache, tokens_new, cfg)


def encode_memory(params: dict, frames: jnp.ndarray, cfg: ModelConfig):
    """Enc-dec only: run the encoder over (stub) frame embeddings."""
    return encdec.encode(params, frames, cfg)


def attach_memory(cache: dict, memory: jnp.ndarray, params: dict,
                  cfg: ModelConfig) -> dict:
    """Enc-dec only: precompute cross-attention K/V into the decode cache."""
    return encdec.precompute_cross(params, memory, cfg, cache)


def make_dummy_batch(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0) -> dict:
    """Concrete (allocated) batch for smoke tests and examples."""
    k = jax.random.PRNGKey(seed)
    out: dict[str, Any] = {
        "tokens": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    }
    if cfg.encoder_decoder:
        out["frames"] = jax.random.normal(
            k, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.compute_dtype))
    if cfg.vision_prefix:
        out["patch_embeds"] = jax.random.normal(
            k, (batch, cfg.num_patches, cfg.vision_dim), jnp.float32
        ).astype(jnp.dtype(cfg.compute_dtype))
    return out
