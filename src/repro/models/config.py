"""Model configuration covering every assigned architecture family.

One config dataclass drives the unified LM (models/lm.py): dense / MoE
(+MLA, +MTP) / SSM (Mamba2-SSD) / hybrid (Mamba2 + shared attention) /
local:global sliding-window attention, plus the enc-dec (whisper) and
vision-prefix (pixtral) assemblies.  Param-count helpers feed the roofline's
MODEL_FLOPS = 6·N(active)·D term.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "LayerKind"]


class LayerKind:
    ATTN = 0      # attention mixer (GQA / MLA)
    MAMBA = 1     # Mamba2 SSD mixer


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int

    # -- attention ---------------------------------------------------------
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_logit_softcap: float = 0.0
    # sliding-window pattern: 0 => all-global.  "5:1" => 5 local then 1
    # global, repeating (gemma3).
    local_global_period: int = 0   # 0 = none; else every Nth layer is global
    sliding_window: int = 0

    # -- MLA (deepseek) ------------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0           # 0 => direct q projection
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # -- FFN -------------------------------------------------------------------
    d_ff: int = 0                  # dense FFN hidden (0 => no FFN, e.g. mamba2)
    mlp_variant: str = "swiglu"    # swiglu | geglu | gelu

    # -- MoE ---------------------------------------------------------------------
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0    # leading layers that keep a dense FFN
    router_aux_coef: float = 0.001
    #: train-time expert-capacity factor (GShard dropping).  Serving paths
    #: (decode_step) always run no-drop (cf = E/k): inference must not drop.
    capacity_factor: float = 1.25

    # -- MTP (deepseek-v3) -----------------------------------------------------------
    mtp_depth: int = 0

    # -- SSM (mamba2 / zamba2) ---------------------------------------------------------
    ssm: bool = False              # True => mixer layers are Mamba2 blocks
    ssm_state: int = 0             # N
    ssm_expand: int = 2
    ssm_head_dim: int = 64         # P
    ssm_groups: int = 1            # G (B/C groups)
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # -- hybrid (zamba2): a SHARED attention block applied every Nth layer -------------
    hybrid_attn_period: int = 0

    # -- enc-dec (whisper) -------------------------------------------------------------
    encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0           # frame count from the (stub) frontend

    # -- vision prefix (pixtral) -------------------------------------------------------
    vision_prefix: bool = False
    vision_dim: int = 0            # stub patch-embedding dim
    num_patches: int = 0

    # -- numerics ----------------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # -- attention implementation ------------------------------------------------------
    #: "xla" — einsum attention (CPU-compilable; what the dry-run lowers).
    #: "pallas_flash" — the kernels/flash_attn forward for plain causal
    #: attention (TPU target; interpret-mode on CPU).  Falls back to xla for
    #: windowed/softcapped/cross/decode paths.
    attn_impl: str = "xla"

    # ----------------------------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.ssm:
            assert self.ssm_state > 0
        elif not self.encoder_decoder:
            assert self.num_heads > 0 and self.head_dim > 0
        if self.moe:
            assert 0 < self.top_k <= self.num_experts
        if self.use_mla:
            assert self.kv_lora_rank > 0 and self.qk_rope_dim > 0

    # -- derived -------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_out_dim(self) -> int:
        if self.use_mla:
            return self.num_heads * self.v_head_dim
        return self.num_heads * self.head_dim

    def layer_kinds(self) -> list[int]:
        """Mixer kind per layer."""
        if self.ssm:
            return [LayerKind.MAMBA] * self.num_layers
        return [LayerKind.ATTN] * self.num_layers

    def is_global_layer(self, i: int) -> bool:
        if not self.local_global_period:
            return True
        return (i + 1) % self.local_global_period == 0

    # -- parameter counting (for MODEL_FLOPS sanity) -----------------------------
    def _attn_params(self) -> int:
        d = self.d_model
        if self.use_mla:
            q = (
                d * self.q_lora_rank
                + self.q_lora_rank
                * self.num_heads
                * (self.qk_nope_dim + self.qk_rope_dim)
                if self.q_lora_rank
                else d * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
            )
            kv_a = d * (self.kv_lora_rank + self.qk_rope_dim)
            kv_b = (
                self.kv_lora_rank
                * self.num_heads
                * (self.qk_nope_dim + self.v_head_dim)
            )
            out = self.num_heads * self.v_head_dim * d
            return q + kv_a + kv_b + out
        q = d * self.num_heads * self.head_dim
        kv = 2 * d * self.num_kv_heads * self.head_dim
        out = self.num_heads * self.head_dim * d
        return q + kv + out

    def _ffn_params(self, hidden: int) -> int:
        mult = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        return mult * self.d_model * hidden

    def _mamba_params(self) -> int:
        d, di, n, g = self.d_model, self.d_inner, self.ssm_state, self.ssm_groups
        in_proj = d * (2 * di + 2 * g * n + self.ssm_heads)  # z, x, B, C, dt
        conv = self.ssm_conv_width * (di + 2 * g * n)
        out_proj = di * d
        extras = self.ssm_heads * 2 + di  # A, dt_bias, (gate norm)
        return in_proj + conv + out_proj + extras

    def param_counts(self) -> dict[str, float]:
        """Returns {'total': N, 'active': N_active} (per-token active params)."""
        d = self.d_model
        embed = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        total = embed + head
        active = embed + head

        n_layers = self.num_layers
        for i in range(n_layers):
            if self.ssm:
                mix = self._mamba_params()
            else:
                mix = self._attn_params()
            total += mix
            active += mix
            if self.moe and i >= self.first_dense_layers:
                expert = self._ffn_params(self.moe_d_ff)
                total += self.num_experts * expert + self.num_shared_experts * expert
                total += d * self.num_experts  # router
                active += (
                    self.top_k + self.num_shared_experts
                ) * expert + d * self.num_experts
            elif self.d_ff and not self.ssm:
                # mamba layers have no separate FFN; for hybrids d_ff sizes
                # only the shared attention block's MLP (counted below)
                ffn = self._ffn_params(self.d_ff)
                total += ffn
                active += ffn
            total += 2 * d  # norms
            active += 2 * d

        if self.hybrid_attn_period:
            shared = self._attn_params() + self._ffn_params(self.d_ff or 4 * d)
            total += shared
            uses = n_layers // self.hybrid_attn_period
            active += shared  # params shared; active-per-token counts once

        if self.encoder_decoder:
            # encoder self-attn + ffn, decoder cross-attn already in layers
            enc = self.encoder_layers * (
                self._attn_params() + self._ffn_params(self.d_ff)
            )
            cross = self.num_layers * self._attn_params()
            total += enc + cross
            active += enc + cross

        if self.vision_prefix:
            total += self.vision_dim * d
            active += self.vision_dim * d

        if self.mtp_depth:
            mtp = self._attn_params() + (
                3 * d * self.moe_d_ff * (self.top_k + self.num_shared_experts)
                if self.moe
                else self._ffn_params(self.d_ff)
            ) + 2 * d * d  # projection
            total += mtp
            active += mtp

        return {"total": float(total), "active": float(active)}
