"""Activation sharding constraints, context-scoped.

GSPMD needs anchor points: with parameters sharded for FSDP (weight dims
over ``data``), propagation alone may choose to all-gather ACTIVATIONS over
the batch axes instead of all-gathering weights — catastrophically wrong at
B=256·4096 tokens.  Model code therefore pins activation layouts at block
boundaries with ``constrain(x, ...)``.

The mesh is provided by the launcher through ``activation_mesh`` (a
contextvar), so model code stays mesh-agnostic and tests on a single device
run with constraints compiled away (no mesh => no-op).

Convention: '__batch__' in a spec expands to every non-'model' mesh axis;
axis names absent from the active mesh drop to None; dims that don't divide
their shard count fall back to None (GSPMD would pad — never useful here).
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "activation_mesh", "constrain", "BATCH", "unrolled_scans", "scan_unroll",
    "current_mesh",
]

BATCH = "__batch__"

_mesh_var: contextvars.ContextVar = contextvars.ContextVar(
    "activation_mesh", default=None
)

# ---------------------------------------------------------------------------
# Scan unrolling for the dry-run: XLA's cost_analysis counts a while-loop
# body ONCE regardless of trip count (verified: the gemma-2b train cell
# reported exactly 1/num_layers of the stack's FLOPs).  The dry-run therefore
# lowers with layer scans unrolled so the roofline reads true per-step cost.
# Training/serving drivers keep rolled scans (compile-time O(1) in depth).
# ---------------------------------------------------------------------------
_unroll_var: contextvars.ContextVar = contextvars.ContextVar(
    "scan_unroll", default=False
)


@contextlib.contextmanager
def unrolled_scans(enable: bool = True):
    token = _unroll_var.set(enable)
    try:
        yield
    finally:
        _unroll_var.reset(token)


def scan_unroll(length: int) -> int:
    """unroll= argument for depth scans under the current context."""
    return length if _unroll_var.get() else 1


def current_mesh():
    """The mesh the launcher scoped for activation sharding (None in
    single-device tests — model code must degrade gracefully)."""
    return _mesh_var.get()


@contextlib.contextmanager
def activation_mesh(mesh):
    token = _mesh_var.set(mesh)
    try:
        yield
    finally:
        _mesh_var.reset(token)


def _resolve(entry, mesh):
    if entry is None:
        return None
    if entry == BATCH:
        axes = tuple(a for a in mesh.axis_names if a != "model")
        return axes if axes else None
    if isinstance(entry, (tuple, list)):
        kept = tuple(a for a in entry if a in mesh.axis_names)
        return kept if kept else None
    return entry if entry in mesh.axis_names else None


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint(x, P(*spec)) against the context mesh."""
    mesh = _mesh_var.get()
    if mesh is None:
        return x
    if len(spec) > x.ndim:
        spec = spec[: x.ndim]
    entries = []
    for dim, e in zip(x.shape, spec):
        r = _resolve(e, mesh)
        if r is not None:
            axes = (r,) if isinstance(r, str) else r
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % size != 0 or dim < size:
                r = None
        entries.append(r)
    entries += [None] * (x.ndim - len(entries))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries))
    )
