"""Multi-head Latent Attention (DeepSeek V2/V3).

Train/prefill: expand the compressed KV latent to full K/V heads and run
standard attention.  Decode: the ABSORBED path — fold the up-projections
into the query/output so attention runs directly against the compressed
cache of (kv_lora_rank + qk_rope_dim) per token, independent of head count.
That cache compression is what makes the deepseek archs' decode_32k cells
fit, and the absorbed matmuls are the beyond-paper perf lever for them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, reduce_boundary, rms_norm, rope

__all__ = ["mla_init", "mla_attention", "mla_decode", "init_mla_cache"]

NEG_INF = -1e30


def mla_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    nope, pe, v = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    p: dict = {}
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], (d, cfg.q_lora_rank), dtype=dtype)
        p["q_norm"] = jnp.zeros((cfg.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(
            ks[1], (cfg.q_lora_rank, h * (nope + pe)), dtype=dtype
        )
    else:
        p["wq"] = dense_init(ks[0], (d, h * (nope + pe)), dtype=dtype)
    p["wkv_a"] = dense_init(ks[2], (d, cfg.kv_lora_rank + pe), dtype=dtype)
    p["kv_norm"] = jnp.zeros((cfg.kv_lora_rank,), dtype)
    p["wkv_b"] = dense_init(ks[3], (cfg.kv_lora_rank, h * (nope + v)), dtype=dtype)
    p["wo"] = dense_init(ks[4], (h * v, d), fan_in=h * v, dtype=dtype)
    return p


def _q_proj(params, x, cfg: ModelConfig):
    b, s, _ = x.shape
    h, nope, pe = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        q = rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
        q = q @ params["wq_b"]
    else:
        q = x @ params["wq"]
    q = q.reshape(b, s, h, nope + pe)
    return q[..., :nope], q[..., nope:]


def _kv_latent(params, x, positions, cfg: ModelConfig):
    """Returns (c_kv normed (B,S,R), k_pe roped (B,S,pe))."""
    pe = cfg.qk_rope_dim
    kv_a = x @ params["wkv_a"]
    c_kv = rms_norm(kv_a[..., : cfg.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_pe = kv_a[..., cfg.kv_lora_rank :]
    cos, sin = rope(positions, pe, cfg.rope_theta)
    if positions.ndim == 1:
        cos, sin = cos[None], sin[None]
    k_pe = apply_rope(k_pe[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_pe


def mla_attention(
    params: dict, x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig
) -> jnp.ndarray:
    """Full-sequence MLA (train / prefill): expand latent, standard SDPA."""
    b, s, _ = x.shape
    h, nope, pe, vd = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_pe = _q_proj(params, x, cfg)
    cos, sin = rope(positions, pe, cfg.rope_theta)
    if positions.ndim == 1:
        cos, sin = cos[None], sin[None]
    q_pe = apply_rope(q_pe, cos, sin)

    c_kv, k_pe = _kv_latent(params, x, positions, cfg)
    kv = (c_kv @ params["wkv_b"]).reshape(b, s, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]

    scale = 1.0 / jnp.sqrt(float(nope + pe))
    s_nope = jnp.einsum(
        "bshd,bthd->bhst", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32)
    )
    s_pe = jnp.einsum(
        "bshd,btd->bhst", q_pe.astype(jnp.float32), k_pe.astype(jnp.float32)
    )
    scores = (s_nope + s_pe) * scale
    pos2 = positions if positions.ndim == 2 else positions[None]
    causal = pos2[..., None, :] <= pos2[..., :, None]
    scores = jnp.where(causal[:, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", w, v.astype(jnp.float32))
    out = reduce_boundary(out.reshape(b, s, h * vd), x.dtype)
    return out @ params["wo"]


def init_mla_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> dict:
    """Compressed cache: latent + shared rope key.  Per token per layer:
    kv_lora_rank + qk_rope_dim values (e.g. 576 for deepseek), vs
    2·H·head_dim for plain GQA."""
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_decode(
    params: dict, x: jnp.ndarray, cache: dict, t: jnp.ndarray, cfg: ModelConfig
) -> tuple[jnp.ndarray, dict]:
    """Absorbed single-token decode against the compressed cache.

    score_h(t) = q_nope_h^T W_uk_h c_t + q_pe_h^T k_pe_t
    out_h      = (Σ_t w_t c_t)^T W_uv_h
    """
    b = x.shape[0]
    h, nope, pe, vd = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank

    q_nope, q_pe = _q_proj(params, x, cfg)          # (B,1,H,nope), (B,1,H,pe)
    pos_new = jnp.full((b, 1), t, jnp.int32)
    cos, sin = rope(pos_new, pe, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin)

    c_new, k_pe_new = _kv_latent(params, x, pos_new, cfg)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, t, 0))
    k_pe = jax.lax.dynamic_update_slice(cache["k_pe"], k_pe_new, (0, t, 0))
    pos = jax.lax.dynamic_update_slice(cache["pos"], pos_new, (0, t))

    wkv_b = params["wkv_b"].reshape(r, h, nope + vd)
    w_uk = wkv_b[..., :nope]                         # (R, H, nope)
    w_uv = wkv_b[..., nope:]                         # (R, H, vd)

    # Absorb W_uk into q: (B,1,H,nope) x (R,H,nope) -> (B,1,H,R)
    q_c = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32))
    s_c = jnp.einsum("bshr,btr->bhst", q_c, c_kv.astype(jnp.float32))
    s_pe = jnp.einsum(
        "bshd,btd->bhst", q_pe.astype(jnp.float32), k_pe.astype(jnp.float32)
    )
    scores = (s_c + s_pe) / jnp.sqrt(float(nope + pe))
    valid = (pos <= t) & (pos >= 0)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)              # (B,H,1,T)
    out_c = jnp.einsum("bhst,btr->bshr", w, c_kv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhv->bshv", out_c, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, h * vd).astype(x.dtype) @ params["wo"]
    return out, {"c_kv": c_kv, "k_pe": k_pe, "pos": pos}
