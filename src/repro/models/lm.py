"""Unified decoder-only LM covering the assigned families.

One parameter/init/apply implementation, driven by ModelConfig flags:

  * dense GQA/MQA/MHA transformers (phi3, gemma-2b, qwen1.5, pixtral backbone)
  * local:global sliding-window attention (gemma3) — branch-free per-layer
    flags inside a single layer scan
  * MLA + MoE (+ optional MTP head) (deepseek-v2-lite, deepseek-v3) — leading
    dense layers as an unrolled prefix, uniform MoE layers scanned
  * pure SSM (mamba2) and hybrid SSM + shared-attention (zamba2) — the shared
    attention block's params enter the scan as closure constants
  * optional vision prefix (pixtral): projected precomputed patch embeddings
    prepended to the token sequence (frontend stubbed per assignment)

Layer stacks use jax.lax.scan over stacked params: HLO size and compile time
stay O(1) in depth — a hard requirement for lowering 61-layer 671B configs
against a 512-device mesh.  jax.checkpoint wraps the scan body (full remat of
the block; the §Perf log iterates on the policy).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, mlp_apply, mlp_init, rms_norm
from repro.models.losses import next_token_loss, softmax_cross_entropy
from repro.models.pspec import BATCH, constrain, scan_unroll

__all__ = [
    "init_params",
    "forward",
    "train_loss",
    "init_cache",
    "prefill",
    "decode_step",
]


# =============================================================================
# init
# =============================================================================
def _block_init(key, cfg: ModelConfig, *, dense_ffn: bool, dtype) -> dict:
    """One transformer/mamba block's params."""
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.ssm:
        p["mixer"] = ssm_mod.mamba_init(ks[0], cfg, dtype)
    elif cfg.use_mla:
        p["mixer"] = mla_mod.mla_init(ks[0], cfg, dtype)
    else:
        p["mixer"] = attn.attn_init(ks[0], cfg, dtype)
    if cfg.moe and not dense_ffn:
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = moe_mod.moe_init(ks[1], cfg, dtype)
    elif cfg.d_ff and not cfg.ssm:
        # Mamba blocks are the whole layer (no separate FFN); for hybrid
        # archs cfg.d_ff sizes the SHARED attention block's MLP only.
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_variant, dtype)
    return p


def _shared_attn_block_init(key, cfg: ModelConfig, dtype) -> dict:
    """zamba2's shared transformer block (attention + MLP), one copy."""
    ks = jax.random.split(key, 2)
    return {
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn.attn_init(ks[0], cfg, dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff or 4 * cfg.d_model,
                        "gelu", dtype),
    }


def _stack(trees: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _layer_plan(cfg: ModelConfig) -> dict:
    """How the depth dimension is organized (must match init & apply)."""
    if cfg.hybrid_attn_period:
        per = cfg.hybrid_attn_period
        return {
            "prefix": 0,
            "groups": cfg.num_layers // per,
            "group_len": per,
            "tail": cfg.num_layers % per,
        }
    return {
        "prefix": cfg.first_dense_layers,
        "groups": 0,
        "group_len": 0,
        "tail": cfg.num_layers - cfg.first_dense_layers,
    }


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    plan = _layer_plan(cfg)
    n_keys = cfg.num_layers + 8
    ks = list(jax.random.split(key, n_keys))
    p: dict[str, Any] = {
        "embed": dense_init(ks.pop(), (cfg.vocab_size, cfg.d_model),
                            fan_in=cfg.d_model, dtype=dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks.pop(), (cfg.d_model, cfg.vocab_size), dtype=dtype)

    if plan["prefix"]:
        p["prefix"] = [
            _block_init(ks.pop(), cfg, dense_ffn=True, dtype=dtype)
            for _ in range(plan["prefix"])
        ]
    if plan["groups"]:
        p["groups"] = _stack(
            [
                _stack(
                    [
                        _block_init(ks.pop(), cfg, dense_ffn=False, dtype=dtype)
                        for _ in range(plan["group_len"])
                    ]
                )
                for _ in range(plan["groups"])
            ]
        )
        p["shared_attn"] = _shared_attn_block_init(ks.pop(), cfg, dtype)
    if plan["tail"]:
        p["tail"] = _stack(
            [
                _block_init(ks.pop(), cfg, dense_ffn=False, dtype=dtype)
                for _ in range(plan["tail"])
            ]
        )

    if cfg.vision_prefix:
        p["vision_proj"] = dense_init(
            ks.pop(), (cfg.vision_dim, cfg.d_model), dtype=dtype
        )
    if cfg.mtp_depth:
        p["mtp"] = {
            "proj": dense_init(ks.pop(), (2 * cfg.d_model, cfg.d_model), dtype=dtype),
            "block": _block_init(ks.pop(), cfg, dense_ffn=not cfg.moe, dtype=dtype),
            "norm": jnp.zeros((cfg.d_model,), dtype),
        }
    return p


# =============================================================================
# forward (train / prefill shared body)
# =============================================================================
def _block_apply(
    bp: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    is_global=True,
    dense_ffn: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = constrain(x, BATCH, None, None)
    h = rms_norm(x, bp["norm1"], cfg.norm_eps)
    if cfg.ssm:
        x = x + ssm_mod.mamba_forward(bp["mixer"], h, cfg)
    elif cfg.use_mla:
        x = x + mla_mod.mla_attention(bp["mixer"], h, positions, cfg)
    else:
        x = x + attn.attention(bp["mixer"], h, positions, cfg, is_global=is_global)
    if "ffn" in bp:
        h = rms_norm(x, bp["norm2"], cfg.norm_eps)
        if cfg.moe and not dense_ffn:
            y, aux = moe_mod.moe_apply(bp["ffn"], h, cfg)
            x = x + y
        else:
            x = x + mlp_apply(bp["ffn"], h, cfg.mlp_variant)
    return x, aux


def _shared_attn_apply(sp: dict, x, positions, cfg: ModelConfig) -> jnp.ndarray:
    h = rms_norm(x, sp["norm1"], cfg.norm_eps)
    x = x + attn.attention(sp["attn"], h, positions, cfg, is_global=True)
    h = rms_norm(x, sp["norm2"], cfg.norm_eps)
    return x + mlp_apply(sp["mlp"], h, "gelu")


def _global_flags(cfg: ModelConfig, n: int, offset: int = 0) -> jnp.ndarray:
    return jnp.asarray(
        [cfg.is_global_layer(offset + i) for i in range(n)], jnp.bool_
    )


def _embed_inputs(
    params, cfg: ModelConfig, batch: dict
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token (+ optional vision-prefix) embedding.  Returns (x, positions)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    tok = params["embed"][batch["tokens"]].astype(cdt)
    if cfg.vision_prefix and "patch_embeds" in batch:
        vis = (batch["patch_embeds"].astype(cdt) @ params["vision_proj"]).astype(cdt)
        x = jnp.concatenate([vis, tok], axis=1)
    else:
        x = tok
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    return x, positions


def forward(
    params: dict, batch: dict, cfg: ModelConfig
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (hidden (B,S,D), logits, aux_loss)."""
    x, positions = _embed_inputs(params, cfg, batch)
    x = constrain(x, BATCH, None, None)
    aux_total = jnp.zeros((), jnp.float32)
    plan = _layer_plan(cfg)

    for i in range(plan["prefix"]):
        x, aux = _block_apply(
            params["prefix"][i], x, positions, cfg,
            is_global=cfg.is_global_layer(i), dense_ffn=True,
        )
        aux_total += aux

    if plan["groups"]:
        shared = params["shared_attn"]

        def group_body(carry, gp):
            x, aux_acc = carry

            def layer_body(c, lp):
                xx, aa = c
                xx, aux = _block_apply(lp, xx, positions, cfg)
                return (xx, aa + aux), None

            (x, aux_acc), _ = jax.lax.scan(
                jax.checkpoint(layer_body), (x, aux_acc), gp,
                unroll=scan_unroll(plan["group_len"]),
            )
            x = _shared_attn_apply(shared, x, positions, cfg)
            return (x, aux_acc), None

        (x, aux_total), _ = jax.lax.scan(
            group_body, (x, aux_total), params["groups"],
            unroll=scan_unroll(plan["groups"]),
        )

    if plan["tail"]:
        flags = _global_flags(cfg, plan["tail"], offset=plan["prefix"])

        def tail_body(carry, inp):
            lp, flag = inp
            xx, aa = carry
            xx, aux = _block_apply(lp, xx, positions, cfg, is_global=flag)
            return (xx, aa + aux), None

        (x, aux_total), _ = jax.lax.scan(
            jax.checkpoint(tail_body), (x, aux_total), (params["tail"], flags),
            unroll=scan_unroll(plan["tail"]),
        )

    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(hidden @ head, BATCH, None, "model")
    return x, logits, aux_total


def train_loss(params: dict, batch: dict, cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """Next-token loss (+ MoE aux, + MTP)."""
    pre_final, logits, aux = forward(params, batch, cfg)
    tokens = batch["tokens"]
    n_prefix = logits.shape[1] - tokens.shape[1]  # vision prefix length
    tok_logits = logits[:, n_prefix:]
    loss = next_token_loss(tok_logits, tokens)
    metrics = {"lm_loss": loss, "aux_loss": aux}

    if cfg.mtp_depth:
        # MTP depth-1 (deepseek-v3): combine h_t with emb(tok_{t+1}) to
        # predict tok_{t+2} through one extra block, sharing embed + head.
        mp = params["mtp"]
        h = pre_final[:, n_prefix:]
        cdt = jnp.dtype(cfg.compute_dtype)
        # keep the full S token count (pad the shifted embedding with one zero
        # row, mask its loss): every MoE call then sees B*S tokens, which the
        # expert-parallel shard_map path requires to divide the mesh.
        emb_next = params["embed"][tokens].astype(cdt)
        emb_next = jnp.concatenate(
            [emb_next[:, 1:], jnp.zeros_like(emb_next[:, :1])], axis=1
        )
        h_in = jnp.concatenate([h, emb_next], axis=-1) @ mp["proj"]
        pos = jnp.arange(h_in.shape[1], dtype=jnp.int32)
        h_out, mtp_aux = _block_apply(
            mp["block"], h_in, pos, cfg, dense_ffn=not cfg.moe
        )
        h_out = rms_norm(h_out, mp["norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        mtp_logits = h_out @ head
        mtp_loss = softmax_cross_entropy(mtp_logits[:, :-2], tokens[:, 2:])
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
        aux = aux + mtp_aux

    total = loss + aux
    metrics["total_loss"] = total
    return total, metrics


# =============================================================================
# serving: cache init / prefill / decode
# =============================================================================
def _layer_cache(cfg: ModelConfig, batch: int, max_len: int, i: int, dtype):
    if cfg.ssm:
        return ssm_mod.init_mamba_state(cfg, batch, dtype)
    if cfg.use_mla:
        return mla_mod.init_mla_cache(cfg, batch, max_len, dtype)
    window_cache = bool(cfg.sliding_window) and not cfg.is_global_layer(i)
    return attn.init_kv_cache(
        cfg, batch, max_len, window_cache=window_cache, dtype=dtype
    )


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Decode-state pytree, organized exactly like the layer plan."""
    dtype = jnp.dtype(cfg.compute_dtype)
    plan = _layer_plan(cfg)
    cache: dict[str, Any] = {"t": jnp.zeros((), jnp.int32)}
    if plan["prefix"]:
        cache["prefix"] = [
            _layer_cache(cfg, batch, max_len, i, dtype)
            for i in range(plan["prefix"])
        ]
    if plan["groups"]:
        cache["groups"] = _stack(
            [
                _stack(
                    [
                        _layer_cache(
                            cfg, batch, max_len, g * plan["group_len"] + i, dtype
                        )
                        for i in range(plan["group_len"])
                    ]
                )
                for g in range(plan["groups"])
            ]
        )
        cache["shared"] = [
            attn.init_kv_cache(cfg, batch, max_len, dtype=dtype)
            for _ in range(plan["groups"])
        ]
    if plan["tail"]:
        # NOTE: ring-buffer (windowed) caches differ in shape between local
        # and global layers, which would break scan stacking; the tail cache
        # stacks FULL-length caches when any layer is global, and windowed
        # ones only for the all-local case (pure-local models).
        window_all = bool(cfg.sliding_window) and all(
            not cfg.is_global_layer(plan["prefix"] + i) for i in range(plan["tail"])
        )
        cache["tail"] = _stack(
            [
                (
                    _layer_cache(cfg, batch, max_len, plan["prefix"] + i, dtype)
                    if (cfg.ssm or cfg.use_mla)
                    else attn.init_kv_cache(
                        cfg, batch, max_len, window_cache=window_all, dtype=dtype
                    )
                )
                for i in range(plan["tail"])
            ]
        )
    return cache


def _mixer_decode(bp, x, lcache, t, cfg: ModelConfig, is_global):
    if cfg.ssm:
        y, new = ssm_mod.mamba_decode(bp["mixer"], x, lcache, cfg)
    elif cfg.use_mla:
        y, new = mla_mod.mla_decode(bp["mixer"], x, lcache, t, cfg)
    else:
        y, new = attn.attention_decode(
            bp["mixer"], x, lcache, t, cfg, is_global=is_global
        )
    return y, new


def _block_decode(bp, x, lcache, t, cfg: ModelConfig, *, is_global=True,
                  dense_ffn: bool = False):
    h = rms_norm(x, bp["norm1"], cfg.norm_eps)
    y, new_cache = _mixer_decode(bp, h, lcache, t, cfg, is_global)
    x = x + y
    if "ffn" in bp:
        h = rms_norm(x, bp["norm2"], cfg.norm_eps)
        if cfg.moe and not dense_ffn:
            # serving runs NO-DROP (cf = E/k caps capacity at the group size):
            # inference must not silently drop tokens from experts.
            y, _ = moe_mod.moe_apply(
                bp["ffn"], h, cfg, group_size=h.shape[0],
                capacity_factor=cfg.num_experts / cfg.top_k,
            )
            x = x + y
        else:
            x = x + mlp_apply(bp["ffn"], h, cfg.mlp_variant)
    return x, new_cache


def decode_step(params: dict, cache: dict, tokens_new: jnp.ndarray,
                cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """One decode step for the whole stack.  tokens_new (B, 1) int32.
    Returns (logits (B, 1, V), updated cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    t = cache["t"]
    x = constrain(params["embed"][tokens_new].astype(cdt), BATCH, None, None)
    plan = _layer_plan(cfg)
    new_cache: dict[str, Any] = {"t": t + 1}

    if plan["prefix"]:
        new_cache["prefix"] = []
        for i in range(plan["prefix"]):
            x, nc = _block_decode(
                params["prefix"][i], x, cache["prefix"][i], t, cfg,
                is_global=cfg.is_global_layer(i), dense_ffn=True,
            )
            new_cache["prefix"].append(nc)

    if plan["groups"]:
        shared = params["shared_attn"]
        new_shared = []

        def group_body(x, inp):
            gp, gcache = inp

            def layer_body(xx, lin):
                lp, lc = lin
                xx, nc = _block_decode(lp, xx, lc, t, cfg)
                return xx, nc

            x, ncs = jax.lax.scan(
                layer_body, x, (gp, gcache),
                unroll=scan_unroll(plan["group_len"]),
            )
            return x, ncs

        # shared attention caches are per-group (python loop over 13 groups
        # keeps their independent caches; group mamba layers still scan).
        g_params = params["groups"]
        g_cache = cache["groups"]
        ncs_all = []
        for gi in range(plan["groups"]):
            gp = jax.tree.map(lambda a: a[gi], g_params)
            gc = jax.tree.map(lambda a: a[gi], g_cache)
            x, ncs = group_body(x, (gp, gc))
            ncs_all.append(ncs)
            h = rms_norm(x, shared["norm1"], cfg.norm_eps)
            y, nsc = attn.attention_decode(
                shared["attn"], h, cache["shared"][gi], t, cfg, is_global=True
            )
            x = x + y
            h = rms_norm(x, shared["norm2"], cfg.norm_eps)
            x = x + mlp_apply(shared["mlp"], h, "gelu")
            new_shared.append(nsc)
        new_cache["groups"] = _stack(ncs_all)
        new_cache["shared"] = new_shared

    if plan["tail"]:
        flags = _global_flags(cfg, plan["tail"], offset=plan["prefix"])

        def tail_body(x, inp):
            lp, lc, flag = inp
            x, nc = _block_decode(lp, x, lc, t, cfg, is_global=flag)
            return x, nc

        x, ncs = jax.lax.scan(
            tail_body, x, (params["tail"], cache["tail"], flags),
            unroll=scan_unroll(plan["tail"]),
        )
        new_cache["tail"] = ncs

    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(hidden @ head, BATCH, None, "model")
    return logits, new_cache


def prefill(params: dict, tokens: jnp.ndarray, cfg: ModelConfig,
            max_len: int) -> tuple[jnp.ndarray, dict]:
    """Prefill by stepping decode over the prompt (reference implementation —
    simple and correct for every family; the serving benchmark uses the
    full-sequence forward for throughput numbers)."""
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)

    def body(cache, tok):
        logits, cache = decode_step(params, cache, tok[:, None], cfg)
        return cache, logits[:, 0]

    cache, logits = jax.lax.scan(body, cache, jnp.moveaxis(tokens, 1, 0))
    return jnp.moveaxis(logits, 0, 1), cache
