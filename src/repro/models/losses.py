"""Loss functions, sharding-aware.

The cross-entropy is written so that GSPMD can keep the vocab dimension
sharded end-to-end (one-hot einsum instead of gather; fp32 reductions):
with logits (B, S, V) sharded (data, None, model), the only cross-shard
traffic is the scalar-tree all-reduce of the reductions — the full-logit
gather a take_along_axis would induce never happens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["softmax_cross_entropy", "next_token_loss"]


def softmax_cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray | None = None
) -> jnp.ndarray:
    """logits (..., V) any float dtype; labels (...) int32.  Mean over masked
    positions, fp32."""
    l32 = logits.astype(jnp.float32)
    m = jnp.max(l32, axis=-1, keepdims=True)
    lse = jnp.squeeze(m, -1) + jnp.log(jnp.sum(jnp.exp(l32 - m), axis=-1))
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    ll = jnp.sum(onehot * l32, axis=-1)
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def next_token_loss(
    logits: jnp.ndarray, tokens: jnp.ndarray, *, shift: int = 1
) -> jnp.ndarray:
    """Causal LM loss: logits[:, :-shift] predict tokens[:, shift:]."""
    return softmax_cross_entropy(logits[:, :-shift], tokens[:, shift:])
