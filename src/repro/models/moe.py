"""Mixture-of-Experts FFN: sort-based (dropping) dispatch + shard_map EP.

Dispatch never materializes the GShard (G, S, E, C) one-hot products — for
deepseek-v3's train_4k cell those are ~21 TB each in fp32 and the dispatch
einsum alone costs 2·T·E·C·D ≈ 3e17 FLOPs, ~400x the useful expert FLOPs.
Instead:

  1. argsort the (token, k)-assignments by expert id (stable: earlier
     tokens keep priority, matching GShard's cumsum drop policy),
  2. rank-within-expert via a vmapped searchsorted; rank >= capacity drops,
  3. scatter tokens into the (G, E, C, D) expert buffer (k static scatters
     of (G, S, D), indices unique by construction),
  4. batched expert FFN einsum,
  5. combine: k static gathers weighted by the (renormalized) router gates.

DISTRIBUTION — measured lesson (§Perf iter-1): expressing step 3/5 as
gather/scatter in pure GSPMD is catastrophic.  The SPMD partitioner cannot
shard a scatter/gather whose indexed dim is distributed, so it all-gathers
the (G, E, C, D) expert buffers over ``model`` every layer (~150 GB/layer
for ds3: measured 1.19 TB/dev peak, 36 s collective term).  The production
formulation is explicit: a ``shard_map`` expert-parallel block —

    tokens sharded over (pod, data, model)   [each device routes its own]
    local sort-dispatch into (G_loc, E, C, D)
    lax.all_to_all over 'model' on the E dim        -> owners compute FFN
    lax.all_to_all back, local combine

which moves exactly the true EP payload (tokens·k·cf·D / devices, ~0.55
GB/dev/layer each way on ds3) and nothing else.  Expert weights enter the
block P('model', None, None): the boundary resharding is the standard
FSDP weight all-gather.  The pure-GSPMD path remains for meshes without a
model axis (single-device tests) and for tiny token counts (decode cells,
where the gather's all-gather is bytes-trivial).

Router: softmax -> top-k -> renormalize among the chosen (deepseek V2
convention), with the switch-style load-balance auxiliary loss.

``moe_apply_einsum`` keeps the textbook GShard einsum formulation as the
test oracle: tests assert both production paths match it bit-for-bit in
fp32 at capacity factors where nothing drops, and match its drop policy
when capacity binds.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.models.pspec import BATCH, constrain, current_mesh

__all__ = ["moe_init", "moe_apply", "moe_apply_einsum"]


def moe_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), fan_in=d, dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, f), fan_in=d, dtype=dtype),
        "w_down": dense_init(ks[3], (e, f, d), fan_in=f, dtype=dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d, fs), dtype=dtype),
            "w_up": dense_init(ks[5], (d, fs), dtype=dtype),
            "w_down": dense_init(ks[6], (fs, d), fan_in=fs, dtype=dtype),
        }
    return p


def _capacity(cfg: ModelConfig, group_size: int, capacity_factor: float) -> int:
    c = int(group_size * cfg.top_k / cfg.num_experts * capacity_factor)
    return max(8, (c + 7) // 8 * 8)  # 8-aligned for TPU sublanes


def _group(x: jnp.ndarray, group_size: int) -> jnp.ndarray:
    b, s, d = x.shape
    tokens = b * s
    gs = min(group_size, tokens)
    while tokens % gs:  # snap to the largest divisor (e.g. MTP's B*(S-1))
        gs -= 1
    return x.reshape(tokens // gs, gs, d)


def _route(params, xg, cfg: ModelConfig):
    """Router probs -> (gate_k, idx_k, aux_loss).  fp32 for stability."""
    g, gs, _ = xg.shape
    e, k = cfg.num_experts, cfg.top_k
    logits = xg.astype(jnp.float32) @ params["router"]           # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(probs, k)                      # (G,S,k)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (switch): E * sum_e f_e * p_e.  f_e via bincount —
    # no (G,S,E) one-hot; f is an indicator (no grad path, as standard).
    me = probs.mean(axis=(0, 1))                                 # (E,)
    ce = jnp.bincount(idx_k[..., 0].reshape(-1), length=e) / float(g * gs)
    aux = (
        cfg.router_aux_coef
        * e
        * jnp.sum(me * jax.lax.stop_gradient(ce.astype(jnp.float32)))
    )
    return gate_k, idx_k, aux


def _dispatch_indices(idx_k: jnp.ndarray, e: int, cap: int):
    """(G,S,k) expert ids -> (dst (G,S,k) slot in [0, E*cap], keep (G,S,k)).

    dst == E*cap is the overflow sentinel (dropped assignment); all kept
    dst values are unique within a group by construction.
    """
    g, gs, k = idx_k.shape
    flat = idx_k.reshape(g, gs * k)
    order = jnp.argsort(flat, axis=1, stable=True)               # (G,S*k)
    e_sorted = jnp.take_along_axis(flat, order, axis=1)
    # first sorted position of each expert -> rank within expert
    starts = jax.vmap(lambda es: jnp.searchsorted(es, jnp.arange(e)))(e_sorted)
    rank = jnp.arange(gs * k)[None, :] - jnp.take_along_axis(
        starts, e_sorted, axis=1
    )                                                            # (G,S*k)
    keep_sorted = rank < cap
    dst_sorted = jnp.where(keep_sorted, e_sorted * cap + rank, e * cap)
    # unsort back to (s, k) layout
    garange = jnp.arange(g)[:, None]
    dst = jnp.zeros((g, gs * k), jnp.int32).at[garange, order].set(
        dst_sorted.astype(jnp.int32)
    )
    keep = jnp.zeros((g, gs * k), bool).at[garange, order].set(keep_sorted)
    return dst.reshape(g, gs, k), keep.reshape(g, gs, k)


def _expert_ffn(xe, params):
    """xe (..., E_loc, C, D) x expert-stacked weights -> (..., E_loc, C, D)."""
    hgate = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", xe, params["w_gate"]))
    hup = jnp.einsum("...ecd,edf->...ecf", xe, params["w_up"])
    return jnp.einsum("...ecf,efd->...ecd", hgate * hup, params["w_down"])


def _dispatch_ffn_combine_local(routed_params, xg, gate_k, idx_k, cfg, cap):
    """Steps 3-5 on local (already-sharded or unsharded) groups."""
    g, gs, d = xg.shape
    e, k = cfg.num_experts, cfg.top_k
    cdt = jnp.dtype(cfg.compute_dtype)

    dst, keep = _dispatch_indices(idx_k, e, cap)
    gate_k = gate_k * keep.astype(gate_k.dtype)                  # drop overflow

    garange = jnp.arange(g)[:, None]
    xe_flat = jnp.zeros((g, e * cap + 1, d), cdt)
    xgc = xg.astype(cdt)
    for j in range(k):
        xe_flat = xe_flat.at[garange, dst[:, :, j]].set(
            xgc, mode="drop", unique_indices=True
        )
    xe = xe_flat[:, : e * cap].reshape(g, e, cap, d)

    he = _expert_ffn(xe, routed_params)

    he_flat = jnp.concatenate(
        [he.reshape(g, e * cap, d), jnp.zeros((g, 1, d), he.dtype)], axis=1
    )
    y = jnp.zeros((g, gs, d), cdt)
    for j in range(k):
        yj = he_flat[garange, dst[:, :, j]]                      # (G,S,D)
        y = y + yj * gate_k[:, :, j, None].astype(cdt)
    return y


def _moe_gspmd(params, x, cfg, group_size, capacity_factor):
    """Pure-GSPMD path: single device / no model axis / tiny token counts."""
    b, s, d = x.shape
    xg = constrain(_group(x, group_size), BATCH, None, None)
    cap = _capacity(cfg, xg.shape[1], capacity_factor)
    gate_k, idx_k, aux = _route(params, xg, cfg)
    routed = {n: params[n] for n in ("w_gate", "w_up", "w_down")}
    y = _dispatch_ffn_combine_local(routed, xg, gate_k, idx_k, cfg, cap)
    return constrain(y, BATCH, None, None).reshape(b, s, d), aux


def _moe_ep(params, x, cfg, mesh, group_size, capacity_factor):
    """shard_map expert parallelism: tokens sharded over every mesh axis,
    experts owned by 'model' ranks, dispatch/return as explicit all-to-alls."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    ep = mesh.shape["model"]
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    tok_axes = (*dp_axes, "model")
    n_dev = mesh.size
    # Explicit reshard staging (measured, §Perf ds3 iter-3): without these
    # constraints the partitioner faces [tokens-sharded] -> [residual-layout]
    # cotangent reshards it cannot express and falls back to "involuntary
    # full rematerialization" — fully-replicated fp32 (B,S,D) buffers and
    # full-tensor all-reduces every MoE layer.
    x = constrain(x, BATCH, None, None)
    toks = constrain(x.reshape(b * s, d), (*dp_axes, "model"), None)
    t_loc = toks.shape[0] // n_dev
    gs = min(group_size, t_loc)
    while t_loc % gs:  # snap to the largest local divisor (odd token counts)
        gs -= 1
    cap = _capacity(cfg, gs, capacity_factor)

    def block(router, w_gate, w_up, w_down, toks_loc):
        xg = toks_loc.reshape(-1, gs, d)                         # (G_loc,S,D)
        gate_k, idx_k, aux = _route({"router": router}, xg, cfg)
        dst, keep = _dispatch_indices(idx_k, e, cap)
        gate_k = gate_k * keep.astype(gate_k.dtype)

        g = xg.shape[0]
        garange = jnp.arange(g)[:, None]
        cdt = jnp.dtype(cfg.compute_dtype)
        xgc = xg.astype(cdt)
        xe_flat = jnp.zeros((g, e * cap + 1, d), cdt)
        for j in range(k):
            xe_flat = xe_flat.at[garange, dst[:, :, j]].set(
                xgc, mode="drop", unique_indices=True
            )
        xe = xe_flat[:, : e * cap].reshape(g, e, cap, d)

        # -> expert owners: (G_loc, E, C, D) -> (G_loc*ep, E/ep, C, D)
        xe = jax.lax.all_to_all(xe, "model", split_axis=1, concat_axis=0,
                                tiled=True)
        he = _expert_ffn(xe, {"w_gate": w_gate, "w_up": w_up, "w_down": w_down})
        # <- back to token owners
        he = jax.lax.all_to_all(he, "model", split_axis=0, concat_axis=1,
                                tiled=True)

        he_flat = jnp.concatenate(
            [he.reshape(g, e * cap, d), jnp.zeros((g, 1, d), he.dtype)], axis=1
        )
        y = jnp.zeros((g, gs, d), cdt)
        for j in range(k):
            yj = he_flat[garange, dst[:, :, j]]
            y = y + yj * gate_k[:, :, j, None].astype(cdt)
        aux = jax.lax.pmean(aux, dp_axes + ("model",))
        return y.reshape(-1, d), aux

    y, aux = shard_map(
        block,
        mesh=mesh,
        in_specs=(
            P(),                      # router: replicated (D x E is small)
            P("model", None, None),   # expert stacks: E owned by model ranks
            P("model", None, None),
            P("model", None, None),
            P(tok_axes, None),        # tokens: fully sharded
        ),
        out_specs=(P(tok_axes, None), P()),
        check_vma=False,
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"], toks)
    y = constrain(y, tok_axes, None)
    y = constrain(y.reshape(b, s, d), BATCH, None, None)
    return y, aux


def moe_apply(
    params: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    group_size: int = 2048,
    capacity_factor: float | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    if capacity_factor is None:
        capacity_factor = cfg.capacity_factor
    b, s, d = x.shape
    tokens = b * s
    mesh = current_mesh()
    use_ep = (
        mesh is not None
        and "model" in mesh.axis_names
        and mesh.shape["model"] > 1
        and cfg.num_experts % mesh.shape["model"] == 0
        and tokens % mesh.size == 0
        and tokens // mesh.size >= 64   # decode cells: payload too small for EP
    )
    if use_ep:
        y, aux = _moe_ep(params, x, cfg, mesh, group_size, capacity_factor)
    else:
        y, aux = _moe_gspmd(params, x, cfg, group_size, capacity_factor)

    # -- shared experts (dense on all tokens; TP via GSPMD like any MLP) ------
    if "shared" in params:
        cdt = jnp.dtype(cfg.compute_dtype)
        sp = params["shared"]
        xc = x.astype(cdt)
        hs = jax.nn.silu(xc @ sp["w_gate"]) * (xc @ sp["w_up"])
        y = y + hs @ sp["w_down"]

    return y.astype(x.dtype), aux


# =============================================================================
# reference: textbook GShard einsum dispatch (test oracle; O(S^2·E·C) memory —
# never use on large cells)
# =============================================================================
def moe_apply_einsum(
    params: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    group_size: int = 2048,
    capacity_factor: float = 1.25,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    xg = _group(x, group_size)
    g, gs, _ = xg.shape
    cap = _capacity(cfg, gs, capacity_factor)

    gate_k, idx_k, aux = _route(params, xg, cfg)

    # capacity positions: cumulative count of each expert along (s, k) order
    oh = jax.nn.one_hot(idx_k, e, dtype=jnp.float32)              # (G,S,k,E)
    flat = oh.reshape(g, gs * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(g, gs, k, e)
    pos = jnp.einsum("gske,gske->gsk", pos, oh)                   # (G,S,k)
    keep = pos < cap
    gate_k = gate_k * keep.astype(gate_k.dtype)

    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    pos_oh = pos_oh * keep[..., None]
    dispatch = jnp.einsum("gske,gskc->gsec", oh, pos_oh)          # 0/1
    combine = jnp.einsum("gsk,gske,gskc->gsec", gate_k, oh, pos_oh)

    cdt = jnp.dtype(cfg.compute_dtype)
    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(cdt), xg.astype(cdt))
    hgate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]))
    hup = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    he = jnp.einsum("gecf,efd->gecd", hgate * hup, params["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(cdt), he)

    if "shared" in params:
        sp = params["shared"]
        xgc = xg.astype(cdt)
        hs = jax.nn.silu(xgc @ sp["w_gate"]) * (xgc @ sp["w_up"])
        y = y + hs @ sp["w_down"]

    return y.reshape(b, s, d).astype(x.dtype), aux
