"""Sharding rules: param/batch/cache pytrees -> PartitionSpecs.

Strategy (1000+-chip posture):
  * params — TP over ``model`` (attention heads / FFN hidden / vocab /
    experts) + FSDP over ``data`` on the complementary dim; replicated over
    ``pod`` (gradients cross pods once per step — the hierarchical-DCN
    pattern).  Scan-stacked leading dims are never sharded.
  * batch — over every non-model axis; falls back to replication when the
    global batch does not divide the shard count (long_500k's batch=1).
  * caches/states — batch-sharded; the KV/state "width" dim shards over
    ``model`` when divisible (heads for GQA, SSM heads for mamba); otherwise
    the SEQUENCE dim shards over ``model`` (sequence-parallel attention —
    MQA and long-context cells), so no cell ever leaves the model axis idle.

Rules are name-based over tree paths, rank-generalized: a leaf's base spec
is right-aligned and leading (scan) dims get None.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes
from repro.models.config import ModelConfig

__all__ = [
    "param_specs",
    "param_shardings",
    "batch_specs",
    "cache_specs",
    "tree_shardings",
]

FSDP = "data"
TP = "model"

# leaf name -> base spec (right-aligned over the trailing dims)
_BASE_RULES: dict[str, tuple] = {
    # embeddings / heads
    "embed": (TP, FSDP),          # (V, D): vocab over model => sharded xent
    "lm_head": (FSDP, TP),        # (D, V)
    "pos_dec": (None, None),
    "vision_proj": (None, FSDP),
    # attention
    "wq": (FSDP, TP),
    "wk": (FSDP, TP),
    "wv": (FSDP, TP),
    "wo": (TP, FSDP),
    "bq": (TP,),
    "bk": (TP,),
    "bv": (TP,),
    # MLA
    "wq_a": (FSDP, None),
    "wq_b": (None, TP),
    "wkv_a": (FSDP, None),
    "wkv_b": (None, TP),
    # dense MLP
    "w_gate": (FSDP, TP),
    "w_up": (FSDP, TP),
    "w_down": (TP, FSDP),
    # MoE (expert-stacked leaves are rank-3; E is the leading dim => EP)
    "router": (FSDP, None),
    "moe.w_gate": (TP, FSDP, None),
    "moe.w_up": (TP, FSDP, None),
    "moe.w_down": (TP, None, FSDP),
    # mamba
    "w_in": (FSDP, TP),
    "w_out": (TP, FSDP),
    "conv_w": (None, TP),
    "conv_b": (TP,),
    "gate_norm": (TP,),
    # mtp
    "proj": (FSDP, TP),
}

_MOE_PARENT = "ffn"  # MoE leaves live under layers' "ffn" subtree


def _leaf_rule(path: tuple, leaf) -> tuple:
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    # expert-stacked MoE weights: under ffn with rank >= 3 base
    if name in ("w_gate", "w_up", "w_down") and _MOE_PARENT in names:
        # distinguish MoE expert stacks from the (dense) "shared" experts
        if "shared" not in names:
            return _BASE_RULES[f"moe.{name}"]
    return _BASE_RULES.get(name, ())


def _right_align(base: tuple, ndim: int) -> P:
    if not base:
        return P()
    if ndim < len(base):
        # scalar-ish leaf (reduced configs can shrink ranks); replicate
        return P()
    return P(*((None,) * (ndim - len(base)) + tuple(base)))


def _drop_missing_axes(spec: P, mesh) -> P:
    """Replace axis names absent from the mesh with None (elasticity)."""
    cleaned = []
    for s in spec:
        if s is None:
            cleaned.append(None)
        elif isinstance(s, (tuple, list)):
            kept = tuple(a for a in s if a in mesh.axis_names)
            cleaned.append(kept if kept else None)
        else:
            cleaned.append(s if s in mesh.axis_names else None)
    return P(*cleaned)


def _divisible(spec: P, shape: tuple, mesh) -> P:
    """Drop shardings that do not divide the dim (GSPMD would pad; for
    tiny dims — MQA's single KV head — padding 15/16 of the axis is worse
    than replicating)."""
    out = []
    for dim, s in zip(shape, spec):
        if s is None:
            out.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(s if dim % size == 0 and dim >= size else None)
    return P(*out)


def param_specs(params_shape: Any, cfg: ModelConfig, mesh) -> Any:
    """PartitionSpec pytree matching a params pytree (arrays or
    ShapeDtypeStructs)."""

    def one(path, leaf):
        base = _leaf_rule(path, leaf)
        spec = _right_align(base, leaf.ndim)
        spec = _drop_missing_axes(spec, mesh)
        # pad spec to rank
        spec = P(*(tuple(spec) + (None,) * (leaf.ndim - len(spec))))
        return _divisible(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def tree_shardings(spec_tree: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_shardings(params_shape: Any, cfg: ModelConfig, mesh) -> Any:
    return tree_shardings(param_specs(params_shape, cfg, mesh), mesh)


def _batch_spec_first_dim(global_batch: int, mesh) -> Optional[tuple]:
    ba = batch_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in ba]))
    if global_batch % size == 0 and global_batch >= size:
        return ba
    # try data-only
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_specs(batch_shape: Any, mesh) -> Any:
    """Sharding specs for a training/prefill batch pytree (tokens, frames,
    patch_embeds...): first dim over the batch axes, rest replicated."""

    def one(leaf):
        first = _batch_spec_first_dim(leaf.shape[0], mesh)
        return P(*((first,) + (None,) * (leaf.ndim - 1)))

    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape: Any, cfg: ModelConfig, mesh) -> Any:
    """Decode-state sharding.  Name-aware: see module docstring."""
    tp_size = mesh.shape[TP] if TP in mesh.axis_names else 1

    def one(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        if leaf.ndim == 0:
            return P()
        if name == "t":
            return P()
        if name in ("k", "v"):          # (.., B, S, KV, hd)
            base = ["__batch__", None, None, None]
        elif name == "pos":              # (.., B, S)
            base = ["__batch__", None]
        elif name in ("c_kv", "k_pe"):   # (.., B, S, R/pe) — MLA latent
            base = ["__batch__", TP if leaf.shape[-2] % tp_size == 0 else None, None]
        elif name == "ssm":              # (.., B, H, P, N)
            base = [
                "__batch__",
                TP if leaf.shape[-3] % tp_size == 0 else None,
                None,
                None,
            ]
        elif name == "conv":             # (.., B, W-1, C)
            base = ["__batch__", None, TP if leaf.shape[-1] % tp_size == 0 else None]
        elif name in ("self_k", "self_v", "mem_k", "mem_v"):  # (L,B,S,H,hd)
            heads_ok = leaf.shape[-2] % tp_size == 0
            base = [
                None, "__batch__",
                None if heads_ok else TP,
                TP if heads_ok else None,
                None,
            ]
        else:
            return P(*([None] * leaf.ndim))
        if name in ("k", "v"):
            heads_ok = leaf.shape[-2] % tp_size == 0
            if heads_ok:
                base[-2] = TP          # shard KV heads
            elif leaf.shape[-3] % tp_size == 0:
                base[-3] = TP          # MQA: sequence-parallel cache
        # batch placement: the '__batch__' slot may not be base[0] (enc-dec
        # caches carry a leading layer-stack dim)
        b_slot = base.index("__batch__")
        batch_size = leaf.shape[leaf.ndim - len(base) + b_slot]
        base[b_slot] = _batch_spec_first_dim(batch_size, mesh)
        spec = P(*((None,) * (leaf.ndim - len(base)) + tuple(base)))
        return _divisible(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, cache_shape)
