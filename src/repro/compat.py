"""Version-tolerant imports for moving jax APIs.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace (and renamed ``check_rep`` to ``check_vma``) across jax
releases.  Model/optim code writes against the new-style surface
(``check_vma=...``); this shim adapts to whichever the installed jax ships.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "ad_barrier"]


@jax.custom_vjp
def ad_barrier(x):
    """``jax.lax.optimization_barrier`` with an explicit AD rule.

    Newer jax differentiates the barrier by barriering the (co)tangents;
    jax 0.4.37 has no rule at all and raises under ``jax.grad``.  This wrapper
    reproduces the new-jax behavior everywhere: barrier on the primal, barrier
    on the cotangent (so e.g. a bf16 boundary stays bf16 in the backward pass).
    """
    return jax.lax.optimization_barrier(x)


def _ad_barrier_fwd(x):
    return ad_barrier(x), None


def _ad_barrier_bwd(_, ct):
    return (jax.lax.optimization_barrier(ct),)


ad_barrier.defvjp(_ad_barrier_fwd, _ad_barrier_bwd)

try:  # jax >= 0.6 style: top-level export, check_vma kwarg
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    shard_map = _shard_map
except ImportError:  # older jax: experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh=None, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None and "check_rep" not in kw:
            kw["check_rep"] = check_vma
        return _exp_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
