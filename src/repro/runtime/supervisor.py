"""Reliability runtime (paper §3.1.2–3.1.3).

  * ``Supervisor`` — runs scheduler-issued jobs with retry/backoff, records
    health metrics, raises alerts on non-recoverable failures, and keeps the
    scheduler's state checkpointable between steps.
  * ``SpeculativeExecutor`` — straggler mitigation for sharded work: launch
    the same shard on a backup worker when the primary exceeds the deadline,
    take whichever finishes first (idempotent merges make duplicate
    completion safe — the same §4.5 argument that makes retries safe).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.core.materializer import Materializer
from repro.core.monitoring import HealthMonitor
from repro.core.scheduler import Scheduler

__all__ = ["Supervisor", "SpeculativeExecutor", "WorkerPool"]


class Supervisor:
    """Drives queued materialization jobs to completion."""

    def __init__(
        self,
        scheduler: Scheduler,
        materializer: Materializer,
        monitor: HealthMonitor,
        *,
        spec_resolver: Callable[[str, int], object],
        source_resolver: Callable[[str], object],
        checkpoint_hook: Optional[Callable[[str], None]] = None,
    ) -> None:
        self.scheduler = scheduler
        self.materializer = materializer
        self.monitor = monitor
        self.spec_resolver = spec_resolver
        self.source_resolver = source_resolver
        self.checkpoint_hook = checkpoint_hook

    def drain(self, max_jobs: Optional[int] = None) -> dict[str, int]:
        """Run queued jobs (retrying failures) until the queue is empty or
        ``max_jobs`` executions happened.  Returns outcome counts."""
        stats = {"succeeded": 0, "retried": 0, "failed": 0}
        executed = 0
        while True:
            runnable = self.scheduler.runnable_jobs()
            if not runnable or (max_jobs is not None and executed >= max_jobs):
                break
            job = runnable[0]
            executed += 1
            self.scheduler.mark_running(job.job_id)
            spec = self.spec_resolver(job.feature_set, job.version)
            source = self.source_resolver(spec.source_name)
            try:
                self.materializer.run_job(job, spec, source)
            except Exception as exc:  # noqa: BLE001 — any job error is retryable
                will_retry = self.scheduler.mark_failed(job.job_id, str(exc))
                self.monitor.record_job(success=False, retried=will_retry)
                if will_retry:
                    stats["retried"] += 1
                else:
                    stats["failed"] += 1
                    self.monitor.alert(self.scheduler.alerts[-1])
            else:
                self.scheduler.mark_succeeded(job.job_id)
                self.monitor.record_job(success=True)
                stats["succeeded"] += 1
            if self.checkpoint_hook:
                self.checkpoint_hook(self.scheduler.to_json())
        return stats


@dataclasses.dataclass
class _ShardRun:
    shard: int
    worker: str
    elapsed: float
    result: object


class WorkerPool:
    """A deterministic simulated worker pool with per-worker speed factors —
    lets tests create stragglers without wall-clock sleeps."""

    def __init__(self, speeds: dict[str, float]):
        if not speeds:
            raise ValueError("need at least one worker")
        self.speeds = speeds  # worker -> multiplier on task cost (1.0 nominal)

    def run(self, worker: str, cost: float, fn: Callable[[], object]) -> _ShardRun:
        start = time.perf_counter()
        result = fn()
        elapsed = time.perf_counter() - start
        # Model the worker's slowness on top of real compute time.
        return _ShardRun(-1, worker, (elapsed + cost) * self.speeds[worker], result)


class SpeculativeExecutor:
    """Deadline-based speculative re-execution of sharded work (§3.1.2)."""

    def __init__(self, pool: WorkerPool, deadline_factor: float = 2.0):
        self.pool = pool
        self.deadline_factor = deadline_factor
        self.speculated: list[int] = []

    def run_shards(
        self,
        shards: list[int],
        fn: Callable[[int], object],
        *,
        shard_cost: float = 1.0,
    ) -> dict[int, object]:
        """Assign shards round-robin; when a worker's modeled latency exceeds
        deadline_factor x the median, re-execute on the fastest worker and
        take the earlier completion."""
        workers = list(self.pool.speeds)
        runs: dict[int, _ShardRun] = {}
        for i, shard in enumerate(shards):
            w = workers[i % len(workers)]
            runs[shard] = self.pool.run(w, shard_cost, lambda s=shard: fn(s))
            runs[shard].shard = shard
        lat = sorted(r.elapsed for r in runs.values())
        median = lat[len(lat) // 2]
        fastest = min(workers, key=lambda w: self.pool.speeds[w])
        for shard, run in list(runs.items()):
            if run.elapsed > self.deadline_factor * median:
                self.speculated.append(shard)
                backup = self.pool.run(fastest, shard_cost, lambda s=shard: fn(s))
                backup.shard = shard
                if backup.elapsed < run.elapsed:
                    runs[shard] = backup
        return {s: r.result for s, r in runs.items()}
