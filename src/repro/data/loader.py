"""Feature-store-backed LM training data pipeline.

The feature store IS the data plane (DESIGN.md §3): token-chunk events are
materialized into the offline store like any feature set, and training
batches are produced by point-in-time retrieval at the run's data clock —
the model can never read tokens from the future of its observation time
(the §4.4 leakage guarantee applied to pretraining data), which the
integration tests assert as a property.

Determinism & distribution:
  * batch content is a pure function of (seed, step) — restart-stable;
  * data-parallel ranks read disjoint document slices (doc_id % world == rank),
    the same contract a multi-host input pipeline needs;
  * the loader cursor (clock) checkpoints alongside the train state.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import UDFTransform
from repro.core.featurestore import FeatureStore
from repro.core.offline_store import EVENT_TS
from repro.core.table import Table
from repro.data.sources import TokenEventSource

__all__ = ["TokenFeatureSet", "FeatureStoreLoader"]

HOUR = 3_600_000


def TokenFeatureSet(source: TokenEventSource, *, version: int = 1) -> FeatureSetSpec:
    """Feature set materializing raw token chunks (identity transform)."""
    features = tuple(
        Feature(f"tok_{j}", "float32") for j in range(source.chunk_len)
    )

    def identity(df: Table, ctx: dict) -> Table:
        return df.rename({"doc_id": "doc_id"})

    return FeatureSetSpec(
        name="token_chunks",
        version=version,
        entity=Entity("document", ("doc_id",)),
        features=features,
        source_name=source.name,
        transform=UDFTransform(identity, name="identity_chunks"),
        timestamp_col="ts",
        source_lookback=0,
        materialization=MaterializationSettings(
            offline_enabled=True,
            online_enabled=True,
            schedule_interval=HOUR,
        ),
    )


@dataclasses.dataclass
class FeatureStoreLoader:
    store: FeatureStore
    spec: FeatureSetSpec
    seq_len: int
    batch_size: int
    chunk_len: int
    seed: int = 0
    rank: int = 0
    world: int = 1
    clock: int = 0  # data-availability clock (ms); checkpointed

    def advance(self, to: int) -> None:
        """Materialize everything due before ``to`` and move the clock."""
        self.clock = max(self.clock, to)
        self.store.tick(now=self.clock)

    # -- batch construction ------------------------------------------------
    def _history(self) -> Table:
        return self.store.offline.read(self.spec.name, self.spec.version)

    def sample_batch(self, step: int) -> dict:
        """(seed, step)-deterministic batch, PIT-correct at the clock."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.rank])
        )
        hist = self._history()
        if len(hist) == 0:
            raise RuntimeError("no materialized token chunks; call advance()")
        ts0 = self.clock
        eligible = hist.filter(
            (hist[EVENT_TS] <= ts0 - self.spec.expected_delay)
            & (hist["doc_id"] % self.world == self.rank)
        )
        if len(eligible) == 0:
            raise RuntimeError(f"rank {self.rank} has no eligible chunks")
        # newest-last ordering per doc
        eligible = eligible.take(
            np.lexsort((eligible[EVENT_TS], eligible["doc_id"]))
        )
        docs = np.unique(eligible["doc_id"])
        chosen = rng.choice(docs, size=self.batch_size, replace=True)

        n_chunks = -(-self.seq_len // self.chunk_len)
        tok_cols = [f"tok_{j}" for j in range(self.chunk_len)]
        toks = np.stack([eligible[c] for c in tok_cols], axis=1).astype(np.int64)

        batch = np.zeros((self.batch_size, n_chunks * self.chunk_len), np.int64)
        max_ev = np.zeros(self.batch_size, np.int64)
        doc_rows: dict[int, np.ndarray] = {}
        doc_ids_col = eligible["doc_id"]
        for i, d in enumerate(chosen):
            rows = doc_rows.get(int(d))
            if rows is None:
                rows = np.nonzero(doc_ids_col == d)[0]
                doc_rows[int(d)] = rows
            take = rows[-n_chunks:]
            seq = toks[take].reshape(-1)
            batch[i, -len(seq):] = seq  # left-pad with 0 when history is short
            max_ev[i] = eligible[EVENT_TS][take].max()
        return {
            "tokens": batch[:, : self.seq_len].astype(np.int32),
            "__max_event_ts__": max_ev,  # leakage-property hook (tests)
            "__observation_ts__": np.full(self.batch_size, ts0, np.int64),
        }

    # -- checkpoint integration ------------------------------------------------
    def state_dict(self) -> dict:
        return {"clock": self.clock, "seed": self.seed}

    def load_state_dict(self, d: dict) -> None:
        self.clock = int(d["clock"])
        self.seed = int(d["seed"])
