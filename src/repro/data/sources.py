"""Source systems (paper Fig. 2 'data sources').

Deterministic synthetic event streams: every read of the same window returns
identical rows (a property the materialization retry/consistency story
relies on, and that real sources provide via snapshot isolation).  Events are
generated per (entity, time-bucket) from a counter-based RNG, so reads are
O(window) regardless of history length and reproducible across processes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.table import Table

__all__ = ["SyntheticEventSource", "TokenEventSource"]


def _bucket_rng(seed: int, bucket: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, bucket]))


@dataclasses.dataclass
class SyntheticEventSource:
    """Numeric business events: (entity_id, ts, amount, quantity)."""

    name: str
    seed: int = 0
    num_entities: int = 100
    events_per_bucket: int = 50
    bucket_ms: int = 3_600_000  # one hour of simulated time
    #: late-arrival modelling: events land up to this many ms after their
    #: nominal bucket (exercises §4.4 delay handling).
    max_jitter_ms: int = 0

    def read(self, start_ts: int, end_ts: int) -> Table:
        start_ts = max(start_ts, 0)  # the event timeline starts at 0
        if end_ts <= start_ts:
            return Table(
                {
                    "entity_id": np.zeros(0, np.int64),
                    "ts": np.zeros(0, np.int64),
                    "amount": np.zeros(0, np.float32),
                    "quantity": np.zeros(0, np.float32),
                }
            )
        b0 = start_ts // self.bucket_ms
        b1 = (end_ts - 1) // self.bucket_ms
        ids, ts, amount, qty = [], [], [], []
        for b in range(b0, b1 + 1):
            rng = _bucket_rng(self.seed, b)
            n = self.events_per_bucket
            e = rng.integers(0, self.num_entities, n)
            t = b * self.bucket_ms + rng.integers(0, self.bucket_ms, n)
            if self.max_jitter_ms:
                t = t + rng.integers(0, self.max_jitter_ms, n)
            a = rng.gamma(2.0, 50.0, n).astype(np.float32)
            q = rng.integers(1, 10, n).astype(np.float32)
            ids.append(e)
            ts.append(t)
            amount.append(a)
            qty.append(q)
        tab = Table(
            {
                "entity_id": np.concatenate(ids).astype(np.int64),
                "ts": np.concatenate(ts).astype(np.int64),
                "amount": np.concatenate(amount),
                "quantity": np.concatenate(qty),
            }
        )
        m = (tab["ts"] >= start_ts) & (tab["ts"] < end_ts)
        out = tab.filter(m)
        return out.take(np.argsort(out["ts"], kind="stable"))


@dataclasses.dataclass
class TokenEventSource:
    """Token-sequence events for the LM data pipeline: each event is one
    document chunk (entity = document id) carrying ``chunk_len`` token ids.

    This is how the feature store becomes the training data plane: chunks are
    materialized like any feature, then PIT-retrieved as training batches
    (launch/train.py), guaranteeing the model never reads tokens "from the
    future" of its data-availability clock.
    """

    name: str
    seed: int = 0
    vocab_size: int = 32_000
    num_docs: int = 512
    chunk_len: int = 128
    chunks_per_bucket: int = 64
    bucket_ms: int = 3_600_000

    def read(self, start_ts: int, end_ts: int) -> Table:
        start_ts = max(start_ts, 0)  # the event timeline starts at 0
        end_ts = max(end_ts, 1)
        cols: dict[str, list[np.ndarray]] = {"doc_id": [], "ts": []}
        tok_cols: list[np.ndarray] = []
        b0 = start_ts // self.bucket_ms
        b1 = max(b0, (end_ts - 1) // self.bucket_ms)
        for b in range(b0, b1 + 1):
            rng = _bucket_rng(self.seed, b)
            n = self.chunks_per_bucket
            cols["doc_id"].append(rng.integers(0, self.num_docs, n).astype(np.int64))
            cols["ts"].append(
                (b * self.bucket_ms + rng.integers(0, self.bucket_ms, n)).astype(
                    np.int64
                )
            )
            # Zipfian-ish token stream, reproducible per bucket.
            toks = (
                rng.zipf(1.3, size=(n, self.chunk_len)).astype(np.int64)
                % self.vocab_size
            )
            tok_cols.append(toks)
        table_cols: dict[str, np.ndarray] = {
            "doc_id": np.concatenate(cols["doc_id"]),
            "ts": np.concatenate(cols["ts"]),
        }
        toks = np.concatenate(tok_cols, axis=0)
        for j in range(self.chunk_len):
            table_cols[f"tok_{j}"] = toks[:, j].astype(np.float32)
        tab = Table(table_cols)
        m = (tab["ts"] >= start_ts) & (tab["ts"] < end_ts)
        out = tab.filter(m)
        return out.take(np.argsort(out["ts"], kind="stable"))
