"""HLO inspection helpers for the §Perf loop (dry-run profiling on CPU).

``top_tensors`` ranks the largest tensor shapes appearing in a compiled
module — the closest thing to a buffer-assignment profile the public API
exposes, and in practice it finds the memory hogs (score matrices,
dispatch buffers, fp32 optimizer temporaries) immediately.

``collective_sites`` groups collective ops by (kind, shape) so a single
pathological all-gather inserted per layer shows up as count=num_layers.
"""

from __future__ import annotations

import re
from collections import Counter, defaultdict

from repro.launch.roofline import _DTYPE_BYTES, _SHAPE_RE

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(\S+)\s+([\w\-]+)")


def _bytes_of(dt: str, dims: str) -> int:
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def top_tensors(hlo_text: str, k: int = 15) -> list[tuple[str, int, int]]:
    """[(shape_str, bytes, count)] for the k largest distinct result shapes."""
    seen: Counter = Counter()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shp = m.group(1)
        sm = _SHAPE_RE.search(shp)
        if not sm:
            continue
        seen[sm.group(0)] += 1
    ranked = sorted(
        ((s, _bytes_of(*_SHAPE_RE.match(s).groups()), c) for s, c in seen.items()),
        key=lambda t: -t[1],
    )
    return ranked[:k]


def collective_sites(hlo_text: str, k: int = 15) -> list[dict]:
    """Collectives grouped by (op kind, operand shape): count + total bytes."""
    from repro.launch.roofline import _COLLECTIVES, _INSTR_RE

    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    groups: dict[tuple, dict] = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        call = line[line.find(op):]
        lp = call.find("(")
        operand_bytes = 0
        shape_key = "?"
        if lp >= 0:
            refs = re.findall(r"%[\w\.\-]+", call[lp:])
            for ref in refs:
                s = shapes.get(ref, "")
                b = sum(
                    _bytes_of(*mm.groups()) for mm in _SHAPE_RE.finditer(s)
                )
                if b:
                    operand_bytes += b
                    shape_key = s[:60]
        g = groups[(kind, shape_key)]
        g["count"] += 1
        g["bytes"] += operand_bytes
        nm = re.search(r'op_name="([^"]+)"', line)
        if nm:
            g.setdefault("op_names", set()).add(nm.group(1)[-80:])
    out = [
        {"kind": k_[0], "shape": k_[1], **v, "op_names": sorted(v.get("op_names", []))[:4]}
        for k_, v in groups.items()
    ]
    return sorted(out, key=lambda d: -d["bytes"])[:k]
