"""Step functions: the units the dry-run lowers and the drivers execute.

  * train_step — fwd + bwd + optimizer update (donated state)
  * serve_step — one decode token against a KV/state cache (donated cache)
  * prefill_step — full-sequence logits (the prefill-throughput unit)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ModelConfig
from repro.optim.adamw import Optimizer

__all__ = ["TrainState", "make_train_step", "make_serve_step", "make_prefill_step"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jnp.ndarray

    @staticmethod
    def create(params, optimizer: Optimizer) -> "TrainState":
        return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32))


def make_train_step(
    cfg: ModelConfig, optimizer: Optimizer, *, num_microbatches: int = 1
) -> Callable:
    """fwd+bwd+update.  num_microbatches > 1 runs gradient accumulation over
    batch slices (a lax.scan): per-microbatch activation memory is 1/µ of the
    full batch while the math (sum of per-slice mean grads / µ) is identical.
    This is THE memory lever for the big train cells — the per-layer saved
    residual stream is O(tokens·d_model) and dominates peak HBM at B=256·4k.
    """

    def grads_of(params, batch):
        def loss_fn(p):
            return api.train_loss(p, batch, cfg)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if num_microbatches == 1:
            (_, metrics), grads = grads_of(state.params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(
                    (num_microbatches, x.shape[0] // num_microbatches)
                    + x.shape[1:]
                ),
                batch,
            )

            def micro(acc, b_i):
                (_, metrics), g = grads_of(state.params, b_i)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g
                )
                return acc, metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            from repro.models.pspec import scan_unroll

            acc, metrics_all = jax.lax.scan(
                micro, zeros, mb, unroll=scan_unroll(num_microbatches)
            )
            grads = jax.tree.map(lambda a: a / num_microbatches, acc)
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)

        new_params, new_opt = optimizer.update(grads, state.opt, state.params)
        new_state = TrainState(new_params, new_opt, state.step + 1)
        return new_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, tokens_new):
        logits, cache = api.decode_step(params, cache, tokens_new, cfg)
        next_tok = jnp.argmax(logits[..., -1, :] if logits.ndim == 3 else logits,
                              axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return api.forward_logits(params, batch, cfg)

    return prefill_step
