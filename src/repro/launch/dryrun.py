import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, against both production meshes
(single-pod 16x16 and multi-pod 2x16x16):

    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...,
                          donate_argnums=...).lower(*input_specs(cell))
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

Results accumulate into a JSON file consumed by EXPERIMENTS.md's §Dry-run /
§Roofline tables and by benchmarks/roofline_summary.

NOTE: the XLA_FLAGS line above MUST precede every other import (jax locks
the device count at first init) — and must never be set for the test /
benchmark processes, which expect 1 device.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import list_archs
from repro.configs.shapes import SHAPES, LONG_CTX_ARCHS, cells_for
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, microbatches_for, step_fn_for
from repro.launch.steps import TrainState
from repro.models import sharding as shd
from repro.models.pspec import activation_mesh, unrolled_scans


def shardings_for(kind, cfg, args, mesh):
    """in/out shardings + donation matching the step signature."""
    if kind == "train":
        state, batch = args
        pspec = shd.param_specs(state.params, cfg, mesh)
        opt_spec = opt_state_specs(state.opt, pspec, mesh)
        state_spec = TrainState(params=pspec, opt=opt_spec, step=P())
        in_specs = (state_spec, shd.batch_specs(batch, mesh))
        out_specs = (state_spec, P())  # metrics replicated
        donate = (0,)
    elif kind == "prefill":
        params, batch = args
        pspec = shd.param_specs(params, cfg, mesh)
        in_specs = (pspec, shd.batch_specs(batch, mesh))
        out_specs = None  # logits: let GSPMD place (batch, None, vocab/model)
        donate = ()
    else:  # decode
        params, cache, tok = args
        pspec = shd.param_specs(params, cfg, mesh)
        cspec = shd.cache_specs(cache, cfg, mesh)
        in_specs = (pspec, cspec, shd.batch_specs({"t": tok}, mesh)["t"])
        out_specs = (None, cspec)
        donate = (1,)
    return in_specs, out_specs, donate


def opt_state_specs(opt_shape, param_specs_tree, mesh=None):
    """Optimizer-state specs mirroring the param specs (quantized moments:
    q inherits the param spec, per-block scales drop the last-dim shard).

    ZeRO-across-pod: params replicate over ``pod`` (gradients cross pods
    once per step), but optimizer MOMENTS need not — each pod owns a slice
    (first spec-free dim divisible by the pod count; for scanned stacks
    that's the layer dim).  GSPMD turns the update into reduce-scatter(grad
    over pod) + update + all-gather(params) — exactly ZeRO-1.  Halves the
    biggest per-device state term on the 671B multi-pod cell."""

    def _pod_shard(ps, shape) -> P:
        if (
            mesh is None
            or "pod" not in getattr(mesh, "axis_names", ())
            or mesh.shape["pod"] == 1
        ):
            return ps
        npod = mesh.shape["pod"]
        entries = list(ps) + [None] * (len(shape) - len(tuple(ps)))
        for i, (e, dim) in enumerate(zip(entries, shape)):
            if e is None and dim % npod == 0 and dim >= npod:
                entries[i] = "pod"
                return P(*entries)
        return ps

    def mirror_moment(ps, leaf):
        if isinstance(leaf, dict):  # {"q": ..., "scale": ...}
            qs = _pod_shard(ps, leaf["q"].shape)
            scale_spec = (
                P(*(tuple(qs)[:-1] + (None,))) if len(tuple(qs)) else P()
            )
            return {"q": qs, "scale": scale_spec}
        return _pod_shard(ps, leaf.shape)

    import jax as _jax

    def mirror(moment_tree):
        # walk the param-spec tree (specs are leaves) against the moment
        # tree, whose leaves are arrays or {"q","scale"} dicts per param.
        flat_specs, treedef = _jax.tree_util.tree_flatten(
            param_specs_tree, is_leaf=lambda x: isinstance(x, P)
        )
        flat_moments = treedef.flatten_up_to(moment_tree)
        out = [mirror_moment(s, m) for s, m in zip(flat_specs, flat_moments)]
        return treedef.unflatten(out)

    return {"count": P(), "m": mirror(opt_shape["m"]), "v": mirror(opt_shape["v"])}


#: full-depth unrolled lowering is used up to this many layers; deeper
#: stacks use the two-point extrapolation (per-layer cost is uniform inside
#: each scanned stack, so cost(L) is exactly linear in L for congruent L).
UNROLL_MAX_LAYERS = 14


def _depth_points(cfg) -> tuple[int, int]:
    """Two depths L1 < L2, congruent to num_layers modulo the arch's layer
    period and preserving the dense prefix, so cost(L) is linear on
    {L1, L2, L}."""
    period = cfg.hybrid_attn_period or cfg.local_global_period or 1
    base = cfg.first_dense_layers
    residue = (cfg.num_layers - base) % period
    k1, k2 = (4, 8) if period == 1 else (1, 2)
    l1 = base + k1 * period + residue
    l2 = base + k2 * period + residue
    if l2 >= cfg.num_layers:
        return cfg.num_layers, cfg.num_layers  # too shallow: no extrapolation
    return l1, l2


def _scaled_cfg(cfg, n_layers: int):
    import dataclasses

    reps = {"num_layers": n_layers}
    if cfg.encoder_decoder and cfg.encoder_layers:
        reps["encoder_layers"] = max(
            1, round(cfg.encoder_layers * n_layers / cfg.num_layers)
        )
    return dataclasses.replace(cfg, **reps)


def _lower_cost(arch, shape, kind, cfg, mesh, *, reduced):
    """Unrolled µ=1 compile for one (possibly depth-scaled) config; returns
    (flops, bytes, coll_by_kind) per device."""
    spec = input_specs(arch, shape, reduced=reduced, cfg_override=cfg)
    args = spec["args"]
    step = step_fn_for(kind, cfg, num_microbatches=1)
    in_specs, out_specs, donate = shardings_for(kind, cfg, args, mesh)
    to_shd = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
    )
    jit_kwargs = dict(in_shardings=to_shd(in_specs), donate_argnums=donate)
    if out_specs is not None:
        jit_kwargs["out_shardings"] = to_shd(out_specs)
    with mesh, activation_mesh(mesh), unrolled_scans():
        compiled = jax.jit(step, **jit_kwargs).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    txt = compiled.as_text()
    colls = rf.collective_bytes(txt)
    byts = max(
        0.0, float(ca.get("bytes accessed", 0.0)) - rf.dus_overcount(txt)
    )
    return float(ca.get("flops", 0.0)), byts, colls


def _cost_terms(arch, shape, kind, cfg, mesh, *, reduced):
    """(flops, bytes, coll_by_kind, method) per device — direct unrolled
    compile for shallow stacks, two-point depth extrapolation for deep ones."""
    l1, l2 = _depth_points(cfg)
    if cfg.num_layers <= UNROLL_MAX_LAYERS or l1 == l2:
        f, b, c = _lower_cost(arch, shape, kind, cfg, mesh, reduced=reduced)
        return f, b, c, "unrolled-full"
    f1, b1, c1 = _lower_cost(
        arch, shape, kind, _scaled_cfg(cfg, l1), mesh, reduced=reduced
    )
    f2, b2, c2 = _lower_cost(
        arch, shape, kind, _scaled_cfg(cfg, l2), mesh, reduced=reduced
    )
    t = (cfg.num_layers - l1) / (l2 - l1)
    lerp = lambda a, b: a + t * (b - a)
    kinds = set(c1) | set(c2)
    colls = {k: max(0.0, lerp(c1.get(k, 0), c2.get(k, 0))) for k in kinds}
    return lerp(f1, f2), lerp(b1, b2), colls, f"extrapolated:{l1},{l2}"


def run_cell(arch: str, shape: str, mesh_kind: str, *, reduced: bool = False) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    spec = input_specs(arch, shape, reduced=reduced)
    kind, cfg, args = spec["kind"], spec["cfg"], spec["args"]
    sh = SHAPES[shape]
    mu = microbatches_for(kind, cfg, sh.global_batch, sh.seq_len, mesh)
    step_mem = step_fn_for(kind, cfg, num_microbatches=mu)

    in_specs, out_specs, donate = shardings_for(kind, cfg, args, mesh)
    to_shd = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
    )
    jit_kwargs = dict(in_shardings=to_shd(in_specs), donate_argnums=donate)
    if out_specs is not None:
        jit_kwargs["out_shardings"] = to_shd(out_specs)

    t0 = time.time()
    # TWO passes per cell:
    #  * rolled scans, µ-batched, FULL depth -> memory_analysis (buffer reuse
    #    across layers/microbatches = the realistic steady-state footprint);
    #  * unrolled µ=1 cost pass -> cost_analysis + collective parse (XLA
    #    counts a while-loop body ONCE regardless of trip count — see
    #    models/pspec.py — so true per-step FLOPs/bytes/collective traffic
    #    need unrolled modules; deep stacks extrapolate from two depths).
    with mesh, activation_mesh(mesh):
        jitted = jax.jit(step_mem, **jit_kwargs)
        compiled_rolled = jitted.lower(*args).compile()
    t_rolled = time.time() - t0
    flops, byts, colls, method = _cost_terms(
        arch, shape, kind, cfg, mesh, reduced=reduced
    )
    t_compile = time.time() - t0 - t_rolled

    ma = compiled_rolled.memory_analysis()
    counts = cfg.param_counts()
    tokens = sh.global_batch * (sh.seq_len if kind != "decode" else 1)
    mult = 3.0 if kind == "train" else 1.0  # fwd+bwd
    model_flops_global = 2.0 * counts["active"] * tokens * mult
    n_dev = mesh.size
    report = rf.roofline_from_terms(
        flops, byts, colls,
        model_flops_global=model_flops_global, num_devices=n_dev,
    )

    out = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "kind": kind,
        "devices": n_dev,
        "microbatches": mu,
        "cost_method": method,
        "compile_s": round(t_compile, 1),
        "compile_rolled_s": round(t_rolled, 1),
        "memory": {
            "argument_bytes_per_dev": int(ma.argument_size_in_bytes),
            "output_bytes_per_dev": int(ma.output_size_in_bytes),
            "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
            "alias_bytes_per_dev": int(ma.alias_size_in_bytes),
            "peak_bytes_per_dev": int(
                ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes
            ),
        },
        "roofline": report.to_json(),
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs() + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke variant (small dims) — for CI only")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    for arch in archs:
        shapes = (
            [s for _, s in cells_for(arch)] if args.shape == "all" else [args.shape]
        )
        for shape in shapes:
            if shape == "long_500k" and arch not in LONG_CTX_ARCHS:
                print(f"SKIP {arch} x {shape} (full attention; DESIGN.md §5)")
                results[f"{arch}|{shape}|-"] = {"skip": True}
                continue
            for mesh_kind in meshes:
                key = f"{arch}|{shape}|{mesh_kind}"
                if results.get(key) and not results[key].get("error"):
                    print(f"CACHED {key}")
                    continue
                print(f"RUN {key} ...", flush=True)
                try:
                    cell = run_cell(arch, shape, mesh_kind, reduced=args.reduced)
                    results[key] = cell
                    r = cell["roofline"]
                    print(
                        f"  ok: compile={cell['compile_s']}s "
                        f"peak={cell['memory']['peak_bytes_per_dev']/2**30:.2f}GiB/dev "
                        f"compute={r['compute_s']*1e3:.2f}ms "
                        f"memory={r['memory_s']*1e3:.2f}ms "
                        f"coll={r['collective_s']*1e3:.2f}ms "
                        f"dom={r['dominant']}",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    results[key] = {"error": f"{type(e).__name__}: {e}"}
                out_path.write_text(json.dumps(results, indent=1))
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
