"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state — the 512-device dry-run and the 1-device test
processes both import it safely.

Axis semantics:
  pod   — one TPU v5e pod per index; the feature store's "region" axis
          (geo-replication = replicate over pod; cross-region access =
          collectives over pod).  DCN-connected.
  data  — data parallel + FSDP parameter sharding within a pod (ICI).
  model — tensor/expert parallel (ICI).

Elastic scaling: any (pod, data, model) factorization is accepted; sharding
rules reference axis NAMES only, and checkpoints reshard on load.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "batch_axes", "axis_size"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic variant for tests (e.g. (2,2,2) on 8 host devices)."""
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes that carry the global batch (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh, *names: str) -> int:
    out = 1
    for n in names:
        if n in mesh.axis_names:
            out *= mesh.shape[n]
    return out
