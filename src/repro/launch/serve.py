"""Batched serving driver: online feature retrieval -> prefill -> decode.

The request path exercises the paper's low-latency plane end to end:
  1. each request names a document/session (entity id);
  2. the ONLINE store serves the session's latest context feature (its most
     recent token chunk — the "session state" pattern) via the Pallas
     lookup kernel;
  3. the model prefills the retrieved context and decodes new tokens.

Offline/online skew shows up here as a wrong prompt — the integration test
asserts the served context equals the offline latest record.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.data.loader import HOUR, TokenFeatureSet
from repro.data.sources import TokenEventSource
from repro.core.featurestore import FeatureStore
from repro.models import api


def build_serving_plane(cfg, *, seed: int = 0):
    src = TokenEventSource(
        "token_stream", seed=seed, vocab_size=cfg.vocab_size,
        num_docs=64, chunk_len=32, chunks_per_bucket=128,
    )
    fs = FeatureStore("lm-serving-plane", interpret=True)
    fs.register_source(src)
    spec = fs.create_feature_set(TokenFeatureSet(src))
    fs.tick(now=3 * HOUR)
    return fs, spec, src


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    fs, spec, src = build_serving_plane(cfg, seed=args.seed)

    # -- request batch: sessions ask for continuations -----------------------
    rng = np.random.default_rng(args.seed)
    doc_ids = rng.integers(0, src.num_docs, args.requests).astype(np.int64)

    t0 = time.perf_counter()
    ctx_vals, found = fs.get_online_features(
        spec.name, spec.version, [doc_ids]
    )
    lookup_ms = (time.perf_counter() - t0) * 1e3
    prompts = np.clip(ctx_vals.astype(np.int64), 0, cfg.vocab_size - 1)
    prompts = np.where(found[:, None], prompts, 1)  # cold sessions: BOS-ish

    params = api.init_params(jax.random.PRNGKey(args.seed), cfg,
                             max_decode_len=prompts.shape[1] + args.new_tokens)
    max_len = prompts.shape[1] + args.new_tokens
    cache = api.init_cache(cfg, args.requests, max_len)
    if cfg.encoder_decoder:
        from repro.models import encdec

        frames = np.zeros((args.requests, cfg.encoder_seq, cfg.d_model), np.float32)
        memory = encdec.encode(params, jnp.asarray(frames), cfg)
        cache = encdec.precompute_cross(params, memory, cfg, cache)

    step = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg))

    # prefill by stepping the prompt (reference path), then decode new tokens
    toks = jnp.asarray(prompts, jnp.int32)
    t1 = time.perf_counter()
    for i in range(prompts.shape[1]):
        logits, cache = step(params, cache, toks[:, i : i + 1])
    generated = []
    cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(args.new_tokens):
        generated.append(np.asarray(cur)[:, 0])
        logits, cache = step(params, cache, cur)
        cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    decode_ms = (time.perf_counter() - t1) * 1e3

    out = {
        "requests": args.requests,
        "context_hits": int(found.sum()),
        "online_lookup_ms": lookup_ms,
        "decode_ms_total": decode_ms,
        "tokens_generated": int(args.new_tokens * args.requests),
        "generated": np.stack(generated, axis=1),
    }
    print(
        f"[serve] {args.requests} reqs, {out['context_hits']} warm sessions, "
        f"lookup {lookup_ms:.2f}ms, {out['tokens_generated']} tokens in "
        f"{decode_ms:.0f}ms"
    )
    return out


if __name__ == "__main__":
    main()
