"""Roofline analysis from the compiled dry-run artifact.

Terms per (arch x shape x mesh), in seconds, derived from the post-SPMD
per-device module (cost_analysis is per-device after partitioning; we
verified a D·F matmul reports global_flops/512 on the 512-device mesh):

  compute    = flops_per_device / PEAK_FLOPS
  memory     = bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / LINK_BW

collective_bytes is NOT in cost_analysis: we parse the compiled HLO text,
build a symbol table of instruction result shapes, and sum the OPERAND
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.  Shapes in the post-SPMD module are shard
(per-device) shapes, so the sum is per-device traffic — equivalent to the
spec's global_bytes / chips for uniform SPMD programs.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

__all__ = [
    "HW", "collective_bytes", "roofline_from_compiled", "roofline_from_terms",
    "RooflineReport",
]

HW = {
    "peak_flops": 197e12,   # bf16 per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "link_bw": 50e9,        # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)"
)
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,512]{1,0}' or tuple '(bf16[..], f32[..])' -> bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes (per device)."""
    # symbol table: %name -> result shape string
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    out: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        kind = next(
            (c for c in _COLLECTIVES if op == c or op.startswith(c + ".")
             or op == c + "-start" or op.startswith(c + "-start")),
            None,
        )
        if kind is None:
            continue
        # operand list: between the first '(' after the op name and its ')'
        call = line[line.find(op):]
        lp = call.find("(")
        if lp < 0:
            continue
        depth, rp = 0, -1
        for i, ch in enumerate(call[lp:], start=lp):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    rp = i
                    break
        operands = call[lp + 1 : rp]
        # operands may carry inline types or be bare %refs
        n = 0
        for ref in re.finditer(r"%[\w\.\-]+", operands):
            n += _shape_bytes(shapes.get(ref.group(0), ""))
        if n == 0:
            n = _shape_bytes(operands)
        # The CPU backend PROMOTES bf16 all-reduces to f32 (no bf16 ALU) and
        # marks the reduce computation "<op>.clone_promoted"; TPU reduces
        # bf16 natively, so count promoted reductions at the source dtype.
        if kind == "all-reduce" and "_promoted" in line:
            n //= 2
        out[kind] += n
    return dict(out)


def dus_overcount(hlo_text: str) -> int:
    """Bytes cost_analysis over-attributes to dynamic-update-slice ops.

    A DUS (KV-cache insert, scan-carry write) is counted operand+output =
    2·buffer + update, but XLA aliases it in place: real traffic ≈ 2·update.
    Overcount per site = 2·buffer − update.  TPU behaves the same way, so
    the memory term subtracts this (raw value kept in the report)."""
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)
    total = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m or not m.group(3).startswith("dynamic-update-slice"):
            continue
        buf = _shape_bytes(m.group(2))
        # operands after the '=': (buffer, update, indices...)
        refs = re.findall(r"%[\w\.\-]+", line.split("=", 1)[1])
        upd = _shape_bytes(shapes.get(refs[1], "")) if len(refs) > 1 else 0
        total += max(0, 2 * buf - upd)
    return total


@dataclasses.dataclass
class RooflineReport:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None
    bytes_raw_per_dev: Optional[float] = None   # before the DUS adjustment

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def roofline_from_terms(
    flops: float, byts: float, colls: dict[str, int], *,
    model_flops_global: Optional[float] = None,
    num_devices: Optional[int] = None,
) -> RooflineReport:
    cb = float(sum(colls.values()))
    compute_s = flops / HW["peak_flops"]
    memory_s = byts / HW["hbm_bw"]
    collective_s = cb / HW["link_bw"]
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
        key=lambda kv: kv[1],
    )[0]

    model_flops = useful = None
    if model_flops_global is not None and num_devices:
        model_flops = model_flops_global / num_devices
        useful = model_flops / flops if flops else None

    return RooflineReport(
        flops, byts, cb, {k: int(v) for k, v in colls.items()},
        compute_s, memory_s, collective_s, dominant, model_flops, useful,
    )


def roofline_from_compiled(
    compiled, *, model_flops_global: Optional[float] = None,
    num_devices: Optional[int] = None,
) -> RooflineReport:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    txt = compiled.as_text()
    raw = float(ca.get("bytes accessed", 0.0))
    adj = max(0.0, raw - dus_overcount(txt))
    rep = roofline_from_terms(
        float(ca.get("flops", 0.0)),
        adj,
        collective_bytes(txt),
        model_flops_global=model_flops_global,
        num_devices=num_devices,
    )
    rep.bytes_raw_per_dev = raw
    return rep
