"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero device allocation: params/optimizer state
come from jax.eval_shape over the real initializers; batches and caches are
constructed to the assigned shape cells.  The dry-run lowers against exactly
these (the pattern that proves a 671B train step fits without ever
allocating it).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models import api
from repro.models.config import ModelConfig
from repro.optim.adamw import adamw
from repro.launch.steps import TrainState, make_prefill_step, make_serve_step, make_train_step

__all__ = [
    "input_specs", "abstract_state", "abstract_params", "step_fn_for",
    "microbatches_for",
]


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def abstract_params(cfg: ModelConfig, *, max_decode_len: int = 4096):
    return _sds(
        jax.eval_shape(
            lambda k: api.init_params(k, cfg, max_decode_len=max_decode_len),
            jax.random.PRNGKey(0),
        )
    )


def abstract_state(cfg: ModelConfig, optimizer=None):
    opt = optimizer or default_optimizer(cfg)
    params = abstract_params(cfg)
    return _sds(jax.eval_shape(lambda p: TrainState.create(p, opt), params))


def default_optimizer(cfg: ModelConfig):
    # 8-bit moments: the HBM-fit configuration for the large cells.
    return adamw(lr=3e-4, weight_decay=0.1, quantize_moments=True)


def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> dict:
    out: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    }
    if cfg.encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype)
        )
    if cfg.vision_prefix:
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_patches, cfg.vision_dim), jnp.dtype(cfg.compute_dtype)
        )
    return out


def input_specs(
    arch: str, shape: str, *, reduced: bool = False, cfg_override=None
) -> dict:
    """Returns {'kind', 'cfg', 'args': tuple of abstract inputs} for the
    (arch x shape) cell.  ``args`` matches the step function's signature:
      train:   (TrainState, batch)
      prefill: (params, batch)
      decode:  (params, cache, tokens_new)

    ``cfg_override`` substitutes a depth-scaled config (the dry-run's
    two-point cost extrapolation) while keeping the cell's batch geometry.
    """
    cfg = cfg_override if cfg_override is not None else get_config(arch, reduced=reduced)
    spec: ShapeSpec = SHAPES[shape]
    b, s = spec.global_batch, spec.seq_len
    if reduced:
        b, s = max(2, b // 64), min(s, 64)

    if spec.kind == "train":
        state = abstract_state(cfg)
        return {
            "kind": "train",
            "cfg": cfg,
            "args": (state, batch_struct(cfg, b, s)),
        }
    if spec.kind == "prefill":
        return {
            "kind": "prefill",
            "cfg": cfg,
            # enc-dec archs size their learned decoder position table from
            # max_decode_len; it must cover the prefill sequence
            "args": (
                abstract_params(cfg, max_decode_len=max(4096, s)),
                batch_struct(cfg, b, s),
            ),
        }
    # decode: one new token against a seq_len-deep cache
    params = abstract_params(cfg, max_decode_len=s)
    cache = _sds(jax.eval_shape(lambda: api.init_cache(cfg, b, s)))
    tokens_new = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return {
        "kind": "decode",
        "cfg": cfg,
        "args": (params, cache, tokens_new),
    }


#: per-device budget for saved (remat) activations, bytes.  v5e has 16 GB
#: HBM; model+optimizer state claims most of it on the big cells, so the
#: residual-carry budget is deliberately small.
ACT_BUDGET_BYTES = 2 * 2**30


def microbatches_for(kind: str, cfg: ModelConfig, batch: int, seq: int, mesh) -> int:
    """Gradient-accumulation factor: smallest divisor of the global batch
    whose per-microbatch saved-residual footprint
    (tokens_per_dev · d_model · 2 B · num_layers, + MoE routed copies)
    fits ACT_BUDGET_BYTES."""
    if kind != "train":
        return 1
    import numpy as np

    dp = int(np.prod([mesh.shape[a] for a in mesh.axis_names if a != "model"]))
    tokens_per_dev = batch * seq / dp
    per_layer = tokens_per_dev * cfg.d_model * 2
    if cfg.moe:  # dispatched activations survive the checkpoint boundary
        per_layer *= 1.0 + 0.35
    act = per_layer * cfg.num_layers
    for mu in sorted({d for d in range(1, batch + 1) if batch % d == 0}):
        if act / mu <= ACT_BUDGET_BYTES:
            return mu
    return batch


def step_fn_for(kind: str, cfg: ModelConfig, *, num_microbatches: int = 1):
    if kind == "train":
        return make_train_step(
            cfg, default_optimizer(cfg), num_microbatches=num_microbatches
        )
    if kind == "prefill":
        return make_prefill_step(cfg)
    return make_serve_step(cfg)
