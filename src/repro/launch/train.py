"""End-to-end training driver: feature store -> PIT batches -> train loop,
with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1

Fault-tolerance demo: add ``--kill-at 120`` to simulate a node failure at
step 120, then re-run the same command — the driver restores the latest
checkpoint (train state + scheduler state + loader clock) and continues to
--steps, bit-identically to an uninterrupted run (tested in
tests/integration/test_train_driver.py).

On a real cluster the same driver runs under the production mesh: pass
--mesh dxm (e.g. --mesh 4x2) to shard over hosts' devices.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, list_archs
from repro.data.loader import HOUR, FeatureStoreLoader, TokenFeatureSet
from repro.data.sources import TokenEventSource
from repro.core.featurestore import FeatureStore
from repro.launch.mesh import make_mesh
from repro.launch.steps import TrainState, make_train_step
from repro.models import api
from repro.models.pspec import activation_mesh
from repro.models import sharding as shd
from repro.optim.adamw import adamw
from repro.optim.schedules import warmup_cosine


def build_data_plane(cfg, *, seq_len: int, batch: int, seed: int = 0):
    src = TokenEventSource(
        "token_stream", seed=seed, vocab_size=cfg.vocab_size,
        num_docs=256, chunk_len=64, chunks_per_bucket=512,
    )
    fs = FeatureStore("lm-data-plane", interpret=True)
    fs.register_source(src)
    spec = fs.create_feature_set(TokenFeatureSet(src))
    loader = FeatureStoreLoader(
        store=fs, spec=spec, seq_len=seq_len, batch_size=batch,
        chunk_len=src.chunk_len, seed=seed,
    )
    return fs, loader


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="gemma-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--kill-at", type=int, default=0,
                    help="simulate node failure at this step")
    ap.add_argument("--mesh", default="", help="dxm, e.g. 4x2 (default: none)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    fs, loader = build_data_plane(cfg, seq_len=args.seq, batch=args.batch,
                                  seed=args.seed)
    loader.advance(6 * HOUR)

    optimizer = adamw(
        lr=warmup_cosine(args.lr, 20, args.steps), weight_decay=0.01,
        quantize_moments=False,
    )
    train_step = make_train_step(cfg, optimizer)

    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    params = api.init_params(jax.random.PRNGKey(args.seed), cfg)
    state = TrainState.create(params, optimizer)

    ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every) if args.ckpt_dir else None
    start_step = 0
    if ckpt:
        restored = ckpt.restore_latest(state)
        if restored[0] is not None:
            saved_step, state, extra = restored
            start_step = saved_step + 1  # state is AFTER executing saved_step
            loader.load_state_dict(extra["loader"])
            fs.restore_scheduler(extra["scheduler"])
            print(f"[train] restored checkpoint at step {saved_step}")

    if mesh is not None:
        pspec = shd.param_specs(state.params, cfg, mesh)
        from repro.launch.dryrun import opt_state_specs
        from jax.sharding import NamedSharding, PartitionSpec as P

        sspec = TrainState(
            params=pspec, opt=opt_state_specs(state.opt, pspec), step=P()
        )
        to_shd = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
        )
        state = jax.device_put(state, to_shd(sspec))
        jitted = jax.jit(train_step, in_shardings=(to_shd(sspec), None),
                         out_shardings=(to_shd(sspec), None),
                         donate_argnums=(0,))
    else:
        jitted = jax.jit(train_step, donate_argnums=(0,))

    losses = []
    t0 = time.time()
    ctx = activation_mesh(mesh) if mesh is not None else _null_ctx()
    with ctx:
        for step in range(start_step, args.steps):
            if args.kill_at and step == args.kill_at:
                print(f"[train] simulated node failure at step {step}")
                raise SystemExit(17)
            batch = loader.sample_batch(step)
            model_batch = {"tokens": jax.numpy.asarray(batch["tokens"])}
            if cfg.encoder_decoder or cfg.vision_prefix:
                dummy = api.make_dummy_batch(cfg, args.batch, args.seq, seed=step)
                for k in ("frames", "patch_embeds"):
                    if k in dummy:
                        model_batch[k] = dummy[k]
            state, metrics = jitted(state, model_batch)
            losses.append(float(metrics["lm_loss"]))
            if step % args.log_every == 0:
                print(
                    f"[train] step {step:5d} loss {losses[-1]:.4f} "
                    f"({(time.time()-t0):.1f}s)", flush=True,
                )
            if ckpt:
                ckpt.maybe_save(
                    step, state,
                    extra={"loader": loader.state_dict(),
                           "scheduler": fs.scheduler_state()},
                )
    result = {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps_run": len(losses),
        "start_step": start_step,
        "losses": losses,
    }
    if losses:
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return result


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
