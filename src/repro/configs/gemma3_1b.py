"""gemma3-1b [hf:google/gemma-3-1b-pt; unverified].

26L d_model=1152 4H (MQA kv=1, head_dim 256) d_ff=6912 vocab=262144;
5:1 local:global sliding-window attention (window 512), tied embeddings.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, vocab_size=262_144,
    num_heads=4, num_kv_heads=1, head_dim=256,
    d_ff=6912, mlp_variant="geglu", tie_embeddings=True,
    local_global_period=6, sliding_window=512,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=6, d_model=64, vocab_size=512,
        num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128,
        local_global_period=3, sliding_window=8,
    )
