"""zamba2-7b [arXiv:2411.15242; unverified].

81 Mamba2 layers d_model=3584 (ssm_state=64) + ONE shared attention block
(32H, d_ff=14336) applied every 6th layer, vocab=32000.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, vocab_size=32_000,
    ssm=True, ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    ssm_groups=1, ssm_conv_width=4, ssm_chunk=256,
    num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14_336, mlp_variant="gelu",
    hybrid_attn_period=6,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=7, d_model=64, vocab_size=512,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        hybrid_attn_period=3,
    )
