"""Assigned input shapes and per-arch applicability.

Four shape cells per architecture:
  train_4k    — train_step,  seq 4096,    global batch 256
  prefill_32k — prefill,     seq 32768,   global batch 32
  decode_32k  — serve_step,  1 new token against a 32768 KV/state, batch 128
  long_500k   — serve_step,  1 new token against 524288 context, batch 1
                (sub-quadratic/compressed-state archs only)
"""

from __future__ import annotations

import dataclasses

__all__ = ["ShapeSpec", "SHAPES", "cells_for", "LONG_CTX_ARCHS", "ALL_ARCHS"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

ALL_ARCHS = (
    "deepseek-v2-lite-16b",
    "deepseek-v3-671b",
    "phi3-medium-14b",
    "gemma-2b",
    "qwen1.5-4b",
    "gemma3-1b",
    "zamba2-7b",
    "pixtral-12b",
    "whisper-tiny",
    "mamba2-2.7b",
)

#: archs whose decode state stays sub-quadratic/bounded at 500k context
#: (SSM / hybrid / mostly-local sliding window).  Everything else SKIPs
#: long_500k — see DESIGN.md §Shape-cell skips.
LONG_CTX_ARCHS = frozenset({"mamba2-2.7b", "zamba2-7b", "gemma3-1b"})


def cells_for(arch: str) -> list[tuple[str, str]]:
    """(arch, shape) cells to run; 40 total across the pool, with long_500k
    marked SKIP for pure full-attention archs."""
    out = []
    for shape in SHAPES:
        if shape == "long_500k" and arch not in LONG_CTX_ARCHS:
            continue
        out.append((arch, shape))
    return out
