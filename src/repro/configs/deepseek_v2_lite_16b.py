"""deepseek-v2-lite-16b [arXiv:2405.04434; hf].

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400; MLA kv_lora=512
(qk_nope 128 / qk_rope 64 / v 128, no q-lora on the lite model); MoE 64
routed experts top-6 + 2 shared, leading dense layer d_ff=10944.
(The assignment line also mentions "160 routed" — that is the full-V2
config; we follow the primary spec "64e top-6".  See DESIGN.md §5.)
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, vocab_size=102_400,
    num_heads=16, num_kv_heads=16, head_dim=128,
    use_mla=True, q_lora_rank=0, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    d_ff=10_944, mlp_variant="swiglu",
    moe=True, num_experts=64, num_shared_experts=2, top_k=6,
    moe_d_ff=1408, first_dense_layers=1,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=64, vocab_size=512,
        num_heads=4, num_kv_heads=4, head_dim=16,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        d_ff=128, num_experts=8, top_k=2, num_shared_experts=1,
        moe_d_ff=32, first_dense_layers=1,
    )
