"""Architecture config registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

from repro.models.config import ModelConfig

_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "phi3-medium-14b": "phi3_medium_14b",
    "gemma-2b": "gemma_2b",
    "qwen1.5-4b": "qwen15_4b",
    "gemma3-1b": "gemma3_1b",
    "zamba2-7b": "zamba2_7b",
    "pixtral-12b": "pixtral_12b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-2.7b": "mamba2_27b",
}


def get_config(arch: str, *, reduced: bool = False) -> ModelConfig:
    import importlib

    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.reduced() if reduced else mod.CONFIG


def list_archs() -> list[str]:
    return sorted(_MODULES)
