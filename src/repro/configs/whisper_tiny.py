"""whisper-tiny [arXiv:2212.04356; unverified].

Enc-dec backbone: 4+4L d_model=384 6H d_ff=1536 vocab=51865; the conv/mel
frontend is a STUB — input_specs() supplies precomputed frame embeddings
(B, 1500, 384).  decode_32k is lowered structurally even though the
published model decodes at 448 (DESIGN.md §5).
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, vocab_size=51_865,
    num_heads=6, num_kv_heads=6, head_dim=64,
    d_ff=1536, mlp_variant="gelu", tie_embeddings=True,
    encoder_decoder=True, encoder_layers=4, encoder_seq=1500,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, d_model=64, vocab_size=512,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, encoder_seq=32,
    )
