"""pixtral-12b [hf:mistralai/Pixtral-12B-2409; unverified].

Backbone only (mistral-nemo): 40L d_model=5120 32H (GQA kv=8, head_dim
128) d_ff=14336 vocab=131072.  The pixtral-ViT frontend is a STUB:
input_specs() supplies precomputed patch embeddings (vision_dim=1024),
projected and prepended to the token sequence.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, vocab_size=131_072,
    num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14_336, mlp_variant="swiglu", rope_theta=1e6,
    vision_prefix=True, vision_dim=1024, num_patches=1024,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=512,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vision_dim=32, num_patches=8,
    )
