"""mamba2-2.7b [arXiv:2405.21060; unverified].

64L d_model=2560, attention-free SSD (state-space duality), ssm_state=128,
expand 2 (d_inner 5120, 80 heads of dim 64), vocab=50280.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, vocab_size=50_280,
    ssm=True, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    ssm_groups=1, ssm_conv_width=4, ssm_chunk=256,
    d_ff=0,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=64, vocab_size=512,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    )
