"""phi3-medium-14b [arXiv:2404.14219; unverified].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352; RoPE SwiGLU GQA.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    num_layers=40, d_model=5120, vocab_size=100_352,
    num_heads=40, num_kv_heads=10, head_dim=128,
    d_ff=17_920, mlp_variant="swiglu",
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=512,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
    )
