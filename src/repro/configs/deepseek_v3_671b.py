"""deepseek-v3-671b [arXiv:2412.19437; hf].

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280; MLA (q_lora 1536,
kv_lora 512, qk_nope 128 / qk_rope 64 / v 128); MoE 256 routed top-8 +
1 shared; 3 leading dense layers d_ff=18432; MTP depth 1.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, vocab_size=129_280,
    num_heads=128, num_kv_heads=128, head_dim=128,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    d_ff=18_432, mlp_variant="swiglu",
    moe=True, num_experts=256, num_shared_experts=1, top_k=8,
    moe_d_ff=2048, first_dense_layers=3,
    mtp_depth=1,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, vocab_size=512,
        num_heads=4, num_kv_heads=4, head_dim=16,
        q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, d_ff=128, num_experts=8, top_k=2,
        num_shared_experts=1, moe_d_ff=32, first_dense_layers=1, mtp_depth=1,
    )
