"""qwen1.5-4b [hf:Qwen/Qwen1.5 family; hf].

40L d_model=2560 20H (MHA kv=20) d_ff=6912 vocab=151936; QKV bias.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, vocab_size=151_936,
    num_heads=20, num_kv_heads=20, head_dim=128,
    d_ff=6912, mlp_variant="swiglu", qkv_bias=True,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=512,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
    )
