"""gemma-2b [arXiv:2403.08295; hf].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000; GeGLU,
head_dim=256, tied embeddings.
"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, vocab_size=256_000,
    num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16_384, mlp_variant="geglu", tie_embeddings=True,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=512,
        num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128,
    )
