"""Quickstart: the paper's end-to-end feature-store story in one script.

    PYTHONPATH=src python examples/quickstart.py          # full walkthrough
    PYTHONPATH=src python examples/quickstart.py --fast   # CI smoke sizes

Walks through every §2.1 capability on a synthetic transaction stream:

  1.  create a feature store + register a source system
  2.  define an entity and a DSL feature set (rolling-window aggregations —
      the paper's customer-churn example: 30day_transactions_sum et al.)
  3.  scheduled incremental materialization (tick) + on-demand backfill
  4.  point-in-time-correct offline retrieval (a training frame)  [§4.4]
  5.  low-latency online retrieval (the Pallas lookup kernel)     [§3.1.4]
  6.  offline/online consistency check + Fig.5 record semantics   [§4.5]
  7.  feature->model lineage                                      [§4.6]
"""

import argparse

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg
from repro.core.featurestore import FeatureStore
from repro.core.lineage import ModelNode
from repro.core.table import Table
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000
DAY = 24 * HOUR


def main(fast: bool = False):
    # --fast: tiny workloads for the CI examples-smoke step
    hours = 6 if fast else 12
    events_per_bucket = 40 if fast else 200

    # -- 1. store + source -----------------------------------------------------
    fs = FeatureStore("quickstart", region="westus2")
    src = SyntheticEventSource(
        "transactions", num_entities=40, events_per_bucket=events_per_bucket
    )
    fs.register_source(src)

    # -- 2. entity + DSL feature set -------------------------------------------
    customer = fs.create_entity(Entity("customer", ("entity_id",)))
    spec = fs.create_feature_set(
        FeatureSetSpec(
            name="customer_activity",
            version=1,
            entity=customer,
            features=(
                Feature("spend_6h_sum", "float32"),
                Feature("spend_6h_mean", "float32"),
                Feature("txn_6h_count", "float32"),
                Feature("qty_6h_max", "float32"),
            ),
            source_name="transactions",
            transform=DslTransform(
                entity_col="entity_id",
                timestamp_col="ts",
                aggs=[
                    RollingAgg("spend_6h_sum", "amount", 6 * HOUR, "sum"),
                    RollingAgg("spend_6h_mean", "amount", 6 * HOUR, "mean"),
                    RollingAgg("txn_6h_count", "amount", 6 * HOUR, "count"),
                    RollingAgg("qty_6h_max", "quantity", 6 * HOUR, "max"),
                ],
            ),
            timestamp_col="ts",
            source_lookback=6 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    print(f"created feature set {spec.name} v{spec.version} "
          f"(fingerprint {spec.transform.code_fingerprint()})")

    # -- 3. scheduled materialization + backfill --------------------------------
    stats = fs.tick(now=hours * HOUR)       # N hours of scheduled incremental jobs
    print(f"scheduled materialization: {stats}")
    stats = fs.backfill("customer_activity", 1, start=0, end=4 * HOUR)
    print(f"backfill(0..4h): {stats} (overlap-free per §4.3 — see scheduler)")

    # -- 4. point-in-time offline retrieval -------------------------------------
    rng = np.random.default_rng(0)
    spine = Table({
        "entity_id": rng.integers(0, 40, size=8).astype(np.int64),
        "ts": rng.integers(2 * HOUR, (hours - 1) * HOUR, size=8).astype(np.int64),
        "label": rng.integers(0, 2, size=8).astype(np.float32),
    })
    frame = fs.get_offline_features(spine, [("customer_activity", 1)])
    print("\ntraining frame (PIT-correct — no feature from the future):")
    print("  cols:", sorted(frame.columns))
    print("  spend_6h_sum:",
          np.round(frame["customer_activity:v1:spend_6h_sum"], 1))

    # -- 5. online retrieval ------------------------------------------------------
    vals, found = fs.get_online_features(
        "customer_activity", 1, [np.arange(8, dtype=np.int64)]
    )
    print(f"\nonline lookup: found={found.tolist()}")
    print(f"  latest spend_6h_sum: {np.round(vals[:, 0], 1)}")
    lat = fs.monitor.system.snapshot()["histograms"].get("online_lookup_us", {})
    print(f"  latency p50/p99 = {lat.get('p50', 0):.0f}/{lat.get('p99', 0):.0f} µs")

    # -- 6. consistency (the §4.5.2 invariant) ------------------------------------
    rep = fs.check_consistency("customer_activity", 1)
    print(f"\nconsistency: online==max(event_ts,creation_ts) per id: {rep.consistent}"
          f" ({rep.checked_ids} ids)")

    # -- 7. lineage ---------------------------------------------------------------
    model = ModelNode("churn-model", version=3, region="eastus")
    fs.track_model(model, [("customer_activity", 1)])
    deps = fs.lineage.features_of_model(model)
    print(f"\nlineage: churn-model v3 <- {len(deps)} features "
          f"(cross-region: westus2 store, eastus model)")
    print("\nquickstart complete.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="tiny CI-smoke workloads")
    main(fast=ap.parse_args().fast)
