"""End-to-end training driver example: feature store as the LM data plane.

    PYTHONPATH=src python examples/train_lm.py --steps 200

Trains a reduced gemma-2b on token-chunk events materialized through the
feature store (point-in-time retrieval — the model can never see tokens
newer than the loader's data clock), with checkpointing.  Demonstrates the
fault-tolerance story end-to-end:

    python examples/train_lm.py --steps 200 --ckpt-dir /tmp/ex_run --kill-at 120
    python examples/train_lm.py --steps 200 --ckpt-dir /tmp/ex_run
        # -> restores step 100 checkpoint, finishes 200, same final loss as
        #    an uninterrupted run (integration-tested).

The ~100M-parameter configuration from the assignment brief is
``--arch gemma3-1b --full --batch 8 --seq 512`` on real hardware; the default
here is CPU-sized.  This is a thin veneer over repro.launch.train (the real
driver) so the example and the production entry point cannot drift.
"""

import sys

from repro.launch import train


def main():
    argv = sys.argv[1:] or ["--steps", "200", "--batch", "4", "--seq", "128",
                            "--arch", "gemma-2b", "--log-every", "20"]
    result = train.main(argv)
    print(
        f"\nexample complete: {result['steps_run']} steps, "
        f"loss {result['first_loss']:.3f} -> {result['last_loss']:.3f}"
    )
    assert result["last_loss"] < result["first_loss"], "loss must decrease"


if __name__ == "__main__":
    main()
