"""Geo-distribution example: cross-region access, replication, fail-over,
rejoin, and resumable materialization (paper §3.1.2–3.1.3, §4.1.2).

    PYTHONPATH=src python examples/geo_failover.py          # full walkthrough
    PYTHONPATH=src python examples/geo_failover.py --fast   # CI smoke sizes

Scenario:
  * a feature store homed in westus2, consumed from eastus + westeurope
  * CROSS_REGION_ACCESS (the paper's implemented mechanism): reads traverse
    the inter-region link — measured by the topology's latency model
  * GEO_REPLICATED (the road-map mechanism): add a replica, reads go local
  * a geo-fenced store refuses replication (compliance, §4.1.2)
  * region failure: fail-over promotes the replica; materialization resumes
    from persisted scheduler state without data loss (§3.1.2)
  * the full two-plane data plane (core/replication.py): online + offline
    stores replicate through one log, failover converges both planes, and
    the recovered ex-home REJOINS via delta bootstrap
  * a lossy WAN (core/channel.py): the same replication through a seeded
    FaultyChannel — the delivery state machine retries/backs off until
    both planes converge anyway, and its fault ledger + monitor counters
    (replication/retries/{replica}, replication/state/{replica}) show the
    price paid
  * a REAL process boundary (core/daemon.py): the replica lives in a child
    interpreter behind a localhost socket; frames ship pipelined with a
    bounded in-flight window, fail-over adopts the daemon's state through
    its dump stream, and the child is torn down cleanly
  * active-active multi-home (core/multihome.py): the keyspace is sharded
    into hash ranges, every region is the write home for its ranges, and
    fail-over promotes ONLY the lost range — then the recovered region
    rejoins empty and is handed a range back via rebalance
"""

import argparse

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.channel import FaultPlan, FaultyChannel
from repro.core.dsl import DslTransform, RollingAgg
from repro.core.featurestore import FeatureStore
from repro.core.regions import (
    ComplianceError,
    GeoTopology,
    Region,
    ReplicationPolicy,
)
from repro.core.replication import DeliveryPolicy, GeoFeatureStore
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def build_store(policy, *, geo_fenced_home=False):
    topo = GeoTopology(
        regions={
            "westus2": Region("westus2", geo_fenced=geo_fenced_home),
            "eastus": Region("eastus"),
            "westeurope": Region("westeurope"),
        },
        local_latency_ms=1.0,
        cross_region_latency_ms=60.0,
    )
    fs = FeatureStore("geo-demo", region="westus2", topology=topo, replication=policy)
    src = SyntheticEventSource("tx", num_entities=16, events_per_bucket=64)
    fs.register_source(src)
    fs.create_feature_set(
        FeatureSetSpec(
            name="activity",
            version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("spend_2h", "float32"),),
            source_name="tx",
            transform=DslTransform(
                "entity_id", "ts", [RollingAgg("spend_2h", "amount", 2 * HOUR, "sum")]
            ),
            timestamp_col="ts",
            source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    return fs


def main(fast: bool = False):
    hours = 2 if fast else 4

    # -- cross-region access (paper's current mechanism) ------------------------
    fs = build_store(ReplicationPolicy.CROSS_REGION_ACCESS)
    fs.tick(now=hours * HOUR)
    for consumer in ("westus2", "eastus", "westeurope"):
        serving, ms = fs.geo.route_read(consumer)
        print(f"cross-region read from {consumer:11s} -> served by {serving} "
              f"({ms:.0f} ms)")

    # -- geo-replication (road-map mechanism) ------------------------------------
    fs2 = build_store(ReplicationPolicy.GEO_REPLICATED)
    fs2.tick(now=hours * HOUR)
    fs2.geo.add_replica("eastus")
    serving, ms = fs2.geo.route_read("eastus")
    print(f"\ngeo-replicated read from eastus -> served by {serving} ({ms:.0f} ms)")

    # -- compliance fencing ---------------------------------------------------------
    fenced = build_store(ReplicationPolicy.GEO_REPLICATED, geo_fenced_home=True)
    try:
        fenced.geo.add_replica("eastus")
    except ComplianceError as e:
        print(f"\ncompliance fence works: {e}")

    # -- region failure + resumable materialization ----------------------------------
    print("\n--- region failure drill ---")
    state = fs2.scheduler_state()              # persisted control-plane state
    fs2.geo.mark_down("westus2")
    new_primary = fs2.geo.failover()
    print(f"westus2 down -> promoted {new_primary}")
    serving, ms = fs2.geo.route_read("westus2")
    print(f"reads from westus2 now served by {serving} ({ms:.0f} ms)")

    # the promoted region restores scheduler state and resumes the timeline:
    fs2.restore_scheduler(state)
    stats = fs2.tick(now=2 * hours * HOUR)
    print(f"resumed materialization at new primary: {stats}")
    intervals = fs2.scheduler.materialized_intervals("activity", 1)
    print(f"materialized timeline (no holes, no loss): {intervals}")
    rep = fs2.check_consistency("activity", 1)
    print(f"offline/online consistency after fail-over: {rep.consistent}")

    # -- the full two-plane data plane: replicate, fail over, REJOIN -------------
    print("\n--- two-plane replication drill (core/replication.py) ---")
    topo = GeoTopology(
        regions={r: Region(r) for r in ("westus2", "eastus", "westeurope")},
        local_latency_ms=1.0,
        cross_region_latency_ms=60.0,
        link_latency_ms={("westus2", "eastus"): 32.0},
    )
    g = GeoFeatureStore(
        "geo-data-plane",
        topology=topo,
        home_region="westus2",
        replica_regions=("eastus",),
    )
    g.register_source(SyntheticEventSource("tx", num_entities=16, events_per_bucket=32))
    g.create_feature_set(
        FeatureSetSpec(
            name="activity",
            version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("spend_2h", "float32"),),
            source_name="tx",
            transform=DslTransform(
                "entity_id", "ts", [RollingAgg("spend_2h", "amount", 2 * HOUR, "sum")]
            ),
            timestamp_col="ts",
            source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    g.tick(now=hours * HOUR)
    lag = g.lag("eastus")
    print(f"replica lag after materialization: {lag.planes}")
    g.drain()
    ship = g.replicator.shipped["eastus"]
    print(
        f"wire transport: {ship.batches} batches coalesced into "
        f"{ship.frames} frames, {ship.raw_bytes} raw B -> "
        f"{ship.bytes} wire B "
        f"({ship.raw_bytes / max(ship.bytes, 1):.2f}x compression)"
    )
    ids = [np.arange(16, dtype=np.int64)]
    _, _, route = g.get_online_features("activity", 1, ids, consumer_region="eastus")
    print(f"read from eastus served by {route['region']} ({route['modeled_ms']} ms)")

    g.tick(now=(hours + 1) * HOUR)   # leave an un-drained suffix, then fail
    g.mark_down("westus2")
    info = g.failover()
    print(f"westus2 down -> promoted {info['promoted']} "
          f"(replayed {info['replayed_batches']} batches on both planes)")
    print(f"promoted offline history rows: {g.fs.offline.num_rows('activity', 1)}")

    g.mark_up("westus2")             # region recovers: its stores are gone...
    info = g.rejoin("westus2")       # ...so it rejoins via delta bootstrap
    print(f"ex-home rejoined: bootstrapped {info['online_rows']} online rows, "
          f"{info['offline_rows']} offline rows in {info['chunks']} chunks")
    g.tick(now=(hours + 2) * HOUR)
    g.drain()
    home_rows = g.fs.offline.num_rows("activity", 1)
    rejoined_rows = g.replicator.offline_stores["westus2"].num_rows("activity", 1)
    print(f"steady state: home offline rows={home_rows}, "
          f"rejoined replica rows={rejoined_rows} (identical={home_rows == rejoined_rows})")

    # -- lossy WAN: the delivery state machine earns its keep ---------------------
    print("\n--- lossy WAN drill (core/channel.py + delivery state machine) ---")
    topo2 = GeoTopology(
        regions={r: Region(r) for r in ("westus2", "eastus")},
        local_latency_ms=1.0,
        cross_region_latency_ms=60.0,
    )
    lossy = GeoFeatureStore(
        "geo-lossy-wan",
        topology=topo2,
        home_region="westus2",
        replica_regions=("eastus",),
        # every 4th frame dropped, plus duplication/corruption/lost acks —
        # all on a seeded schedule, so this walkthrough prints the same
        # ledger every run
        channel=FaultyChannel(
            FaultPlan(
                seed=8,
                drop_rate=0.25,
                dup_rate=0.10,
                corrupt_rate=0.10,
                ack_loss_rate=0.10,
            ),
            topo2,
        ),
        delivery_policy=DeliveryPolicy(
            suspect_after=2, dead_after=5, backoff_base=1, backoff_cap=2,
            probe_interval=1,
        ),
    )
    lossy.register_source(
        SyntheticEventSource("tx", num_entities=16, events_per_bucket=32)
    )
    lossy.create_feature_set(
        FeatureSetSpec(
            name="activity",
            version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("spend_2h", "float32"),),
            source_name="tx",
            transform=DslTransform(
                "entity_id", "ts", [RollingAgg("spend_2h", "amount", 2 * HOUR, "sum")]
            ),
            timestamp_col="ts",
            source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    for h in range(1, (2 if fast else 4) + 1):
        lossy.tick(now=h * HOUR)
        lossy.drain()
    rounds = 0
    while lossy.lag("eastus").batches > 0:  # retry until the log drains dry
        rounds += 1
        assert rounds <= 100, "lossy WAN drill failed to converge"
        lossy.drain()
    st = lossy.replicator.delivery["eastus"]
    channel = lossy.replicator.channel
    print(
        f"channel injected: {channel.counts['dropped']} drops, "
        f"{channel.counts['duplicated']} dups, {channel.counts['corrupted']} "
        f"corruptions, {channel.counts['ack_lost']} lost acks over "
        f"{channel.counts['transmits']} transmits"
    )
    print(
        f"delivery ledger: state={st.status}, retried_batches={st.retries}, "
        f"timeouts={st.timeouts}, crc_rejected={st.corrupt_frames}, "
        f"redelivered={st.redelivered_batches}, transitions={st.transitions}"
    )
    mon = lossy.fs.monitor.system
    print(
        f"monitor: replication/retries/eastus="
        f"{mon.counters.get('replication/retries/eastus', 0):.0f}, "
        f"replication/timeout/eastus="
        f"{mon.counters.get('replication/timeout/eastus', 0):.0f}, "
        f"replication/state/eastus={mon.gauges.get('replication/state/eastus')}"
    )
    home_dump = lossy.fs.online.dump_all("activity", 1)
    rep_dump = lossy.replicator.stores["eastus"].dump_all("activity", 1)
    identical = all(
        np.array_equal(home_dump[n], rep_dump[n]) for n in home_dump.names
    )
    print(f"converged byte-identical through the lossy WAN: {identical}")

    # -- real process boundary: replica daemon over a localhost socket ------------
    print("\n--- socket transport drill (core/daemon.py) ---")
    from repro.core.daemon import SocketChannel, spawn_replica_daemon
    from repro.core.offline_store import OfflineStore
    from repro.core.online_store import OnlineStore
    from repro.core.replication import GeoReplicator, ReplicationLog
    from repro.core.table import Table

    topo3 = GeoTopology(regions={r: Region(r) for r in ("westus2", "eastus")})
    home = OnlineStore()
    home_off = OfflineStore()
    repl = GeoReplicator(
        home,
        topology=topo3,
        home_region="westus2",
        home_offline=home_off,
        log=ReplicationLog(capacity=256),
        policy=DeliveryPolicy(inflight_window=8),
    )
    spec = FeatureSetSpec(
        name="activity",
        version=1,
        entity=Entity("customer", ("entity_id",)),
        features=(Feature("spend_2h", "float32"),),
        source_name="tx",
        transform=DslTransform(
            "entity_id", "ts", [RollingAgg("spend_2h", "amount", 2 * HOUR, "sum")]
        ),
        materialization=MaterializationSettings(
            offline_enabled=True, online_enabled=True
        ),
    )
    rng = np.random.default_rng(11)
    with spawn_replica_daemon(region="eastus") as handle:
        ch = SocketChannel(
            handle.connect(), src="westus2", dst="eastus", topology=topo3
        )
        repl.add_remote_replica("eastus", ch, offline=True)
        print(f"replica daemon pid={handle.proc.pid} on 127.0.0.1:{handle.port}")
        rows = 200 if fast else 2_000
        for i in range(hours):
            frame = Table({
                "entity_id": rng.integers(0, 16, rows).astype(np.int64),
                "ts": ((i + 1) * HOUR + rng.integers(0, HOUR, rows)).astype(
                    np.int64
                ),
                "spend_2h": rng.random(rows).astype(np.float32),
            })
            home.merge(spec, frame, 10**8 + i)
            home_off.merge(spec, frame, 10**8 + i)
        repl.drain("eastus")
        ledger = ch.ledger()
        print(
            f"daemon ledger: {ledger['frames']} frames -> "
            f"{ledger['batches_applied']} batches / "
            f"{ledger['rows_applied']} rows applied, nacks={ledger['nacks']}"
        )
        # one more merge left un-drained, then the home dies mid-stream:
        frame = Table({
            "entity_id": rng.integers(0, 16, rows).astype(np.int64),
            "ts": ((hours + 1) * HOUR + rng.integers(0, HOUR, rows)).astype(
                np.int64
            ),
            "spend_2h": rng.random(rows).astype(np.float32),
        })
        home.merge(spec, frame, 10**9)
        home_off.merge(spec, frame, 10**9)
        pre = home.dump_all("activity", 1)
        topo3.regions["westus2"].healthy = False
        promoted = repl.promote("eastus")
        post = repl.stores["eastus"].dump_all("activity", 1)
        same = all(np.array_equal(pre[n], post[n]) for n in pre.names)
        print(
            f"promoted eastus: replayed {promoted['replayed_batches']} batches, "
            f"adopted daemon state byte-identical={same}"
        )
    print(f"daemon torn down cleanly: exit={handle.proc.poll()}")

    # -- active-active multi-home: every region accepts writes --------------------
    print("\n--- active-active multi-home drill (core/multihome.py) ---")
    from repro.core.multihome import MultiHomeGeoStore

    mh_regions = ("westus2", "eastus", "westeurope")
    topo4 = GeoTopology(
        regions={r: Region(r) for r in mh_regions},
        local_latency_ms=1.0,
        cross_region_latency_ms=60.0,
    )
    mh = MultiHomeGeoStore(
        "geo-multi-home",
        topology=topo4,
        regions=list(mh_regions),
        online_partitions=8,
    )
    mh.create_feature_set(spec)           # same schema as the socket drill
    mh.advance_clock(2 * 10**9)
    print(f"shard ownership: {dict(enumerate(mh.shard_map.owners))}")
    mh_rows = 300 if fast else 1_500
    rng = np.random.default_rng(23)
    for i, r in enumerate(mh_regions):    # concurrent ingest at ALL homes
        frame = Table({
            "entity_id": rng.integers(0, 4096, mh_rows).astype(np.int64),
            "ts": (10**8 + rng.integers(0, HOUR, mh_rows)).astype(np.int64),
            "spend_2h": rng.random(mh_rows).astype(np.float32),
        })
        info = mh.write_batch("activity", 1, frame, region=r, creation_ts=10**9 + i)
        print(
            f"write at {r:11s}: {info['rows']} rows split {info['slices']} "
            f"({info['forwarded_rows']} forwarded to their shard-homes)"
        )
    rounds = mh.converge()
    wl = mh.write_log
    print(
        f"converged in {rounds} round(s); forwarded fraction "
        f"{wl['forwarded_rows'] / wl['rows']:.2f} (~2/3 for 3 uniform ranges)"
    )
    ids4 = [rng.integers(0, 4096, 64).astype(np.int64)]
    _, _, route = mh.get_online_features(
        "activity", 1, ids4, consumer_region="eastus"
    )
    served = {sid: leg["region"] for sid, leg in route["per_range"].items()}
    print(f"read from eastus: per-range routing {served} "
          f"(worst leg {route['modeled_ms']:.0f} ms)")

    victim = mh_regions[2]                # per-shard fail-over: ONLY its range moves
    mh.write_batch("activity", 1, frame, region=mh_regions[0], creation_ts=10**9 + 9)
    mh.mark_down(victim)
    info = mh.failover(victim)
    print(
        f"{victim} down -> shards {info['shards']} promoted to {info['promoted']} "
        f"(replayed {info['replayed_batches']} un-acked batches)"
    )
    mh.converge()
    mh.mark_up(victim)
    back = mh.rejoin(victim)              # returns with ZERO owned shards...
    moved = mh.rebalance(info["shards"][0], victim)  # ...then takes one back
    print(
        f"{victim} rejoined ({back['online_rows']} online rows bootstrapped) "
        f"and re-owns shard {moved['shard']} "
        f"({moved['online_rows']} online rows topped up)"
    )
    mh.converge()
    dumps = [mh.online[r].dump_all("activity", 1) for r in mh.regions()]
    identical = all(
        np.array_equal(dumps[0][n], d[n]) for d in dumps[1:] for n in dumps[0].names
    )
    print(f"all {len(dumps)} cells byte-identical after the full drill: {identical}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="tiny CI-smoke workloads")
    main(fast=ap.parse_args().fast)
