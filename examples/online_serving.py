"""Online-serving example: feature store as the low-latency request plane.

    PYTHONPATH=src python examples/online_serving.py --requests 8 --new-tokens 16

Each request names a session (entity id); the ONLINE store serves the
session's latest materialized context through the Pallas lookup kernel, the
model prefills it and decodes new tokens with a KV cache.  Thin veneer over
repro.launch.serve (the production driver), plus a skew check: the served
online context must equal the offline store's latest record for the same id
(the paper's central online/offline-consistency promise).
"""

import sys

import numpy as np

from repro.launch import serve


def main():
    argv = sys.argv[1:] or ["--requests", "8", "--new-tokens", "16"]
    out = serve.main(argv)
    assert out["tokens_generated"] > 0
    print(
        f"\nexample complete: {out['context_hits']}/{out['requests']} sessions "
        f"served from the online store; generated shape "
        f"{np.asarray(out['generated']).shape}"
    )


if __name__ == "__main__":
    main()
