"""Serving front walkthrough: the §2.1/§3.1.4 request plane in action.

    PYTHONPATH=src python examples/serving_front.py          # full demo
    PYTHONPATH=src python examples/serving_front.py --fast   # CI smoke sizes

Shows the three mechanisms of core/serving.py on a live store:

  1.  micro-batched GETs — concurrent callers submit tickets, one flush
      coalesces them into a single deduplicated store dispatch
  2.  hot-key cache — repeat traffic serves from decoded rows; a
      materializer merge invalidates exactly the touched keys
  3.  overload — with the queue budget exhausted, requests inside the
      staleness bound degrade to cached rows (age reported), the rest shed

and prints the per-stage latency histograms (queue wait / assembly /
kernel / decode) the front records into HealthMonitor.
"""

import argparse

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg
from repro.core.featurestore import FeatureStore
from repro.core.serving import ServingConfig
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def main(fast: bool = False):
    entities = 500 if fast else 4_000
    callers = 8 if fast else 32
    keys_per_caller = 64 if fast else 256

    # -- 1. live store with a caching serving front ---------------------------
    fs = FeatureStore(
        "serving-demo",
        serving=ServingConfig(
            cache_capacity=entities, staleness_bound_ms=2_000
        ),
    )
    fs.register_source(
        SyntheticEventSource(
            "tx", num_entities=entities, events_per_bucket=entities // 2
        )
    )
    fs.create_feature_set(
        FeatureSetSpec(
            name="act",
            version=1,
            entity=fs.create_entity(Entity("customer", ("entity_id",))),
            features=(Feature("spend_2h", "float32"),),
            source_name="tx",
            transform=DslTransform(
                "entity_id",
                "ts",
                [RollingAgg("spend_2h", "amount", 2 * HOUR, "sum")],
            ),
            timestamp_col="ts",
            source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True,
                schedule_interval=HOUR,
            ),
        )
    )
    fs.tick(now=3 * HOUR)
    front = fs.serving

    # -- 2. concurrent callers coalesce into one dispatch ---------------------
    rng = np.random.default_rng(0)
    tickets = [
        front.submit(
            "act", 1, ids=rng.integers(0, entities, keys_per_caller)
        )
        for _ in range(callers)
    ]
    front.flush("act", 1)
    s = front.stats()
    print(f"{callers} callers x {keys_per_caller} keys")
    print(
        f"  -> {int(s['dispatches'])} dispatch(es), "
        f"{int(s['coalesced_keys'])} coalesced / "
        f"{int(s['unique_keys'])} unique keys hit the store"
    )
    hit = sum(int(t.found.sum()) for t in tickets)
    print(f"  found {hit}/{callers * keys_per_caller} rows")

    # -- 3. hot keys serve from cache -----------------------------------------
    hot = rng.integers(0, entities, keys_per_caller)
    front.get("act", 1, ids=hot)
    d_before = front.stats()["dispatches"]
    front.get("act", 1, ids=hot)  # all cached: no store dispatch
    s = front.stats()
    print(
        f"repeat GET: +{int(s['dispatches'] - d_before)} dispatches, "
        f"hit rate {s['cache_hit_rate']:.2f}"
    )

    # -- 4. a merge invalidates exactly the touched keys ----------------------
    fs.tick(now=4 * HOUR)
    s = front.stats()
    print(f"after materializer tick: {int(s['cache_invalidations'])} cached "
          f"rows marked stale")

    # -- 5. overload: degrade inside the staleness bound, shed beyond ---------
    front.get("act", 1, ids=hot)  # re-warm the hot set
    fs.tick(now=5 * HOUR)  # supersede cached rows at t=5h
    front.config.max_queue_keys = 0  # simulate a saturated queue
    fs.advance_clock(5 * HOUR + 1_500)  # age 1.5s <= 2s bound
    t = front.submit("act", 1, ids=hot)
    print(
        f"overloaded, stale age 1500 ms: status={t.status} "
        f"degraded={t.degraded} (served {int(t.found.sum())} cached rows)"
    )
    fs.advance_clock(5 * HOUR + 60_000)  # age 60s > bound
    t = front.submit("act", 1, ids=hot)
    print(f"overloaded, stale age 60 s: status={t.status} (bound enforced)")
    front.config.max_queue_keys = 1 << 30

    # -- 6. per-stage latency histograms --------------------------------------
    snap = fs.monitor.system.snapshot()
    print("per-stage latency (us):")
    for stage in ("queue_wait", "assembly", "kernel", "decode", "request"):
        h = snap["histograms"].get(f"serving/{stage}_us")
        if h and h["n"]:
            print(
                f"  {stage:>10}: p50 {h['p50']:>9.1f}  p99 {h['p99']:>9.1f}"
                f"  (n={h['n']})"
            )
    print(f"max stale age served: {front.max_stale_age_ms:.0f} ms "
          f"(bound {front.config.staleness_bound_ms} ms)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="tiny CI-smoke workloads")
    main(fast=ap.parse_args().fast)
