"""Regenerate the generated tables in EXPERIMENTS.md from results/*.json.

    PYTHONPATH=src python scripts/render_experiments.py

Everything between the <!-- BEGIN:xxx --> / <!-- END:xxx --> markers is
rewritten; hand-written prose outside the markers is preserved.
"""

from __future__ import annotations

import json
import re
import sys
from pathlib import Path

sys.path.insert(0, "benchmarks")
sys.path.insert(0, "src")

V5E_HBM = 16 * 2**30


def _fmt_cell(c) -> str:
    r = c["roofline"]
    peak = c["memory"]["peak_bytes_per_dev"] / 2**30
    ur = r.get("useful_ratio")
    return (
        f"| {c['arch']} | {c['shape']} | {c['kind']} | "
        f"{r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
        f"{r['collective_s']*1e3:.1f} | **{r['dominant']}** | "
        f"{ur:.3f} | {peak:.2f} | "
        f"{'y' if peak*2**30 <= V5E_HBM else 'N'} | {c.get('microbatches',1)} |"
    )


def dryrun_tables(results: dict) -> dict[str, str]:
    from repro.configs.shapes import ALL_ARCHS, LONG_CTX_ARCHS

    single, multi, errors = [], [], []
    skips = [
        f"{a}|long_500k" for a in ALL_ARCHS if a not in LONG_CTX_ARCHS
    ]
    for k, c in sorted(results.items()):
        if not isinstance(c, dict) or c.get("skip"):
            continue
        if c.get("error"):
            errors.append((k, c["error"]))
            continue
        (single if c["mesh"] == "single" else multi).append(c)

    hdr = (
        "| arch | shape | kind | compute ms | memory ms | collective ms | "
        "dominant | useful | peak GiB/dev | fits v5e | µ |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|"
    )
    t_single = "\n".join([hdr] + [_fmt_cell(c) for c in single])

    m_rows = [
        f"| {c['arch']} | {c['shape']} | {c['kind']} | ok | "
        f"{c['memory']['peak_bytes_per_dev']/2**30:.2f} | {c.get('microbatches',1)} |"
        for c in multi
    ]
    t_multi = "\n".join(
        ["| arch | shape | kind | compile | peak GiB/dev | µ |",
         "|---|---|---|---|---|---|"] + m_rows
    )
    t_skips = "\n".join(f"- `{s}` — long_500k on a full-attention arch" for s in skips)
    t_err = "\n".join(f"- `{k}`: {e}" for k, e in errors) or "(none)"
    return {
        "ROOFLINE_SINGLE": t_single,
        "DRYRUN_MULTI": t_multi,
        "SKIPS": t_skips or "(none recorded yet)",
        "ERRORS": t_err,
        "COUNTS": (
            f"single-pod cells compiled: **{len(single)}**, multi-pod cells "
            f"compiled: **{len(multi)}**, skips: **{len(skips)}**, errors: "
            f"**{len(errors)}**"
        ),
    }


def inject(text: str, blocks: dict[str, str]) -> str:
    for name, body in blocks.items():
        pat = re.compile(
            rf"(<!-- BEGIN:{name} -->\n).*?(\n<!-- END:{name} -->)", re.S
        )
        if not pat.search(text):
            print(f"WARNING: marker {name} not found")
            continue
        text = pat.sub(lambda m: m.group(1) + body + m.group(2), text)
    return text


def main() -> None:
    results = json.loads(Path("results/dryrun.json").read_text())
    blocks = dryrun_tables(results)
    p = Path("EXPERIMENTS.md")
    p.write_text(inject(p.read_text(), blocks))
    print("EXPERIMENTS.md tables regenerated;", blocks["COUNTS"])


if __name__ == "__main__":
    main()
