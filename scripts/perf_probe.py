"""§Perf iteration probe: compile ONE depth-scaled cell, print roofline terms
+ collective sites + top tensors, and append to results/perf_iters/<tag>.json.

    PYTHONPATH=src python scripts/perf_probe.py --arch deepseek-v3-671b \
        --shape train_4k --layers 5 --tag ds3_iter3_ep_boundary
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import time
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--layers", type=int, default=0, help="depth override (0=full)")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--memory-pass", action="store_true",
                    help="also run the rolled µ-batched memory pass")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.launch import dryrun, hlo_tools
    from repro.launch import roofline as rf
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs, microbatches_for, step_fn_for
    from repro.models.pspec import activation_mesh, unrolled_scans

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    cfg = get_config(args.arch)
    if args.layers:
        cfg = dryrun._scaled_cfg(cfg, args.layers)
    spec = input_specs(args.arch, args.shape, cfg_override=cfg)
    kind, cargs = spec["kind"], spec["args"]
    step = step_fn_for(kind, cfg, num_microbatches=1)
    in_specs, out_specs, donate = dryrun.shardings_for(kind, cfg, cargs, mesh)
    to_shd = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
    )
    kw = dict(in_shardings=to_shd(in_specs), donate_argnums=donate)
    if out_specs is not None:
        kw["out_shardings"] = to_shd(out_specs)

    t0 = time.time()
    with mesh, activation_mesh(mesh), unrolled_scans():
        compiled = jax.jit(step, **kw).lower(*cargs).compile()
    compile_s = time.time() - t0

    report = rf.roofline_from_compiled(compiled, num_devices=mesh.size)
    txt = compiled.as_text()
    colls = hlo_tools.collective_sites(txt, k=10)
    tops = hlo_tools.top_tensors(txt, k=10)

    out = {
        "tag": args.tag,
        "arch": args.arch,
        "shape": args.shape,
        "layers": args.layers or cfg.num_layers,
        "mesh": args.mesh,
        "compile_s": round(compile_s, 1),
        "roofline": report.to_json(),
        "collective_sites": colls,
        "top_tensors": [
            {"shape": s, "GiB": round(b / 2**30, 3), "count": c}
            for s, b, c in tops
        ],
    }

    if args.memory_pass:
        sh = SHAPES[args.shape]
        mu = microbatches_for(kind, cfg, sh.global_batch, sh.seq_len, mesh)
        step_m = step_fn_for(kind, cfg, num_microbatches=mu)
        with mesh, activation_mesh(mesh):
            cm = jax.jit(step_m, **kw).lower(*cargs).compile()
        ma = cm.memory_analysis()
        out["memory_pass"] = {
            "microbatches": mu,
            "peak_GiB_per_dev": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 2
            ),
            "temp_GiB": round(ma.temp_size_in_bytes / 2**30, 2),
        }

    r = out["roofline"]
    print(f"[{args.tag}] compile={compile_s:.0f}s "
          f"compute={r['compute_s']*1e3:.1f}ms memory={r['memory_s']*1e3:.1f}ms "
          f"collective={r['collective_s']*1e3:.1f}ms dom={r['dominant']}")
    for s in colls[:6]:
        print(f"  coll {s['kind']:18s} {s['shape']:50s} n={s['count']:4d} "
              f"{s['bytes']/2**30:7.2f} GiB")
    for t in out["top_tensors"][:6]:
        print(f"  top  {t['shape']:50s} {t['GiB']:8.3f} GiB x{t['count']}")
    if "memory_pass" in out:
        print(f"  mem-pass µ={out['memory_pass']['microbatches']} "
              f"peak={out['memory_pass']['peak_GiB_per_dev']} GiB/dev")

    p = Path(f"results/perf_iters/{args.tag}.json")
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(out, indent=1))
    print("wrote", p)


if __name__ == "__main__":
    main()
