#!/usr/bin/env bash
# Tier-1 verify: the ROADMAP gate every PR must keep green.
#
#   scripts/tier1.sh              # full suite
#   scripts/tier1.sh tests/core   # any extra pytest args pass through
#
# Wraps the canonical command with PYTHONPATH setup so it works from any
# checkout without an editable install.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q -p no:cacheprovider "$@"
