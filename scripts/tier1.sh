#!/usr/bin/env bash
# Tier-1 verify: the ROADMAP gate every PR must keep green.
#
#   scripts/tier1.sh              # full suite + serving-path bench smoke
#   scripts/tier1.sh tests/core   # any extra pytest args pass through
#
# Wraps the canonical command with PYTHONPATH setup so it works from any
# checkout without an editable install.  After pytest, a fast benchmark
# smoke runs the online-store + geo-replication + serving suites —
# bench_online_store raises on a transfer regression (table-sized
# host<->device traffic on the serving path), bench_geo_replication asserts
# replica convergence on both planes INCLUDING its chaos phase (the same
# workload through a seeded lossy FaultyChannel must still converge
# byte-identical), bench_serving asserts the coalesced kernel GET stays
# within 2x of host and stale reads stay inside the bound — and
# benchmarks/check_regression.py gates the fresh numbers against the
# committed BENCH_online_store.json + BENCH_geo_replication.json +
# BENCH_serving.json trajectory artifacts (transfer/shipped bytes, cache
# hit rate, and the chaos retry/fault ledger exactly; merge/replica-apply/
# serving/chaos-goodput throughput within a machine-calibrated 30%).
# CI (.github/workflows/ci.yml) runs this same script, so a regression
# fails tier-1 locally and the workflow identically.
# Set TIER1_SKIP_BENCH=1 to run tests only.
#
# Budget guard: --durations=15 prints the slowest tests on every run, so a
# test drifting past its budget is visible in the log before it blows the
# CI wall clock.  Tests that are structurally heavy carry pytest markers —
# `slow` (wall-clock-heavy property/convergence sweeps) and `proc` (spawn
# child processes) — and CI runs those lanes in a parallel job while the
# main lane deselects them (-m "not slow and not proc"); a plain local
# `scripts/tier1.sh` still runs everything.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 static analysis (fslint) ==="
# stdlib-only invariant checker (see src/repro/analysis/README.md): the
# recurring bug classes of PRs 5-9 as enforced rules.  Exits nonzero on any
# finding, unused suppression, or stale baseline entry.  ~2s; runs first so
# a rule violation fails fast before the test suite spends minutes.
python -m repro.analysis

python -m pytest -x -q -p no:cacheprovider --durations=15 "$@"

if [[ "${TIER1_SKIP_BENCH:-0}" != "1" ]]; then
  echo "=== tier-1 bench smoke (serving-path transfer guard) ==="
  python -m benchmarks.run --fast --only online_store,geo_replication,serving \
    --out results/bench_fast.json
  echo "=== tier-1 bench-regression gate ==="
  python -m benchmarks.check_regression \
    --current results/bench_fast.json --baseline BENCH_online_store.json \
    --geo-baseline BENCH_geo_replication.json \
    --serving-baseline BENCH_serving.json
fi
