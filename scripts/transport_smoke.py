"""Multi-process transport smoke (ISSUE 8): the CI gate for the socket
carrier.

Spawns a REAL replica daemon (``python -m repro.core.daemon``, its own
interpreter and stores), replicates a seeded two-plane workload from an
in-process home region to it over a localhost socket with the pipelined
in-flight window, then runs the failover drill: mark the home region
down, ``promote`` the remote replica — which force-drains the un-acked
tail and adopts the daemon's state through its dump stream — and verify
the adopted stores byte-identical (online) / chunk-set-identical
(offline) against the pre-failure home.

Hardened the way a CI gate must be:

  * HARD WALL CLOCK — the whole drill runs under a SIGALRM deadline
    (default 120 s, ``--timeout`` to change); a hang exits 124 instead of
    eating the job's timeout budget;
  * GUARANTEED TEARDOWN — the daemon handle is closed in a ``finally``
    (shutdown control -> terminate -> kill, and atexit as the last net),
    and the drill ASSERTS the child is gone afterwards: an orphaned
    daemon fails the step even when everything else passed;
  * LEDGER LOG — the daemon's shipped-frame ledger and the publisher's
    delivery counters are printed on success AND on the failure path, so
    a red run shows what crossed the wire.

Exit codes: 0 success, 1 drill assertion failed, 124 wall-clock timeout.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.assets import (  # noqa: E402
    Entity,
    Feature,
    FeatureSetSpec,
    MaterializationSettings,
)
from repro.core.daemon import SocketChannel, spawn_replica_daemon  # noqa: E402
from repro.core.dsl import UDFTransform  # noqa: E402
from repro.core.offline_store import OfflineStore  # noqa: E402
from repro.core.online_store import OnlineStore  # noqa: E402
from repro.core.regions import GeoTopology, Region  # noqa: E402
from repro.core.replication import (  # noqa: E402
    DeliveryPolicy,
    GeoReplicator,
    ReplicationLog,
)
from repro.core.table import Table  # noqa: E402

HOUR = 3_600_000


def _spec() -> FeatureSetSpec:
    return FeatureSetSpec(
        name="smoke",
        version=1,
        entity=Entity("cust", ("entity_id",)),
        features=(Feature("f0"), Feature("f1")),
        source_name="src",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        materialization=MaterializationSettings(True, True),
    )


def _frame(rng, n: int, entities: int, t0: int) -> Table:
    return Table(
        {
            "entity_id": rng.integers(0, entities, n).astype(np.int64),
            "ts": (t0 + rng.integers(0, HOUR, n)).astype(np.int64),
            "f0": rng.random(n).astype(np.float32),
            "f1": rng.random(n).astype(np.float32),
        }
    )


def drill(merges: int, rows: int) -> dict:
    """Replicate -> failover over a real socket; returns the evidence."""
    spec = _spec()
    topo = GeoTopology(regions={r: Region(r) for r in ("westus2", "eastus")})
    home = OnlineStore()
    home_off = OfflineStore()
    repl = GeoReplicator(
        home,
        topology=topo,
        home_region="westus2",
        home_offline=home_off,
        log=ReplicationLog(capacity=8 * merges + 16),
        policy=DeliveryPolicy(inflight_window=8),
    )
    rng = np.random.default_rng(42)
    handle = spawn_replica_daemon(region="eastus")
    child_pid = handle.proc.pid
    evidence: dict = {"child_pid": child_pid}
    ch = None
    try:
        ch = SocketChannel(
            handle.connect(), src="westus2", dst="eastus", topology=topo
        )
        repl.add_remote_replica("eastus", ch, offline=True)

        # -- replicate ------------------------------------------------------
        for i in range(merges):
            f = _frame(rng, rows, 5_000, (i + 1) * HOUR)
            home.merge(spec, f, 10**8 + i)
            home_off.merge(spec, f, 10**8 + i)
        t0 = time.perf_counter()
        repl.drain("eastus")
        evidence["drain_ms"] = round((time.perf_counter() - t0) * 1e3, 1)
        assert repl.lag_batches("eastus") == 0, "drain left batches pending"
        evidence["delivery"] = {
            "status": repl.delivery["eastus"].status,
            "timeouts": repl.delivery["eastus"].timeouts,
            "retries": repl.delivery["eastus"].retries,
        }

        # -- failover: un-acked tail + promote over the socket --------------
        for i in range(2):
            f = _frame(rng, rows, 5_000, (merges + i + 1) * HOUR)
            home.merge(spec, f, 2 * 10**8 + i)
            home_off.merge(spec, f, 2 * 10**8 + i)
        pre_online = home.dump_all(spec.name, spec.version)
        pre_off = home_off.canonical_history(spec.name, spec.version)

        evidence["ledger"] = ch.ledger()
        topo.regions["westus2"].healthy = False
        t0 = time.perf_counter()
        promoted = repl.promote("eastus")
        evidence["promote_ms"] = round((time.perf_counter() - t0) * 1e3, 1)
        evidence["replayed"] = promoted

        post_online = repl.stores["eastus"].dump_all(spec.name, spec.version)
        for name in pre_online.names:
            np.testing.assert_array_equal(
                post_online[name], pre_online[name], err_msg=name
            )
        post_off = repl.offline_stores["eastus"].canonical_history(
            spec.name, spec.version
        )
        assert len(post_off) == len(pre_off), "offline row count diverged"
        for name in pre_off.names:
            np.testing.assert_array_equal(
                post_off[name], pre_off[name], err_msg=name
            )
        evidence["converged_identical"] = True
        evidence["measured_rtt_ms"] = topo.measured_latency("westus2", "eastus")
    finally:
        if ch is not None:
            ch.close()
        handle.close()
        # an orphaned child is a failure in its own right: the handle's
        # close must have reaped it (shutdown -> terminate -> kill)
        assert handle.proc.poll() is not None, "daemon child still running"
        try:
            os.kill(child_pid, 0)
        except ProcessLookupError:
            evidence["child_reaped"] = True
        else:
            raise AssertionError(f"daemon pid {child_pid} survived teardown")
    return evidence


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--merges", type=int, default=6)
    ap.add_argument("--rows", type=int, default=2_000)
    args = ap.parse_args()

    def on_alarm(signum, frame):  # noqa: ARG001
        print(
            f"transport smoke exceeded the {args.timeout:.0f}s wall clock",
            file=sys.stderr,
        )
        # os._exit skips atexit, but SIGALRM only fires on a hang, and a
        # hung run's job teardown kills the whole process group anyway
        os._exit(124)

    signal.signal(signal.SIGALRM, on_alarm)
    signal.setitimer(signal.ITIMER_REAL, args.timeout)
    try:
        evidence = drill(args.merges, args.rows)
    except AssertionError as e:
        print(f"transport smoke FAILED: {e}", file=sys.stderr)
        return 1
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
    print(json.dumps(evidence, indent=1, default=str))
    print("transport smoke: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
