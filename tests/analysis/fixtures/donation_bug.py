"""Positive fixture for rule ``donation``.

Use-after-donate: ``planes`` is passed in a ``donate_argnums`` slot, so
XLA reuses its device buffer for the output — the later ``planes.sum()``
reads freed device memory (raises at best, garbage in dispatch paths
that skip the check).
"""

import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0,))
def merge_at_slots(planes, updates):
    return planes.at[:].set(updates)


def apply_update(planes, updates):
    merged = merge_at_slots(planes, updates)
    checksum = planes.sum()
    return merged, checksum
