"""Positive fixture for rule ``gauge-keys``.

The PR-9 ``clear_replica_gauges`` bug, verbatim shape: the replica name
is matched as a raw suffix of the gauge key, so clearing ``r1`` touches
``r11``'s gauges, while per-shard keys that put the replica mid-path
(``replication/shard_lag_batches/{replica}/{shard}``) are missed
entirely.  Plus the construction-side half: a gauge key minted by string
concatenation.
"""


class HealthMonitor:
    def __init__(self, system):
        self.system = system

    def clear_replica_gauges(self, replica):
        suffix = f"/{replica}"
        gauges = self.system.gauges
        for key in [
            k
            for k in gauges
            if k.startswith("replication/") and k.endswith(suffix)
        ]:
            del gauges[key]

    def record_lag(self, plane, replica, lag):
        self.system.set_gauge(
            "replication/lag_batches/" + plane + "/" + replica, lag
        )
