"""Negative fixture for rule ``determinism``: the shipped PR-7 shape.

Every decision is a pure splitmix64 hash of (seed, logical tick), and
numpy draws come from an explicitly seeded generator.
"""

import numpy as np

_MASK = (1 << 64) - 1


def _splitmix64(x):
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK
    return x ^ (x >> 31)


def backoff_jitter_ticks(seed, streak):
    return _splitmix64(seed ^ streak) % (2**streak)


def should_drop(seed, tick, rate):
    return (_splitmix64(seed ^ tick) / float(_MASK)) < rate


def fault_schedule(seed, n):
    rng = np.random.default_rng(seed)
    return rng.random(n)
