"""Positive fixture for rule ``format``: over-length line, trailing
whitespace, and a single-quoted string on the ruff-format-claimed tree."""

TABLE = 'driver_hourly_stats'

FLOOR = 1000.0  # merge throughput floor (rows/s), calibrated on the CI runner class, held with margin


def describe():
    return f"table={TABLE} floor={FLOOR}"
RESULTS_DIR = "results"   
