"""Negative fixture for rule ``wire-format``: little-endian-explicit
formats, and every magic dispatched via the decoder's magic tuple."""

import struct

MAGIC = b"FW"
ACK_MAGIC = b"FA"
_STREAM_MAGICS = (MAGIC, ACK_MAGIC)

_HEADER = struct.Struct("<2sBBI")


def encode_ack(seq: int) -> bytes:
    return ACK_MAGIC + struct.pack("<Q", seq)


class StreamDecoder:
    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes):
        self._buf += data
        if len(self._buf) < _HEADER.size:
            return None
        head = bytes(self._buf[:2])
        if head not in _STREAM_MAGICS:
            return None
        return "ack" if head == ACK_MAGIC else "frame"
