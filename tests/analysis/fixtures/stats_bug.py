"""Positive fixture for rule ``frozen-stats``.

A public function returns a bare dict literal whose keys reproduce the
fields of an existing frozen stats dataclass — the typed result PR 9
introduced, downgraded back to a stringly-keyed dict every consumer can
typo into a silent KeyError.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class MergeStats:
    inserts: int
    overrides: int
    noops: int


def merge_summary(inserts: int, overrides: int, noops: int):
    return {"inserts": inserts, "overrides": overrides, "noops": noops}
