"""Negative fixture for rule ``gauge-keys``: the shipped PR-9 fix.

Replica identity is matched as a full ``/``-separated segment (any
position in the key path), and keys are minted as f-strings.
"""


class HealthMonitor:
    def __init__(self, system):
        self.system = system

    def clear_replica_gauges(self, replica):
        gauges = self.system.gauges
        for key in [
            k
            for k in gauges
            if k.startswith("replication/") and replica in k.split("/")
        ]:
            del gauges[key]

    def record_lag(self, plane, replica, lag):
        self.system.set_gauge(
            f"replication/lag_batches/{plane}/{replica}", lag
        )
