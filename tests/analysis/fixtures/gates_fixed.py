"""Negative fixture for rule ``vacuous-gate``: the shipped PR-8 shape.

A missing artifact fails the gate loudly, exceptions are narrow and
handled with a recorded failure, and asserts test measured quantities.
(The narrow ``except ProcessLookupError: pass`` is the legitimate
kill-an-already-dead-pid idiom and must NOT be flagged.)
"""

import json
import os
import signal
from pathlib import Path


def check_regression(report: Path) -> bool:
    if not report.exists():
        raise SystemExit(
            f"{report}: bench artifact missing — the smoke that produces it "
            f"is dead upstream; this gate cannot pass vacuously"
        )
    current = json.loads(report.read_text())
    return current["merge_rows_per_s"] >= 1000.0


def gate_all(reports):
    failures = []
    for report in reports:
        try:
            ok = check_regression(report)
        except ValueError as e:
            failures.append((report, f"unreadable: {e}"))
            continue
        if not ok:
            failures.append((report, "below floor"))
    assert len(reports) > 0
    return failures


def stop_worker(pid: int) -> None:
    try:
        os.kill(pid, signal.SIGTERM)
    except ProcessLookupError:
        pass
