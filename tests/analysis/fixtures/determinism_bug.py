"""Positive fixture for rule ``determinism``.

Wall clock and module-state RNG on the deterministic-replay surface:
``time.time()`` as a decision input, ``random.random()`` drawing from
process-global state, and an entropy-seeded ``default_rng()``.  Any one
of these turns PR-7's byte-replayable chaos ledger into flaky noise.
"""

import random
import time

import numpy as np


def backoff_jitter_ms(streak):
    return (time.time() * 1000.0) % float(2**streak)


def should_drop(rate):
    return random.random() < rate


def fault_schedule(n):
    rng = np.random.default_rng()
    return rng.random(n)
