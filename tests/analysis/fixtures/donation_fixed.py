"""Negative fixture for rule ``donation``: read before donating, and
rebind the caller's handle from the call's result afterwards."""

import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0,))
def merge_at_slots(planes, updates):
    return planes.at[:].set(updates)


def apply_update(planes, updates):
    checksum = planes.sum()
    planes = merge_at_slots(planes, updates)
    return planes, checksum
