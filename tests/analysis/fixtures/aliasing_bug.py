"""Positive fixture for rule ``aliasing``.

The PR-5 ``ReplicationLog.append`` bug, verbatim shape: the logged batch
wraps ``np.asarray`` views of the publisher's arrays.  ``asarray`` is a
no-copy pass-through when the dtype already matches, so the retained log
entry aliases the caller's LIVE merge buffers — a publisher reusing its
arrays rewrites history that replicas have yet to drain.
"""

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReplicatedBatch:
    seq: int
    keys: np.ndarray
    event_ts: np.ndarray
    values: np.ndarray


class ReplicationLog:
    def __init__(self):
        self.next_seq = 0
        self._batches = []

    def append(self, keys: np.ndarray, event_ts: np.ndarray, values: np.ndarray):
        batch = ReplicatedBatch(
            seq=self.next_seq,
            keys=np.asarray(keys, np.int64),
            event_ts=np.asarray(event_ts, np.int64),
            values=np.asarray(values, np.float32),
        )
        self.next_seq += 1
        self._batches.append(batch)
        return batch
