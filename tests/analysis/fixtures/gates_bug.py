"""Positive fixture for rule ``vacuous-gate``.

The PR-8 bench-regression gate failure modes, as Python: a gate that
returns success when its input artifact is missing, a broad except that
swallows the crash the gate exists to report, an except that answers
failure with ``continue``, and an assert on a constant.
"""

import json
from pathlib import Path


def check_regression(report: Path) -> bool:
    if not report.exists():
        return True
    current = json.loads(report.read_text())
    return current["merge_rows_per_s"] >= 1000.0


def load_counters(path: Path) -> dict:
    try:
        return json.loads(path.read_text())
    except Exception:
        pass
    return {}


def gate_all(reports):
    failures = []
    for report in reports:
        try:
            if not check_regression(report):
                failures.append(report)
        except ValueError:
            continue
    assert True
    return failures
