"""Positive fixture for rule ``wire-format``.

Native-order struct formats on the wire surface (no ``<`` prefix: byte
order and alignment change per architecture), and a frame-kind magic
(``ACK_MAGIC``) that encodes but is never dispatched by
``StreamDecoder`` — those frames are dropped as torn-stream garbage on
the receive path.
"""

import struct

MAGIC = b"FW"
ACK_MAGIC = b"FA"

_HEADER = struct.Struct("2sBBI")


def encode_ack(seq: int) -> bytes:
    return ACK_MAGIC + struct.pack("Q", seq)


class StreamDecoder:
    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes):
        self._buf += data
        if len(self._buf) < _HEADER.size:
            return None
        if bytes(self._buf[:2]) == MAGIC:
            return "frame"
        return None
