"""Negative fixture for rule ``frozen-stats``: the public surface returns
the frozen dataclass; dict literals remain legal at serialization
boundaries (``to_dict``-style names are exempt — dicts are their job)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class MergeStats:
    inserts: int
    overrides: int
    noops: int


def merge_summary(inserts: int, overrides: int, noops: int) -> MergeStats:
    return MergeStats(inserts=inserts, overrides=overrides, noops=noops)


def to_dict(stats: MergeStats) -> dict:
    return {
        "inserts": stats.inserts,
        "overrides": stats.overrides,
        "noops": stats.noops,
    }
