"""Negative fixture for rule ``format``: wrapped lines, double quotes,
no trailing whitespace.  The single quote INSIDE a double-quoted string
and the double-quote-bearing single-quoted string are both legal."""

TABLE = "driver_hourly_stats"

# merge throughput floor (rows/s), calibrated on the CI runner class,
# held with margin
FLOOR = 1000.0

QUOTED = 'a "quoted" segment keeps single quotes to avoid escaping'


def describe():
    return f"table={TABLE} floor={FLOOR}"
