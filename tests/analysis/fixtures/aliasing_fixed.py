"""Negative fixture for rule ``aliasing``: the shipped PR-5 fix.

``_frozen_copy`` owns the data (``copy=True``) and freezes it
(``writeable=False``) before the batch enters the log's retention.
"""

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReplicatedBatch:
    seq: int
    keys: np.ndarray
    event_ts: np.ndarray
    values: np.ndarray


def _frozen_copy(a: np.ndarray, dtype=None) -> np.ndarray:
    out = np.array(a, dtype=dtype, copy=True)
    out.flags.writeable = False
    return out


class ReplicationLog:
    def __init__(self):
        self.next_seq = 0
        self._batches = []

    def append(self, keys: np.ndarray, event_ts: np.ndarray, values: np.ndarray):
        batch = ReplicatedBatch(
            seq=self.next_seq,
            keys=_frozen_copy(keys, np.int64),
            event_ts=_frozen_copy(event_ts, np.int64),
            values=_frozen_copy(values, np.float32),
        )
        self.next_seq += 1
        self._batches.append(batch)
        return batch
