"""Per-rule fixture tests for fslint.

Every rule ships with a paired fixture: ``*_bug.py`` reproduces the
historical defect the rule was distilled from (PR-5 aliasing, PR-9
gauge-key substring matching, PR-8 vacuous gates, ...) in the shape it
actually shipped in, and ``*_fixed.py`` is the shape of the landed fix.
The rule must fire on the former and stay silent on the latter — that
pair is the rule's executable specification, and it pins the engine's
scope-override path (``ignore_scope=True``) the fixtures rely on.
"""

from pathlib import Path

import pytest

from repro.analysis.engine import run

FIXTURES = Path(__file__).resolve().parent / "fixtures"

# rule name -> (bug fixture, expected finding count, fixed fixture)
CASES = {
    "aliasing": ("aliasing_bug.py", 1, "aliasing_fixed.py"),
    "determinism": ("determinism_bug.py", 3, "determinism_fixed.py"),
    "donation": ("donation_bug.py", 1, "donation_fixed.py"),
    "gauge-keys": ("gauges_bug.py", 2, "gauges_fixed.py"),
    "vacuous-gate": ("gates_bug.py", 4, "gates_fixed.py"),
    "wire-format": ("wire_bug.py", 3, "wire_fixed.py"),
    "frozen-stats": ("stats_bug.py", 1, "stats_fixed.py"),
    "format": ("format_bug.py", 3, "format_fixed.py"),
}


def _run(rule: str, filename: str):
    return run(
        [str(FIXTURES / filename)],
        select=[rule],
        ignore_scope=True,
        baseline=None,
    )


@pytest.mark.parametrize("rule", sorted(CASES))
def test_rule_fires_on_historical_bug(rule):
    bug, expected, _ = CASES[rule]
    result = _run(rule, bug)
    assert len(result.findings) == expected, [
        f.render() for f in result.findings
    ]
    assert all(f.rule == rule for f in result.findings)
    assert all(f.line > 0 for f in result.findings)


@pytest.mark.parametrize("rule", sorted(CASES))
def test_rule_silent_on_shipped_fix(rule):
    _, _, fixed = CASES[rule]
    result = _run(rule, fixed)
    assert result.findings == [], [f.render() for f in result.findings]
    assert result.clean


def test_every_registered_rule_has_a_fixture_pair():
    from repro.analysis.registry import RULES
    from repro.analysis import rules as _rules  # noqa: F401 - registration

    assert set(RULES) == set(CASES)


# -- pinned messages: the finding must name the defect, not just point ------


def test_aliasing_finding_names_the_container_sink():
    result = _run("aliasing", "aliasing_bug.py")
    (finding,) = result.findings
    assert "defensive copy" in finding.message
    assert ".append()" in finding.message


def test_gauge_finding_names_the_substring_trap():
    result = _run("gauge-keys", "gauges_bug.py")
    messages = " | ".join(f.message for f in result.findings)
    assert "segment" in messages
    assert "endswith" in messages


def test_wire_finding_flags_the_undispatched_magic():
    result = _run("wire-format", "wire_bug.py")
    messages = " | ".join(f.message for f in result.findings)
    assert "ACK_MAGIC" in messages
    assert "byte-order" in messages


def test_donation_finding_names_donor_and_line():
    result = _run("donation", "donation_bug.py")
    (finding,) = result.findings
    assert "merge_at_slots" in finding.message
    assert "donate_argnums" in finding.message
