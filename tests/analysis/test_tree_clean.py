"""Dogfood gate: the default fslint run over the repo must be clean.

This is the same invocation CI runs (``python -m repro.analysis``): every
rule on its scoped surface, the committed (EMPTY) baseline, unused-
suppression and stale-baseline hygiene included.  If this test fails, a
real invariant regressed somewhere in the tree — fix the code, don't
baseline it.
"""

from repro.analysis.engine import run


def test_default_run_is_clean():
    result = run()
    problems = (
        [f.render() for f in result.findings]
        + [
            f"{s.path}:{s.line}: unused suppression {s.rules}"
            for s in result.unused_suppressions
        ]
        + [f"stale baseline: {fp}" for fp in result.stale_baseline]
    )
    assert result.clean, "\n".join(problems)
    # sanity: the run actually covered the tree with the full rule set
    assert result.files_scanned > 100
    assert len(result.rules_run) == 8
