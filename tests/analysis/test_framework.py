"""Framework tests for fslint: suppressions, baseline, walking, CLI.

These drive the engine on synthetic files under ``tmp_path`` (absolute
paths, outside the repo root — also covering the fallback relpath) and
the CLI through in-process ``main(argv)``.
"""

import json
from pathlib import Path

import pytest

from repro.analysis.__main__ import main
from repro.analysis.engine import (
    EXCLUDED_SUBTREES,
    REPO_ROOT,
    iter_python_files,
    run,
)

FIXTURES = Path(__file__).resolve().parent / "fixtures"

#: a one-line determinism violation, the workhorse for suppression tests
VIOLATION = "import time\n\n\ndef now_ms():\n    return time.time() * 1000.0\n"


def _run_determinism(path: Path, **kw):
    kw.setdefault("select", ["determinism"])
    kw.setdefault("ignore_scope", True)
    kw.setdefault("baseline", None)
    return run([str(path)], **kw)


# -- suppressions -------------------------------------------------------------


def test_violation_fires_without_suppression(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(VIOLATION)
    result = _run_determinism(f)
    assert len(result.findings) == 1
    assert not result.clean


def test_same_line_suppression_round_trip(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        VIOLATION.replace(
            "time.time() * 1000.0",
            "time.time() * 1000.0  # fslint: disable=determinism",
        )
    )
    result = _run_determinism(f)
    assert result.findings == []
    assert result.unused_suppressions == []
    assert result.clean


def test_comment_above_suppression_covers_next_line(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        VIOLATION.replace(
            "    return time.time",
            "    # fslint: disable=determinism\n    return time.time",
        )
    )
    result = _run_determinism(f)
    assert result.findings == []
    assert result.unused_suppressions == []


def test_unused_suppression_fails_the_run(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("X = 1  # fslint: disable=determinism\n")
    result = _run_determinism(f)
    assert result.findings == []
    assert len(result.unused_suppressions) == 1
    assert result.unused_suppressions[0].rules == ("determinism",)
    assert not result.clean


def test_suppression_for_unselected_rule_is_not_misreported(tmp_path):
    # the pragma names a rule that did not run; --select subsets must not
    # call it unused
    f = tmp_path / "mod.py"
    f.write_text("X = 1  # fslint: disable=determinism\n")
    result = run(
        [str(f)], select=["wire-format"], ignore_scope=True, baseline=None
    )
    assert result.unused_suppressions == []
    assert result.clean


def test_suppression_covers_only_its_rule(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        VIOLATION.replace(
            "time.time() * 1000.0",
            "time.time() * 1000.0  # fslint: disable=wire-format",
        )
    )
    result = run(
        [str(f)],
        select=["determinism", "wire-format"],
        ignore_scope=True,
        baseline=None,
    )
    # the determinism finding survives; the wire-format pragma is dead
    assert len(result.findings) == 1
    assert len(result.unused_suppressions) == 1


# -- baseline -----------------------------------------------------------------


def test_baseline_subtracts_known_findings(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(VIOLATION)
    first = _run_determinism(f)
    assert len(first.findings) == 1
    baseline = tmp_path / "baseline.json"
    baseline.write_text(
        json.dumps(
            {
                "version": 1,
                "findings": [
                    {"rule": x.rule, "path": x.path, "message": x.message}
                    for x in first.findings
                ],
            }
        )
    )
    second = _run_determinism(f, baseline=baseline)
    assert second.findings == []
    assert second.stale_baseline == []
    assert second.clean


def test_stale_baseline_entry_fails_the_run(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("X = 1\n")
    baseline = tmp_path / "baseline.json"
    baseline.write_text(
        json.dumps(
            {
                "version": 1,
                "findings": [
                    {"rule": "determinism", "path": "gone.py", "message": "x"}
                ],
            }
        )
    )
    result = _run_determinism(f, baseline=baseline)
    assert result.findings == []
    assert result.stale_baseline == ["determinism::gone.py::x"]
    assert not result.clean


def test_committed_baseline_is_empty():
    from repro.analysis.engine import DEFAULT_BASELINE

    data = json.loads(DEFAULT_BASELINE.read_text())
    assert data["findings"] == []


# -- walking / parsing --------------------------------------------------------


def test_fixture_corpus_is_excluded_from_directory_walks():
    (subtree,) = EXCLUDED_SUBTREES
    assert subtree == "tests/analysis/fixtures"
    walked = iter_python_files(REPO_ROOT, ["tests/analysis"])
    assert walked, "the analysis test dir itself must be walkable"
    assert not any("fixtures" in p.parts for p in walked)


def test_explicitly_named_fixture_bypasses_the_exclusion():
    target = FIXTURES / "determinism_bug.py"
    walked = iter_python_files(REPO_ROOT, [str(target)])
    assert walked == [target]


def test_unparseable_file_is_a_finding_not_a_crash(tmp_path):
    f = tmp_path / "broken.py"
    f.write_text("def broken(:\n")
    result = _run_determinism(f)
    assert len(result.findings) == 1
    assert result.findings[0].rule == "parse-error"


def test_unknown_rule_name_raises():
    with pytest.raises(KeyError, match="unknown rule"):
        run(select=["no-such-rule"], baseline=None)


# -- CLI ----------------------------------------------------------------------


def _bug(name: str) -> str:
    return str(FIXTURES / name)


def test_cli_exit_zero_on_clean_file(capsys):
    rc = main(
        [
            "--select=determinism",
            "--no-scope",
            "--baseline=",
            _bug("determinism_fixed.py"),
        ]
    )
    assert rc == 0
    out = capsys.readouterr().out
    assert "0 finding(s)" in out


def test_cli_exit_one_and_renders_findings(capsys):
    rc = main(
        [
            "--select=determinism",
            "--no-scope",
            "--baseline=",
            _bug("determinism_bug.py"),
        ]
    )
    assert rc == 1
    out = capsys.readouterr().out
    assert "[determinism]" in out
    assert "determinism_bug.py" in out


def test_cli_json_output_shape(capsys):
    rc = main(
        [
            "--format=json",
            "--select=wire-format",
            "--no-scope",
            "--baseline=",
            _bug("wire_bug.py"),
        ]
    )
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["clean"] is False
    assert payload["files_scanned"] == 1
    assert payload["rules_run"] == ["wire-format"]
    assert len(payload["findings"]) == 3
    for finding in payload["findings"]:
        assert set(finding) == {"rule", "path", "line", "col", "message"}


def test_cli_unknown_rule_is_usage_error(capsys):
    rc = main(["--select=no-such-rule"])
    assert rc == 2
    assert "unknown rule" in capsys.readouterr().err


def test_cli_write_baseline_round_trip(tmp_path, capsys):
    baseline = tmp_path / "baseline.json"
    rc = main(
        [
            "--select=determinism",
            "--no-scope",
            "--baseline",
            str(baseline),
            "--write-baseline",
            _bug("determinism_bug.py"),
        ]
    )
    assert rc == 0
    assert len(json.loads(baseline.read_text())["findings"]) == 3
    capsys.readouterr()
    rc = main(
        [
            "--select=determinism",
            "--no-scope",
            "--baseline",
            str(baseline),
            _bug("determinism_bug.py"),
        ]
    )
    assert rc == 0
    assert "0 finding(s)" in capsys.readouterr().out


def test_cli_list_rules(capsys):
    rc = main(["--list-rules"])
    assert rc == 0
    out = capsys.readouterr().out
    for name in (
        "aliasing",
        "determinism",
        "donation",
        "gauge-keys",
        "vacuous-gate",
        "wire-format",
        "frozen-stats",
        "format",
    ):
        assert name in out
