"""Unit tests for the roofline instrument itself (HLO text parsing).

The §Perf conclusions rest on collective_bytes / dus_overcount /
promoted-all-reduce accounting being right — so they get their own tests
against synthetic post-SPMD HLO snippets.
"""

from repro.launch.hlo_tools import collective_sites, top_tensors
from repro.launch.roofline import collective_bytes, dus_overcount

HLO = """
HloModule jit_step

%add.5 (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main {
  %p0 = bf16[16,4096,7168]{2,1,0} parameter(0)
  %p1 = f32[16,1024]{1,0} parameter(1)
  %ar0 = bf16[16,4096,7168]{2,1,0} all-reduce(%p0), to_apply=%add.5
  %cvt = f32[16,4096,7168]{2,1,0} convert(%ar0)
  %ar1 = f32[16,4096,7168]{2,1,0} all-reduce(%cvt), to_apply=%add.5.clone_promoted
  %ag = f32[16,1024]{1,0} all-gather(%p1), dimensions={0}
  %a2a = f32[16,1024]{1,0} all-to-all(%p1), dimensions={0}
  %upd = bf16[16,1,7168]{2,1,0} parameter(2)
  %dus = bf16[16,4096,7168]{2,1,0} dynamic-update-slice(%p0, %upd, %p1, %p1, %p1)
  ROOT %t = (bf16[16,4096,7168]{2,1,0}) tuple(%dus)
}
"""

BF16_BIG = 16 * 4096 * 7168 * 2        # bytes of bf16[16,4096,7168]
F32_BIG = 16 * 4096 * 7168 * 4
F32_SMALL = 16 * 1024 * 4
UPD = 16 * 1 * 7168 * 2


def test_collective_bytes_by_kind():
    out = collective_bytes(HLO)
    # ar0 counts bf16 operand; ar1 is PROMOTED -> counted at half (source bf16)
    assert out["all-reduce"] == BF16_BIG + F32_BIG // 2
    assert out["all-gather"] == F32_SMALL
    assert out["all-to-all"] == F32_SMALL


def test_dus_overcount():
    # one DUS: overcount = 2*buffer - update
    assert dus_overcount(HLO) == 2 * BF16_BIG - UPD


def test_top_tensors_ranks_by_bytes():
    tops = top_tensors(HLO, k=3)
    assert tops[0][0].startswith("f32[16,4096,7168]")
    assert tops[0][1] == F32_BIG


def test_collective_sites_groups():
    sites = collective_sites(HLO, k=10)
    kinds = {s["kind"] for s in sites}
    assert {"all-reduce", "all-gather", "all-to-all"} <= kinds
    ar = [s for s in sites if s["kind"] == "all-reduce"]
    assert sum(s["count"] for s in ar) == 2
