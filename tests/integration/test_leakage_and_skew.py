"""§4.4 leakage prevention + online/offline skew, asserted end-to-end.

  * training batches can never contain tokens whose event_ts exceeds the
    loader's data-availability clock (minus the expected delay)
  * the online store's served context equals the offline store's latest
    record for the same entity (no online/offline skew)
  * late-arriving source data (jitter) is held back by expected_delay
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg
from repro.core.featurestore import FeatureStore
from repro.core.offline_store import CREATION_TS, EVENT_TS
from repro.core.table import Table
from repro.data.loader import FeatureStoreLoader, TokenFeatureSet
from repro.data.sources import SyntheticEventSource, TokenEventSource

HOUR = 3_600_000


def _lm_plane(seed=0):
    src = TokenEventSource("tok", seed=seed, vocab_size=512, num_docs=32,
                           chunk_len=16, chunks_per_bucket=64)
    fs = FeatureStore("leak-test", interpret=True)
    fs.register_source(src)
    spec = fs.create_feature_set(TokenFeatureSet(src))
    loader = FeatureStoreLoader(store=fs, spec=spec, seq_len=32, batch_size=4,
                                chunk_len=16, seed=seed)
    return fs, loader


@settings(max_examples=8, deadline=None)
@given(step=st.integers(0, 50), hours=st.integers(2, 12))
def test_no_token_from_the_future(step, hours):
    fs, loader = _lm_plane()
    loader.advance(hours * HOUR)
    batch = loader.sample_batch(step)
    # the leakage property: every chunk in the batch was materialized from
    # events at or before the observation clock
    assert (batch["__max_event_ts__"] <= batch["__observation_ts__"]).all()


def test_clock_monotonicity_and_determinism():
    fs, loader = _lm_plane()
    loader.advance(6 * HOUR)
    b1 = loader.sample_batch(7)
    b2 = loader.sample_batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # (seed, step) pure
    # advancing the clock changes eligibility, not determinism
    loader.advance(9 * HOUR)
    b3 = loader.sample_batch(7)
    assert (b3["__max_event_ts__"] <= 9 * HOUR).all()


def test_online_equals_offline_latest():
    """§4.5.2: online must serve max(tuple(event_ts, creation_ts)) per id."""
    fs = FeatureStore("skew-test", interpret=True)
    src = SyntheticEventSource("tx", num_entities=24, events_per_bucket=120)
    fs.register_source(src)
    fs.create_feature_set(
        FeatureSetSpec(
            name="act", version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"),),
            source_name="tx",
            transform=DslTransform("entity_id", "ts",
                                   [RollingAgg("s2", "amount", 2 * HOUR, "sum")]),
            timestamp_col="ts", source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    fs.tick(now=8 * HOUR)

    hist = fs.offline.read("act", 1)
    ids = np.unique(hist["entity_id"])[:16].astype(np.int64)
    vals, found = fs.get_online_features("act", 1, [ids])
    assert found.all()
    for i, eid in enumerate(ids):
        rows = np.nonzero(hist["entity_id"] == eid)[0]
        order = np.lexsort((hist[CREATION_TS][rows], hist[EVENT_TS][rows]))
        latest = rows[order[-1]]
        np.testing.assert_allclose(vals[i, 0], hist["s2"][latest], rtol=1e-6)


def test_expected_delay_holds_back_late_data():
    """A feature set with expected_delay D must not serve values within D of
    the observation time (the paper's 'expected delay of source and feature
    data')."""
    fs = FeatureStore("delay-test", interpret=True)
    src = SyntheticEventSource("tx", num_entities=8, events_per_bucket=60)
    fs.register_source(src)
    fs.create_feature_set(
        FeatureSetSpec(
            name="act", version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"),),
            source_name="tx",
            transform=DslTransform("entity_id", "ts",
                                   [RollingAgg("s2", "amount", 2 * HOUR, "sum")]),
            timestamp_col="ts", source_lookback=2 * HOUR,
            expected_delay=HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    fs.tick(now=6 * HOUR)
    spine = Table({
        "entity_id": np.arange(8, dtype=np.int64),
        "ts": np.full(8, 4 * HOUR, np.int64),
    })
    frame = fs.get_offline_features(spine, [("act", 1)])
    hist = fs.offline.read("act", 1)
    for i in range(8):
        if not frame["act:v1:__found__"][i]:
            continue
        rows = np.nonzero(
            (hist["entity_id"] == spine["entity_id"][i])
            & (hist["s2"] == frame["act:v1:s2"][i])
        )[0]
        assert (hist[EVENT_TS][rows] <= 4 * HOUR - HOUR).any()
