"""Eventual consistency under failure injection (§4.5.4) + bootstrap
equivalence (§4.5.5) + Fig.5 record semantics — property-based.

The central §4.5 argument: merges are idempotent (offline full-key dedup,
online latest-wins), therefore ANY failure at ANY seam followed by retries
converges both stores to the same state as a failure-free run.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg
from repro.core.featurestore import FeatureStore
from repro.core.offline_store import CREATION_TS, EVENT_TS
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000
SEAMS = ("before_compute", "after_compute", "between_merges", "after_merges")


def _store(seed=0, online=True, offline=True) -> FeatureStore:
    fs = FeatureStore("chaos", interpret=True)
    src = SyntheticEventSource("tx", seed=seed, num_entities=12,
                               events_per_bucket=40)
    fs.register_source(src)
    fs.create_feature_set(
        FeatureSetSpec(
            name="act", version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"),),
            source_name="tx",
            transform=DslTransform("entity_id", "ts",
                                   [RollingAgg("s2", "amount", 2 * HOUR, "sum")]),
            timestamp_col="ts", source_lookback=2 * HOUR,
            materialization=MaterializationSettings(
                offline_enabled=offline, online_enabled=online,
                schedule_interval=HOUR,
            ),
        )
    )
    return fs


def _offline_fingerprint(fs) -> bytes:
    h = fs.offline.read("act", 1)
    order = np.lexsort((h[CREATION_TS], h[EVENT_TS], h["__key__"]))
    return h["s2"][order].tobytes() + h[EVENT_TS][order].tobytes()


@settings(max_examples=10, deadline=None)
@given(
    faults=st.lists(
        st.tuples(st.sampled_from(SEAMS), st.integers(1, 3)),
        min_size=0, max_size=6,
    ),
    hours=st.integers(3, 10),
)
def test_chaos_converges_to_failure_free_state(faults, hours):
    """Arm arbitrary fault patterns; after retries the stores must equal the
    failure-free run's stores exactly (same source is deterministic)."""
    clean = _store()
    clean.tick(now=hours * HOUR)

    chaotic = _store()
    for seam, times in faults:
        chaotic.faults.arm(seam, times)
    chaotic.tick(now=hours * HOUR)
    # jobs that exhausted their automatic retries leave timeline gaps; the
    # §4.5.2 'manual retry' path (repair) re-drives them to convergence
    for _ in range(4):
        if chaotic.scheduler.materialized_intervals("act", 1) == [
            (0, hours * HOUR)
        ]:
            break
        chaotic.repair("act", 1)

    assert _offline_fingerprint(chaotic) == _offline_fingerprint(clean)
    rep = chaotic.check_consistency("act", 1)
    assert rep.consistent, rep.summary()
    assert chaotic.scheduler.materialized_intervals("act", 1) == [
        (0, hours * HOUR)
    ]


def test_failure_between_merges_reaches_eventual_consistency():
    """The paper's exact §4.5.4 scenario: offline merge lands, online merge
    fails -> stores diverge -> retry converges them."""
    fs = _store()
    fs.faults.arm("between_merges", 1)
    fs.tick(now=2 * HOUR)
    fs.tick(now=2 * HOUR)  # retries the failed job
    rep = fs.check_consistency("act", 1)
    assert rep.consistent, rep.summary()


def test_bootstrap_offline_to_online_matches_always_on():
    """§4.5.5: enabling online late + bootstrap == online enabled all along."""
    always = _store(online=True)
    always.tick(now=6 * HOUR)

    late = _store(online=False)
    late.tick(now=6 * HOUR)
    n = late.enable_online("act", 1)
    assert n > 0

    ids = np.arange(12, dtype=np.int64)
    va, fa = always.get_online_features("act", 1, [ids])
    vl, fl = late.get_online_features("act", 1, [ids])
    np.testing.assert_array_equal(fa, fl)
    np.testing.assert_allclose(va[fa], vl[fl], rtol=1e-6)


def test_bootstrap_online_to_offline():
    """§4.5.5 reverse direction: offline enabled late gets online's records
    (latest-only — the documented asymmetry)."""
    fs = _store(online=True, offline=False)
    fs.tick(now=4 * HOUR)
    assert len(fs.offline.read("act", 1)) == 0
    n = fs.enable_offline("act", 1)
    assert n > 0
    h = fs.offline.read("act", 1)
    # exactly one record per live online id
    assert len(h) == len(np.unique(h["__key__"]))
    rep = fs.check_consistency("act", 1)
    assert rep.consistent


def test_fig5_semantics_exact():
    """The worked Fig.5 example: R0(t0), R1(t1), R2(t2), then R3 rewrites t1
    with a later creation_ts.  Offline keeps 4 records; online still serves
    R2 (greater event_ts wins over creation_ts)."""
    fs = _store()
    fs.tick(now=3 * HOUR)  # materialize t0..t2 equivalents
    spec = fs.registry.get_feature_set("act", 1)
    # backfill re-materializes an old window -> new creation_ts for same
    # event window (the R3 pattern)
    before = len(fs.offline.read("act", 1))
    fs.backfill("act", 1, start=0, end=1 * HOUR)
    h = fs.offline.read("act", 1)
    # offline: every (id, event_ts, creation_ts) kept — backfill adds records
    # only if creation differs; dedup guarantees no duplicates
    assert len(h) >= before
    keys = np.stack([h["__key__"], h[EVENT_TS], h[CREATION_TS]], axis=1)
    assert len(np.unique(keys, axis=0)) == len(h)
    # online: still the latest event_ts per id
    rep = fs.check_consistency("act", 1)
    assert rep.consistent
