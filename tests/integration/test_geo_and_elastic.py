"""Geo fail-over (§3.1.2, §4.1.2) + elastic mesh resharding — integration.

The elastic test runs in a subprocess because the 8-device host platform
flag must be set before jax initializes (the test process runs 1-device).
"""

import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg
from repro.core.featurestore import FeatureStore
from repro.core.regions import (
    ComplianceError,
    GeoTopology,
    Region,
    RegionDownError,
    ReplicationPolicy,
)
from repro.data.sources import SyntheticEventSource

HOUR = 3_600_000


def _geo_store(policy, fenced=False):
    topo = GeoTopology(
        regions={
            "westus2": Region("westus2", geo_fenced=fenced),
            "eastus": Region("eastus"),
        },
        local_latency_ms=1.0, cross_region_latency_ms=60.0,
    )
    fs = FeatureStore("geo", region="westus2", topology=topo, replication=policy)
    src = SyntheticEventSource("tx", num_entities=8, events_per_bucket=30)
    fs.register_source(src)
    fs.create_feature_set(
        FeatureSetSpec(
            name="act", version=1,
            entity=Entity("customer", ("entity_id",)),
            features=(Feature("s2", "float32"),),
            source_name="tx",
            transform=DslTransform("entity_id", "ts",
                                   [RollingAgg("s2", "amount", HOUR, "sum")]),
            timestamp_col="ts", source_lookback=HOUR,
            materialization=MaterializationSettings(
                offline_enabled=True, online_enabled=True, schedule_interval=HOUR
            ),
        )
    )
    return fs


def test_failover_resumes_without_data_loss():
    fs = _geo_store(ReplicationPolicy.GEO_REPLICATED)
    fs.tick(now=4 * HOUR)
    fs.geo.add_replica("eastus")
    state = fs.scheduler_state()

    fs.geo.mark_down("westus2")
    assert fs.geo.failover() == "eastus"
    # reads keep working (served by the replica)
    serving, _ = fs.geo.route_read("westus2")
    assert serving == "eastus"

    # the promoted region restores control-plane state and resumes the
    # timeline exactly where it stopped — no holes, no re-materialization
    fs.restore_scheduler(state)
    fs.tick(now=7 * HOUR)
    assert fs.scheduler.materialized_intervals("act", 1) == [(0, 7 * HOUR)]
    assert fs.check_consistency("act", 1).consistent


def test_cross_region_access_no_replica_down_raises():
    fs = _geo_store(ReplicationPolicy.CROSS_REGION_ACCESS)
    fs.geo.mark_down("westus2")
    with pytest.raises(RegionDownError):
        fs.geo.route_read("eastus")


def test_geo_fencing_blocks_replication():
    fs = _geo_store(ReplicationPolicy.GEO_REPLICATED, fenced=True)
    with pytest.raises(ComplianceError):
        fs.geo.add_replica("eastus")


def test_hub_and_spoke_cross_subscription_sharing():
    """§4.1.1/§4.1.2: spokes in other subscriptions/regions resolve assets
    through the hub; cross-region reads require an explicit grant."""
    fs = _geo_store(ReplicationPolicy.CROSS_REGION_ACCESS)
    from repro.core.registry import RegistryError, Workspace

    spoke = Workspace("ml-team-b", subscription="sub-B", region="eastus")
    fs.registry.attach_workspace(spoke)
    # no grant yet -> cross-region access denied
    with pytest.raises(RegistryError):
        fs.registry.resolve_for_workspace("ml-team-b", "act", 1)
    fs.registry.grant_access("ml-team-b", "act")
    spec, mode = fs.registry.resolve_for_workspace("ml-team-b", "act", 1)
    assert spec.name == "act" and mode == "cross-region"
    # local spoke resolves without a grant
    local = Workspace("ml-team-a", subscription="sub-A", region="westus2")
    fs.registry.attach_workspace(local)
    _, mode = fs.registry.resolve_for_workspace("ml-team-a", "act", 1)
    assert mode == "local"


_ELASTIC_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys, tempfile
    import jax, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint.manager import restore_checkpoint, save_checkpoint
    from repro.configs import get_config
    from repro.launch.steps import TrainState, make_train_step
    from repro.models import api
    from repro.models import sharding as shd
    from repro.models.pspec import activation_mesh
    from repro.optim.adamw import adamw
    import dataclasses

    cfg = get_config("qwen1.5-4b", reduced=True)
    cfg = dataclasses.replace(cfg, param_dtype="float32", compute_dtype="float32")
    opt = adamw(lr=1e-3)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    state = TrainState.create(params, opt)
    batch = api.make_dummy_batch(cfg, 4, 16)
    step = make_train_step(cfg, opt)

    def place(state, mesh):
        pspec = shd.param_specs(state.params, cfg, mesh)
        from repro.launch.dryrun import opt_state_specs
        sspec = TrainState(pspec, opt_state_specs(state.opt, pspec), P())
        shards = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                              is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(state, shards), shards

    # run 2 steps on a 4x2 mesh, checkpoint
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    state_a, shards_a = place(state, mesh_a)
    with mesh_a, activation_mesh(mesh_a):
        jit_a = jax.jit(step)
        state_a, _ = jit_a(state_a, batch)
        state_a, _ = jit_a(state_a, batch)
    d = tempfile.mkdtemp()
    save_checkpoint(d, 2, state_a)

    # restore onto a DIFFERENT (2x4) mesh and continue
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    template = jax.eval_shape(lambda: TrainState.create(
        api.init_params(jax.random.PRNGKey(0), cfg), opt))
    _, shards_b = place(jax.tree.map(np.zeros_like,
                                     jax.device_get(state_a)), mesh_b)
    state_b, _ = restore_checkpoint(d, 2, template, shardings=shards_b)
    with mesh_b, activation_mesh(mesh_b):
        state_b, metrics_b = jax.jit(step)(state_b, batch)

    # reference: continue on the original mesh
    with mesh_a, activation_mesh(mesh_a):
        state_ref, metrics_ref = jit_a(state_a, batch)

    out = {
        "loss_resharded": float(metrics_b["total_loss"]),
        "loss_reference": float(metrics_ref["total_loss"]),
    }
    print("ELASTIC_RESULT " + json.dumps(out))
    """
)


@pytest.mark.proc
def test_elastic_reshard_subprocess():
    """Checkpoint saved from a (4,2) mesh restores onto a (2,4) mesh and the
    next step's loss matches the non-resharded continuation."""
    proc = subprocess.run(
        [sys.executable, "-c", _ELASTIC_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("ELASTIC_RESULT")]
    assert line, proc.stdout
    res = json.loads(line[0].split(" ", 1)[1])
    np.testing.assert_allclose(
        res["loss_resharded"], res["loss_reference"], rtol=1e-5
    )
