"""End-to-end driver fault tolerance: kill -> restart -> identical result.

The paper's §3.1.2 resume guarantee ("safely resume from where it left off
without any data loss"), asserted across the WHOLE stack: train state,
optimizer moments (deterministically quantized), the feature-store
scheduler's interval state, and the loader's data clock all ride the
checkpoint.
"""

import numpy as np
import pytest

from repro.launch import train

ARGS = ["--arch", "gemma-2b", "--steps", "12", "--batch", "2", "--seq", "32",
        "--ckpt-every", "4", "--log-every", "100"]


@pytest.mark.proc
def test_kill_restart_bit_identical(tmp_path):
    d1 = str(tmp_path / "uninterrupted")
    ref = train.main(ARGS + ["--ckpt-dir", d1])
    assert ref["steps_run"] == 12

    d2 = str(tmp_path / "killed")
    with pytest.raises(SystemExit) as e:
        train.main(ARGS + ["--ckpt-dir", d2, "--kill-at", "9"])
    assert e.value.code == 17  # simulated node failure

    resumed = train.main(ARGS + ["--ckpt-dir", d2])
    # resumed from step 8 checkpoint -> runs 9..11
    assert resumed["start_step"] == 9
    # the tail of the loss curve must match the uninterrupted run exactly:
    # same data (loader clock restored), same state (deterministic ckpt)
    np.testing.assert_allclose(
        resumed["losses"], ref["losses"][9:], rtol=0, atol=0
    )


def test_loss_decreases_over_training(tmp_path):
    res = train.main(ARGS)
    assert res["last_loss"] < res["first_loss"]
