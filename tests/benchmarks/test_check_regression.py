"""Regression tests for the bench gate's phase-presence discipline.

Every phase extraction in ``benchmarks/check_regression.py`` goes through
``require_phase``: a phase missing from a bench result means the section
that produces it silently stopped running upstream, and the gate must
fail LOUDLY (named phase, named source, available keys) instead of dying
with an opaque KeyError — or worse, ``.get(..., {})``-ing its way to a
vacuous pass (the PR-8 failure mode).
"""

import pytest

from benchmarks.check_regression import (
    check_chaos,
    check_multi_home,
    check_serving,
    check_socket,
    check_transfer_bytes,
    require_phase,
)


def test_require_phase_returns_the_section():
    result = {"resident_cycle": {"per_cycle_bytes": 128}}
    section = require_phase(result, "resident_cycle", source="current")
    assert section == {"per_cycle_bytes": 128}


def test_require_phase_missing_fails_loudly():
    with pytest.raises(SystemExit) as exc:
        require_phase({"other": {}}, "resident_cycle", source="current")
    msg = str(exc.value)
    assert "resident_cycle" in msg
    assert "current" in msg
    assert "other" in msg  # names what IS present
    assert "vacuous" in msg


def test_require_phase_on_empty_result_names_the_gap():
    with pytest.raises(SystemExit, match="<empty>"):
        require_phase({}, "socket", source="current geo")


def test_require_phase_rejects_scalar_phase():
    with pytest.raises(SystemExit, match="not a mapping"):
        require_phase({"socket": 42}, "socket", source="current geo")


def test_require_phase_accepts_list_phases():
    # lookup_table is a top-level phase that is a list of rows
    rows = [{"entities": 1, "batch": 2}]
    assert require_phase({"lookup_table": rows}, "lookup_table", source="x") == rows


# -- the gate functions inherit the loud failure ----------------------------


def test_check_socket_without_phase_refuses_to_gate():
    with pytest.raises(SystemExit, match="socket"):
        check_socket({}, {}, [])


def test_check_serving_without_overload_refuses_to_gate():
    # closed_loop present but the overload section vanished: the old code
    # would KeyError (current) or gate nothing; now it names the gap
    stack = {
        "mean_coalesced_keys": 4096,
        "cache_hit_rate": 0.5,
        "lookups_per_s": 1000,
        "max_stale_age_ms": 1,
    }
    closed = {"kernel_over_host_x": 1.0, "host": stack, "kernel": stack}
    cur = {"closed_loop": closed}
    base = {"closed_loop": closed, "overload": {"staleness_bound_ms": 100}}
    with pytest.raises(SystemExit, match="overload"):
        check_serving(cur, base, 0.3, 1.0, [])


def test_check_chaos_without_partition_refuses_to_gate():
    cur = {"chaos": {"converged_identical": True}}
    base = {"chaos": {}}
    with pytest.raises(SystemExit, match="partition"):
        check_chaos(cur, base, 0.3, 1.0, [])


def test_check_multi_home_without_failover_refuses_to_gate():
    section = {
        "per_shard_shipped_bytes": {"s0": 10},
        "online_identical": True,
        "offline_identical": True,
    }
    with pytest.raises(SystemExit, match="failover"):
        check_multi_home({"multi_home": section}, {"multi_home": section}, 0.3, [])


def test_intact_phases_still_gate_normally():
    cur = {
        "resident_cycle": {
            "transfers": {"device_uploads": 0, "host_syncs": 0},
            "per_cycle_bytes": 128,
        },
        "lookup_table": [],
    }
    failures: list = []
    check_transfer_bytes(cur, cur, failures)
    assert failures == []


def test_intact_phases_still_catch_regressions():
    base = {
        "resident_cycle": {
            "transfers": {"device_uploads": 0, "host_syncs": 0},
            "per_cycle_bytes": 128,
        },
        "lookup_table": [],
    }
    cur = {
        "resident_cycle": {
            "transfers": {"device_uploads": 0, "host_syncs": 0},
            "per_cycle_bytes": 256,
        },
        "lookup_table": [],
    }
    failures: list = []
    check_transfer_bytes(cur, base, failures)
    assert len(failures) == 1
    assert "transfer bytes regressed" in failures[0]
