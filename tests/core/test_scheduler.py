"""Scheduling subsystem (paper §3.1.1, §4.3): window-state tracking,
non-overlap invariant, context-aware backfill, retries, resume."""


import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import (
    IntervalSet,
    JobKind,
    JobState,
    Scheduler,
)
from repro.core.transform import FeatureWindow

H = 3_600_000


class TestIntervalSet:
    def test_merge_and_gaps(self):
        iv = IntervalSet()
        iv.add(0, 10)
        iv.add(20, 30)
        iv.add(10, 20)  # touching intervals coalesce
        assert iv.intervals == [(0, 30)]
        assert iv.gaps_within(0, 30) == []
        iv2 = IntervalSet([(0, 10), (20, 30)])
        assert iv2.gaps_within(5, 25) == [(10, 20)]
        assert iv2.covers(0, 10) and not iv2.covers(5, 15)

    @settings(max_examples=200, deadline=None)
    @given(
        st.lists(
            st.tuples(st.integers(0, 500), st.integers(1, 50)), min_size=1, max_size=20
        )
    )
    def test_property_disjoint_sorted(self, spans):
        """After arbitrary adds, intervals are sorted, disjoint, non-touching."""
        iv = IntervalSet()
        for s, l in spans:
            iv.add(s, s + l)
        out = iv.intervals
        for (s1, e1), (s2, e2) in zip(out, out[1:]):
            assert e1 < s2, out  # strictly disjoint with gaps
        # coverage: every added point is covered
        for s, l in spans:
            assert iv.covers(s, s + l)


def make_sched(cadence=H, unit=None):
    s = Scheduler()
    s.register_feature_set("fs", 1, schedule_interval=cadence, partition_window=unit)
    return s


class TestScheduledMaterialization:
    def test_tick_generates_cadence_windows(self):
        s = make_sched()
        jobs = s.tick(now=3 * H + 5)
        assert [(j.window.start, j.window.end) for j in jobs] == [
            (0, H), (H, 2 * H), (2 * H, 3 * H),
        ]
        assert all(j.kind is JobKind.SCHEDULED for j in jobs)
        # completing jobs updates data state
        for j in jobs:
            s.mark_running(j.job_id)
            s.mark_succeeded(j.job_id)
        assert s.is_materialized("fs", 1, 0, 3 * H)
        assert s.tick(now=3 * H + 5) == []  # nothing new due

    def test_overlap_invariant_enforced(self):
        s = make_sched()
        s.tick(now=H)
        with pytest.raises(RuntimeError, match="invariant"):
            s._enqueue(("fs", 1), FeatureWindow(0, H // 2), JobKind.BACKFILL)

    def test_staleness_metric(self):
        s = make_sched()
        for j in s.tick(now=2 * H):
            s.mark_running(j.job_id)
            s.mark_succeeded(j.job_id)
        assert s.staleness("fs", 1, now=2 * H + 500) == 500


class TestBackfill:
    def test_backfill_suspends_scheduled(self):
        """§3.1.1: backfill temporarily suspends conflicting scheduled jobs,
        which resume (or cancel if covered) afterwards."""
        s = make_sched()
        scheduled = s.tick(now=2 * H)
        assert len(scheduled) == 2
        backfill = s.request_backfill("fs", 1, FeatureWindow(0, 2 * H))
        assert all(j.state is JobState.SUSPENDED for j in scheduled)
        for j in backfill:
            s.mark_running(j.job_id)
            s.mark_succeeded(j.job_id)
        resumed = s.resume_suspended()
        assert resumed == []  # fully covered by the backfill -> cancelled
        assert all(j.state is JobState.CANCELLED for j in scheduled)

    def test_backfill_partitioned_and_coalesced(self):
        """Backfill splits into unit windows and SKIPS already-materialized
        sub-windows (context-aware partitioning)."""
        s = make_sched(cadence=H, unit=H)
        s.data_state[("fs", 1)].add(H, 2 * H)  # middle hour already done
        jobs = s.request_backfill("fs", 1, FeatureWindow(0, 3 * H))
        windows = sorted((j.window.start, j.window.end) for j in jobs)
        assert windows == [(0, H), (2 * H, 3 * H)]

    def test_backfill_against_running_job_rejected(self):
        s = make_sched()
        jobs = s.tick(now=H)
        s.mark_running(jobs[0].job_id)
        with pytest.raises(RuntimeError, match="running"):
            s.request_backfill("fs", 1, FeatureWindow(0, H))


class TestRetryAndResume:
    def test_retry_then_nonrecoverable_alert(self):
        s = make_sched()
        (job,) = s.tick(now=H)
        s.mark_running(job.job_id)
        assert s.mark_failed(job.job_id, "boom")  # retry 1
        assert s.mark_failed(job.job_id, "boom")  # retry 2
        assert not s.mark_failed(job.job_id, "boom")  # attempts exhausted
        assert job.state is JobState.FAILED
        assert "non-recoverable" in s.alerts[0]

    def test_json_roundtrip_requeues_interrupted(self):
        """§3.1.2: a job RUNNING at checkpoint time resumes as QUEUED —
        no data loss, no double-covering."""
        s = make_sched()
        jobs = s.tick(now=2 * H)
        s.mark_running(jobs[0].job_id)
        s.mark_succeeded(jobs[0].job_id)
        s.mark_running(jobs[1].job_id)  # interrupted mid-flight
        restored = Scheduler.from_json(s.to_json())
        assert restored.jobs[jobs[0].job_id].state is JobState.SUCCEEDED
        assert restored.jobs[jobs[1].job_id].state is JobState.QUEUED
        assert restored.data_state[("fs", 1)].intervals == [(0, H)]
        assert restored.schedule_cursor[("fs", 1)] == 2 * H

    @settings(max_examples=50, deadline=None)
    @given(st.integers(1, 10), st.integers(0, 5))
    def test_property_no_active_overlap(self, hours, backfills):
        """Whatever mix of ticks and backfills, active jobs never overlap."""
        s = make_sched(cadence=H, unit=H)
        s.tick(now=hours * H)
        for i in range(backfills):
            try:
                s.request_backfill(
                    "fs", 1, FeatureWindow(i * H // 2, i * H // 2 + H)
                )
            except RuntimeError:
                pass
        active = [
            j for j in s.jobs.values()
            if j.state in (JobState.QUEUED, JobState.RUNNING)
        ]
        for a in active:
            for b in active:
                if a.job_id < b.job_id:
                    assert not a.window.overlaps(b.window)
