"""Wire codec round-trip properties (ISSUE 5 tentpole).

The transport's contract: encode→decode is IDENTITY on ``ReplicatedBatch``
content for both planes, bit-exact for every record-schema dtype, with or
without compression, for empty through maximal batches — and a coalesced
run decodes back to the same per-batch ack sequence the un-coalesced path
would have produced.  Decoded arrays must be read-only (a replica can never
scribble on what it was handed), and foreign/corrupt bytes must raise
``WireFormatError`` instead of decoding garbage.
"""

import zlib

import numpy as np
import pytest

from repro.core import wire
from repro.core.online_store import OnlineStore
from repro.core.regions import GeoTopology, Region
from repro.core.replication import GeoReplicator, ReplicatedBatch, ReplicationLog
from tests.core.test_replication import make_frame, make_spec

# every dtype the offline record schema can put in a column: int64 index
# columns + timestamps, plus whatever numpy dtype a Feature declares
RECORD_SCHEMA_DTYPES = [
    np.int64,
    np.int32,
    np.int16,
    np.int8,
    np.uint64,
    np.uint32,
    np.uint16,
    np.uint8,
    np.float64,
    np.float32,
    np.float16,
    np.bool_,
]


def random_online_batch(rng, seq=0, rows=None, d=None):
    rows = int(rng.integers(0, 50)) if rows is None else rows
    d = int(rng.integers(0, 5)) if d is None else d
    return ReplicatedBatch(
        seq=seq,
        table=("fs", 1),
        creation_ts=int(rng.integers(0, 2**40)),
        keys=rng.integers(0, 2**62, rows).astype(np.int64),
        event_ts=rng.integers(0, 2**40, rows).astype(np.int64),
        values=rng.random((rows, d)).astype(np.float32),
    )


def random_offline_batch(rng, seq=0, rows=None, dtypes=(np.int64, np.float32)):
    rows = int(rng.integers(0, 50)) if rows is None else rows
    cols = {"entity_id": rng.integers(0, 100, rows).astype(np.int64)}
    for i, dt in enumerate(dtypes):
        dt = np.dtype(dt)
        if dt.kind == "f":
            cols[f"f{i}"] = rng.random(rows).astype(dt)
        elif dt.kind == "b":
            cols[f"f{i}"] = rng.integers(0, 2, rows).astype(dt)
        else:
            hi = min(2**62, int(np.iinfo(dt).max)) + 1
            cols[f"f{i}"] = rng.integers(0, hi, rows).astype(dt)
    return ReplicatedBatch(
        seq=seq,
        table=("fs", 1),
        creation_ts=int(rng.integers(0, 2**40)),
        keys=rng.integers(0, 2**62, rows).astype(np.int64),
        event_ts=rng.integers(0, 2**40, rows).astype(np.int64),
        values=np.empty((rows, 0), np.float32),
        plane="offline",
        columns=cols,
    )


def assert_batches_equal(a: ReplicatedBatch, b: ReplicatedBatch):
    assert a.seq == b.seq
    assert a.table == b.table
    assert a.creation_ts == b.creation_ts
    assert a.plane == b.plane
    for name in ("keys", "event_ts", "values"):
        got, want = getattr(b, name), getattr(a, name)
        assert got.dtype == want.dtype, name
        assert got.shape == want.shape, name
        np.testing.assert_array_equal(got, want, err_msg=name)
    if a.columns is None:
        assert b.columns is None
    else:
        assert list(b.columns) == list(a.columns)  # order carries too
        for k in a.columns:
            assert b.columns[k].dtype == a.columns[k].dtype, k
            np.testing.assert_array_equal(b.columns[k], a.columns[k], err_msg=k)


# -- round trips ---------------------------------------------------------------


@pytest.mark.parametrize("compress_level", [0, 1, 6])
def test_roundtrip_property_both_planes(compress_level):
    """Randomized shapes on both planes: encode→decode is identity."""
    rng = np.random.default_rng(42)
    for trial in range(40):
        if trial % 2:
            batch = random_online_batch(rng, seq=trial)
        else:
            batch = random_offline_batch(rng, seq=trial)
        frame = wire.encode_batch(batch, compress_level=compress_level)
        assert frame.seqs == (trial,)
        assert frame.rows == batch.rows
        assert frame.plane == batch.plane
        assert_batches_equal(batch, wire.decode_batch(frame.data))


@pytest.mark.parametrize("dtype", RECORD_SCHEMA_DTYPES)
def test_roundtrip_every_record_schema_dtype(dtype):
    """Offline columns survive bit-exact in their NATIVE dtype — the wire
    must never silently promote (or truncate) a record-schema column."""
    rng = np.random.default_rng(7)
    batch = random_offline_batch(rng, rows=33, dtypes=(dtype, dtype, np.int64))
    for level in (0, 6):
        decoded = wire.decode_batch(
            wire.encode_batch(batch, compress_level=level).data
        )
        assert_batches_equal(batch, decoded)
        assert decoded.columns["f0"].dtype == np.dtype(dtype)


def test_roundtrip_empty_and_degenerate_batches():
    rng = np.random.default_rng(3)
    cases = [
        random_online_batch(rng, rows=0, d=0),  # fully empty
        random_online_batch(rng, rows=0, d=4),  # zero rows, nonzero width
        random_online_batch(rng, rows=5, d=0),  # zero-width values plane
        random_offline_batch(rng, rows=0),  # empty offline chunk
        ReplicatedBatch(  # bootstrap sentinel seq + empty columns dict
            seq=wire.BOOTSTRAP_SEQ,
            table=("a-table-with-a-long-name", 2**31 - 1),
            creation_ts=0,
            keys=np.empty(0, np.int64),
            event_ts=np.empty(0, np.int64),
            values=np.empty((0, 0), np.float32),
            plane="offline",
            columns={},
        ),
    ]
    for batch in cases:
        for level in (0, 6):
            frame = wire.encode_batch(batch, compress_level=level)
            assert_batches_equal(batch, wire.decode_batch(frame.data))


def test_roundtrip_maximal_batch():
    """A large mixed batch: many rows, wide values, every-dtype columns."""
    rng = np.random.default_rng(11)
    online = random_online_batch(rng, rows=20_000, d=16)
    offline = random_offline_batch(
        rng, rows=20_000, dtypes=tuple(RECORD_SCHEMA_DTYPES)
    )
    for batch in (online, offline):
        frame = wire.encode_batch(batch)
        assert_batches_equal(batch, wire.decode_batch(frame.data))
        assert frame.raw_nbytes > batch.nbytes  # payload + array framing


# -- compression ---------------------------------------------------------------


def test_compression_recorded_and_effective():
    """Compressible payloads shrink on the wire and the ratio says so;
    level 0 ships raw at a fixed small framing overhead."""
    batch = ReplicatedBatch(
        seq=0,
        table=("fs", 1),
        creation_ts=1,
        keys=np.arange(10_000, dtype=np.int64),
        event_ts=np.full(10_000, 123, np.int64),
        values=np.zeros((10_000, 4), np.float32),
    )
    raw = wire.encode_batch(batch, compress_level=0)
    packed = wire.encode_batch(batch, compress_level=6)
    header = wire._HEADER.size
    assert raw.raw_nbytes == packed.raw_nbytes  # same serialization
    assert raw.wire_nbytes == raw.raw_nbytes + header  # header only, no zlib
    assert packed.wire_nbytes < raw.wire_nbytes // 10  # actually compressed
    assert packed.compression_ratio > 10
    assert 0.99 < raw.compression_ratio <= 1.0 + 1e-9
    assert_batches_equal(batch, wire.decode_batch(packed.data))
    assert_batches_equal(batch, wire.decode_batch(raw.data))


def test_incompressible_payload_ships_raw():
    """When zlib does not win, the encoder falls back to the raw payload
    (flag bit clear) rather than shipping a LARGER frame."""
    rng = np.random.default_rng(19)
    batch = random_online_batch(rng, rows=3, d=1)  # tiny: zlib overhead loses
    frame = wire.encode_batch(batch, compress_level=9)
    assert frame.wire_nbytes <= frame.raw_nbytes + wire._HEADER.size
    assert_batches_equal(batch, wire.decode_batch(frame.data))


# -- coalescing ----------------------------------------------------------------


def test_coalesce_groups_adjacent_same_plane_same_table_runs():
    rng = np.random.default_rng(23)
    a1 = random_online_batch(rng, seq=0)
    a2 = random_online_batch(rng, seq=1)
    b1 = random_offline_batch(rng, seq=2)
    b2 = random_offline_batch(rng, seq=3)
    c1 = random_online_batch(rng, seq=4)
    other = ReplicatedBatch(**{**a1.__dict__, "seq": 5, "table": ("other", 1)})
    runs = wire.coalesce([a1, a2, b1, b2, c1, other])
    assert [[b.seq for b in run] for run in runs] == [[0, 1], [2, 3], [4], [5]]
    assert wire.coalesce([]) == []


def test_coalesced_run_decodes_to_same_per_batch_ack_sequence():
    """One frame, N batches: decode yields every batch with its own seq, in
    order — the replica acks exactly what the un-coalesced path acks."""
    rng = np.random.default_rng(29)
    batches = [random_online_batch(rng, seq=i, rows=10) for i in range(5)]
    frame = wire.encode_run(batches)
    assert frame.seqs == (0, 1, 2, 3, 4)
    decoded = wire.decode_frame(frame.data)
    assert [b.seq for b in decoded] == [0, 1, 2, 3, 4]
    for want, got in zip(batches, decoded):
        assert_batches_equal(want, got)
    # and the shared-stream frame is smaller than five separate frames
    separate = sum(wire.encode_batch(b).wire_nbytes for b in batches)
    assert frame.wire_nbytes < separate


def test_encode_run_rejects_mixed_runs():
    rng = np.random.default_rng(31)
    online = random_online_batch(rng, seq=0)
    offline = random_offline_batch(rng, seq=1)
    with pytest.raises(ValueError, match="plane"):
        wire.encode_run([online, offline])
    other_table = ReplicatedBatch(**{**online.__dict__, "table": ("x", 9)})
    with pytest.raises(ValueError, match="plane"):
        wire.encode_run([online, other_table])
    with pytest.raises(ValueError, match="empty"):
        wire.encode_run([])


# -- decode safety -------------------------------------------------------------


def test_decoded_arrays_are_read_only():
    rng = np.random.default_rng(37)
    for batch in (random_online_batch(rng, rows=8), random_offline_batch(rng)):
        decoded = wire.decode_batch(wire.encode_batch(batch).data)
        for a in (decoded.keys, decoded.event_ts, decoded.values):
            assert not a.flags.writeable
        for col in (decoded.columns or {}).values():
            assert not col.flags.writeable


def _restamp_crc(data: bytes) -> bytes:
    """Re-stamp the header checksum over a (mutated) frame, so structural
    corruption reaches the decoder's parsing checks — the CRC would
    otherwise reject the bytes first."""
    h = wire._HEADER
    magic, version, flags, batch_count, raw_len, _ = h.unpack(data[: h.size])
    payload = data[h.size :]
    crc = zlib.crc32(payload, zlib.crc32(h.pack(magic, version, flags, batch_count, raw_len, 0)))
    return h.pack(magic, version, flags, batch_count, raw_len, crc) + payload


def test_decode_rejects_foreign_and_corrupt_bytes():
    rng = np.random.default_rng(41)
    frame = wire.encode_batch(random_online_batch(rng, rows=4))
    with pytest.raises(wire.WireFormatError, match="magic"):
        wire.decode_frame(b"XX" + frame.data[2:])
    with pytest.raises(wire.WireFormatError, match="version"):
        wire.decode_frame(frame.data[:2] + b"\x63" + frame.data[3:])
    with pytest.raises(wire.WireFormatError, match="shorter"):
        wire.decode_frame(frame.data[:10])
    with pytest.raises(wire.WireFormatError):
        wire.decode_frame(frame.data + b"\x00\x01")  # trailing garbage
    with pytest.raises(wire.WireFormatError):
        wire.decode_batch(wire.encode_run([
            random_online_batch(rng, seq=0),
            random_online_batch(rng, seq=1),
        ]).data)  # decode_batch wants exactly one
    # structural corruption must ALSO surface as WireFormatError even when
    # the checksum is valid (a malicious or buggy sender can stamp a
    # correct CRC over garbage) — never leak numpy/unicode internals
    raw = wire.encode_batch(random_online_batch(rng, rows=4), compress_level=0)
    with pytest.raises(wire.WireFormatError, match="malformed"):
        wire.decode_frame(_restamp_crc(raw.data.replace(b"<i8", b"<z8", 1)))
    with pytest.raises(wire.WireFormatError, match="malformed"):
        wire.decode_frame(_restamp_crc(raw.data.replace(b"fs", b"\xff\xfe", 1)))


def test_checksum_rejects_single_byte_flips_anywhere():
    """Any single flipped byte — header or payload, compressed or raw — is
    rejected at the door, BEFORE zlib or record parsing runs.  This is the
    gap the v1 wire had (magic/length checks passed silently-corrupted
    payload arrays straight into replica state), and the gap a
    payload-only checksum would keep: a flipped header bit nothing
    validates, e.g. an undefined ``flags`` bit, decodes "successfully"."""
    rng = np.random.default_rng(47)
    batch = random_online_batch(rng, rows=64, d=4)
    for level in (0, 6):
        data = wire.encode_batch(batch, compress_level=level).data
        h = wire._HEADER.size
        # every header byte: magic/version flips get their own loud error,
        # everything else (flags, counts, lengths, the crc itself) fails
        # the frame checksum
        for pos in range(h):
            corrupted = data[:pos] + bytes([data[pos] ^ 0x40]) + data[pos + 1 :]
            with pytest.raises(wire.WireFormatError):
                wire.decode_frame(corrupted)
        step = max(1, (len(data) - h) // 9)
        for pos in range(h, len(data), step):
            corrupted = data[:pos] + bytes([data[pos] ^ 0x40]) + data[pos + 1 :]
            with pytest.raises(wire.WireFormatError, match="checksum"):
                wire.decode_frame(corrupted)
    # the specific v2-payload-only-crc escape: an undefined flags bit
    flags_pos = 3  # <2sBBIQI: magic(0-1) version(2) flags(3)
    data = wire.encode_batch(batch).data
    bad = data[:flags_pos] + bytes([data[flags_pos] ^ 0x14]) + data[flags_pos + 1 :]
    with pytest.raises(wire.WireFormatError, match="checksum"):
        wire.decode_frame(bad)
    # and even a correctly-stamped frame with undefined flag bits is a
    # protocol error, not something to silently ignore
    with pytest.raises(wire.WireFormatError, match="flag"):
        wire.decode_frame(_restamp_crc(bad))


def test_v1_frames_rejected_loudly():
    """A checksum-less v1 frame must not decode on a v2 receiver: silent
    corruption is worse than a loud version mismatch on a mixed link."""
    rng = np.random.default_rng(53)
    data = wire.encode_batch(random_online_batch(rng, rows=4)).data
    v1 = data[:2] + b"\x01" + data[3:]
    with pytest.raises(wire.WireFormatError, match="version"):
        wire.decode_frame(v1)


def test_probe_frame_roundtrip():
    """The zero-batch probe is the smallest well-formed frame: header only,
    decodes to no batches, and still carries a verifiable checksum."""
    probe = wire.encode_probe()
    assert probe.wire_nbytes == wire.HEADER_SIZE
    assert probe.seqs == () and probe.rows == 0
    assert probe.table == wire.PROBE_TABLE
    assert wire.decode_frame(probe.data) == []
    flipped = probe.data[:-1] + bytes([probe.data[-1] ^ 0x01])
    with pytest.raises(wire.WireFormatError, match="checksum"):
        wire.decode_frame(flipped)


# -- transport end-to-end ------------------------------------------------------


def test_shipped_state_survives_the_wire_hop():
    """A real home-merge batch shipped through encode→WAN→decode applies to
    a byte-identical replica — the transport changes representation, never
    content — and the accounting reflects measured wire frames."""
    spec = make_spec()
    topo = GeoTopology(
        regions={"h": Region("h"), "r": Region("r")},
        cross_region_latency_ms=40.0,
    )
    home = OnlineStore(num_partitions=4)
    log = ReplicationLog()
    repl = GeoReplicator(home, topology=topo, home_region="h", log=log)
    replica = OnlineStore(num_partitions=4)
    repl.add_replica("r", replica)
    rng = np.random.default_rng(43)
    for i in range(4):
        home.merge(spec, make_frame(rng, 100, 40, 60 * (i + 1)), 5_000 + i)
    repl.drain()
    da = home.dump_all(spec.name, spec.version)
    db = replica.dump_all(spec.name, spec.version)
    for name in da.names:
        np.testing.assert_array_equal(da[name], db[name], err_msg=name)
    ship = repl.shipped["r"]
    assert ship.batches == 4
    assert ship.frames == 1  # one table, one plane: the run coalesced
    assert 0 < ship.bytes <= ship.raw_bytes
    assert ship.ms > 0  # the WAN model priced the wire size
