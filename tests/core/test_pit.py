"""Point-in-time retrieval (paper §4.4): leakage freedom as a property."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.assets import Entity, Feature, FeatureSetSpec
from repro.core.dsl import UDFTransform
from repro.core.offline_store import OfflineStore
from repro.core.pit import get_offline_features, pit_join_feature_set
from repro.core.table import Table


def make_spec(delay=0):
    return FeatureSetSpec(
        name="fs",
        version=1,
        entity=Entity("cust", ("entity_id",)),
        features=(Feature("val"),),
        source_name="src",
        transform=UDFTransform(lambda df, ctx: df, name="id"),
        expected_delay=delay,
    )


def history_table(ids, ev, cr, vals):
    return Table(
        {
            "__key__": np.asarray(ids, np.int64),
            "entity_id": np.asarray(ids, np.int64),
            "event_ts": np.asarray(ev, np.int64),
            "creation_ts": np.asarray(cr, np.int64),
            "val": np.asarray(vals, np.float32),
        }
    )


records = st.lists(
    st.tuples(st.integers(0, 5), st.integers(0, 1000)),
    min_size=1,
    max_size=60,
)
queries = st.lists(
    st.tuples(st.integers(0, 7), st.integers(0, 1100)),
    min_size=1,
    max_size=40,
)


@pytest.mark.slow
@settings(max_examples=60, deadline=None)
@given(records, queries, st.sampled_from([0, 7, 50]), st.booleans())
def test_property_no_leakage_and_nearest_past(recs, qs, delay, use_kernel):
    """For every query: (a) the joined record's event_ts <= ts0 - delay —
    NEVER the future; (b) it is the NEAREST past (max event_ts among
    eligible); (c) found=False iff no eligible record exists."""
    spec = make_spec(delay)
    ids = [r[0] for r in recs]
    evs = [r[1] for r in recs]
    hist = history_table(ids, evs, [e + 1 for e in evs], evs)

    q_ids = np.asarray([q[0] for q in qs], np.int64)
    q_ts = np.asarray([q[1] for q in qs], np.int64)
    res = pit_join_feature_set([q_ids], q_ts, spec, hist, use_kernel=use_kernel)

    for i in range(len(qs)):
        eligible = [
            e for (k, e) in zip(ids, evs) if k == q_ids[i] and e <= q_ts[i] - delay
        ]
        if eligible:
            assert res.found[i]
            assert res.event_ts[i] == max(eligible)          # nearest past
            assert res.event_ts[i] <= q_ts[i] - delay        # no leakage
            assert res.values["val"][i] == float(max(eligible))
        else:
            assert not res.found[i]


def test_tie_break_prefers_latest_creation():
    """Same event_ts twice (re-materialized): the later creation wins,
    matching the §4.5 record ordering."""
    spec = make_spec()
    hist = history_table([1, 1], [100, 100], [200, 300], [1.0, 2.0])
    res = pit_join_feature_set(
        [np.array([1])], np.array([150]), spec, hist, use_kernel=False
    )
    assert res.found[0] and res.values["val"][0] == 2.0


def test_multi_feature_set_spine_join():
    store = OfflineStore(num_shards=2)
    spec_a, spec_b = make_spec(), None
    import dataclasses

    spec_b = dataclasses.replace(make_spec(), name="fs_b")
    for spec, base in ((spec_a, 0.0), (spec_b, 100.0)):
        store.register(spec)
        store.merge(
            spec,
            Table(
                {
                    "entity_id": np.arange(4, dtype=np.int64),
                    "ts": np.full(4, 10, np.int64),
                    "val": np.arange(4, dtype=np.float32) + base,
                }
            ),
            creation_ts=50,
        )
    spine = Table(
        {
            "entity_id": np.arange(4, dtype=np.int64),
            "ts": np.full(4, 100, np.int64),
        }
    )
    out = get_offline_features(store, spine, [spec_a, spec_b], use_kernel=False)
    assert np.allclose(out["fs:v1:val"], [0, 1, 2, 3])
    assert np.allclose(out["fs_b:v1:val"], [100, 101, 102, 103])
    assert out["fs:v1:__found__"].all() and out["fs_b:v1:__found__"].all()


def test_kernel_vs_oracle_agree_large():
    rng = np.random.default_rng(3)
    n, q = 500, 300
    spec = make_spec(delay=5)
    ids = rng.integers(0, 40, n)
    evs = rng.integers(0, 100_000, n)
    hist = history_table(ids, evs, evs + 1, evs.astype(np.float32))
    q_ids = rng.integers(0, 45, q).astype(np.int64)
    q_ts = rng.integers(0, 110_000, q).astype(np.int64)
    a = pit_join_feature_set([q_ids], q_ts, spec, hist, use_kernel=True)
    b = pit_join_feature_set([q_ids], q_ts, spec, hist, use_kernel=False)
    assert np.array_equal(a.found, b.found)
    assert np.array_equal(a.event_ts, b.event_ts)
    assert np.allclose(a.values["val"], b.values["val"])
