"""Eventual consistency + bootstrap (paper §4.5.2, §4.5.4, §4.5.5):
failures between the two merges converge under retry; late-enabled stores
bootstrap from the other."""

import numpy as np

from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
from repro.core.dsl import DslTransform, RollingAgg
from repro.core.featurestore import FeatureStore
from repro.data.sources import SyntheticEventSource

H = 3_600_000


def build_store(*, online=True, offline=True, name="fs-c"):
    fs = FeatureStore(name)
    src = SyntheticEventSource("txn", seed=3, num_entities=15, events_per_bucket=25)
    fs.register_source(src)
    spec = FeatureSetSpec(
        name="stats",
        version=1,
        entity=Entity("cust", ("entity_id",)),
        features=(Feature("s1h"),),
        source_name="txn",
        transform=DslTransform(
            "entity_id", "ts", [RollingAgg("s1h", "amount", H, "sum")]
        ),
        source_lookback=H,
        materialization=MaterializationSettings(
            offline_enabled=offline, online_enabled=online, schedule_interval=H
        ),
    )
    fs.create_feature_set(spec)
    return fs, spec


def test_happy_path_consistent():
    fs, spec = build_store()
    fs.tick(now=4 * H)
    rep = fs.check_consistency("stats", 1)
    assert rep.consistent, rep.summary()
    assert rep.checked_ids > 0


def test_failure_between_merges_converges_with_retry():
    """§4.5.4: a job can fail after the offline merge but before the online
    merge; the retry re-runs BOTH merges; idempotence makes that safe and
    the stores converge."""
    fs, spec = build_store()
    fs.faults.arm("between_merges", times=1)
    stats = fs.tick(now=2 * H)
    assert stats["retried"] >= 1 and stats["failed"] == 0
    rep = fs.check_consistency("stats", 1)
    assert rep.consistent, rep.summary()
    # dedup counters prove the retry re-merged idempotently
    assert fs.offline.rows_deduped > 0 or fs.online.noops >= 0


def test_repeated_failures_alert_but_keep_invariants():
    fs, spec = build_store()
    fs.faults.arm("after_compute", times=3)  # kills one job permanently
    stats = fs.tick(now=2 * H)
    assert stats["failed"] == 1
    assert fs.monitor.alerts
    # the failed window is NOT marked materialized (§4.3 disambiguation)
    iv = fs.scheduler.data_state[("stats", 1)]
    assert iv.total_length() == H  # only the surviving job's window


def test_bootstrap_offline_to_online():
    """§4.5.5: enable online later; bootstrap = latest record per ID."""
    fs, spec = build_store(online=False)
    fs.tick(now=4 * H)
    assert not fs.online.has("stats", 1)
    n = fs.enable_online("stats", 1)
    assert n > 0
    rep = fs.check_consistency("stats", 1)
    assert rep.consistent, rep.summary()


def test_bootstrap_online_to_offline():
    """§4.5.5 other direction: dump everything online into offline."""
    fs, spec = build_store(offline=False)
    fs.tick(now=3 * H)
    assert fs.offline.num_rows("stats", 1) == 0
    n = fs.enable_offline("stats", 1)
    assert n == fs.online.num_records("stats", 1)
    rep = fs.check_consistency("stats", 1)
    # after online->offline bootstrap, every online record exists offline
    assert not rep.missing_offline


def test_bootstrap_idempotent():
    fs, spec = build_store(online=False)
    fs.tick(now=3 * H)
    n1 = fs.enable_online("stats", 1)
    n2 = fs.enable_online("stats", 1)  # replay: Algorithm 2 no-ops
    assert n1 == n2
    assert fs.check_consistency("stats", 1).consistent


def test_online_offline_same_values_no_skew():
    """§1 'avoid offline and online data skew': online GET equals the
    offline PIT value at the same observation time."""
    from repro.core.table import Table

    fs, spec = build_store()
    fs.tick(now=4 * H)
    ids = np.arange(10, dtype=np.int64)
    online_vals, online_found = fs.get_online_features("stats", 1, [ids])
    spine = Table({"entity_id": ids, "ts": np.full(10, fs.clock(), np.int64)})
    off = fs.get_offline_features(spine, [("stats", 1)], use_kernel=False)
    for i in range(10):
        assert online_found[i] == off["stats:v1:__found__"][i]
        if online_found[i]:
            np.testing.assert_allclose(
                online_vals[i, 0], off["stats:v1:s1h"][i], rtol=1e-6
            )
