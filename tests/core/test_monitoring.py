"""§2.1/§3.1.2 health monitoring: metrics, alerts, staleness SLA — and the
bounded-histogram sketch the serving front's per-stage latencies ride on."""

import math

import numpy as np
import pytest

from repro.core.monitoring import BoundedHistogram, HealthMonitor, Metrics

# BoundedHistogram guarantees relative accuracy ~``resolution`` (5% default);
# the assertions below allow a little slack over one bucket width
RTOL = 0.06


def test_counters_gauges_histograms():
    m = Metrics()
    m.inc("jobs")
    m.inc("jobs", 2)
    m.set_gauge("depth", 7)
    for v in range(100):
        m.observe("lat", float(v))
    snap = m.snapshot()
    assert snap["counters"]["jobs"] == 3
    assert snap["gauges"]["depth"] == 7
    # histogram quantiles are sketched (bounded memory), not exact
    assert snap["histograms"]["lat"]["p50"] == pytest.approx(50.0, rel=RTOL)
    assert snap["histograms"]["lat"]["max"] == 99.0
    assert snap["histograms"]["lat"]["n"] == 100


# -- BoundedHistogram: quantile accuracy vs numpy on known distributions ------


def _assert_quantiles_close(h: BoundedHistogram, samples: np.ndarray) -> None:
    for q in (0.10, 0.50, 0.90, 0.99, 0.999):
        exact = float(np.quantile(samples, q, method="inverted_cdf"))
        got = h.quantile(q)
        assert got == pytest.approx(exact, rel=RTOL), (q, got, exact)


def test_bounded_histogram_uniform_vs_numpy():
    rng = np.random.default_rng(7)
    samples = rng.uniform(1.0, 1e4, 50_000)
    h = BoundedHistogram()
    for v in samples:
        h.observe(v)
    _assert_quantiles_close(h, samples)
    assert h.n == len(samples)
    assert h.mean == pytest.approx(samples.mean(), rel=1e-9)
    assert h.vmin == samples.min() and h.vmax == samples.max()


def test_bounded_histogram_lognormal_vs_numpy():
    # heavy tail over ~6 decades — the realistic latency shape
    rng = np.random.default_rng(11)
    samples = np.exp(rng.normal(3.0, 2.0, 50_000))
    h = BoundedHistogram()
    h.observe_batch(samples)  # vectorized path must match scalar indexing
    _assert_quantiles_close(h, samples)


def test_bounded_histogram_batch_matches_scalar():
    rng = np.random.default_rng(3)
    samples = rng.exponential(250.0, 10_000) + 0.5
    a, b = BoundedHistogram(), BoundedHistogram()
    for v in samples:
        a.observe(v)
    b.observe_batch(samples)
    np.testing.assert_array_equal(a.counts, b.counts)
    assert a.n == b.n and a.vmin == b.vmin and a.vmax == b.vmax
    assert a.total == pytest.approx(b.total, rel=1e-9)


def test_bounded_histogram_memory_is_bounded():
    h = BoundedHistogram()
    nbuckets = len(h.counts)
    h.observe_batch(np.random.default_rng(0).uniform(0.1, 1e6, 200_000))
    assert len(h.counts) == nbuckets  # storage never grows with samples


def test_bounded_histogram_edges():
    h = BoundedHistogram(lo=1.0, hi=1e3)
    assert math.isnan(h.quantile(0.5))  # empty
    h.observe(0.0)  # below lo clamps into the first bucket
    h.observe(1e9)  # above hi clamps into the last
    assert h.quantile(0.0) == 0.0  # reported values clamp to observed range
    # an above-hi outlier lands in the overflow bucket: reported near hi,
    # never beyond the observed max (accuracy only guaranteed inside [lo, hi))
    assert h.quantile(1.0) == pytest.approx(1e3, rel=RTOL)
    assert h.quantile(1.0) <= h.vmax
    single = BoundedHistogram()
    single.observe(42.0)
    for q in (0.01, 0.5, 0.999):
        assert single.quantile(q) == 42.0


def test_alert_hook_fires():
    got = []
    hm = HealthMonitor(alert_hook=got.append)
    hm.alert("region down")
    assert got == ["region down"] and hm.alerts == ["region down"]


def test_health_judgement():
    hm = HealthMonitor()
    for _ in range(99):
        hm.record_job(success=True)
    assert hm.healthy()
    hm2 = HealthMonitor()
    for _ in range(5):
        hm2.record_job(success=False)
    assert not hm2.healthy()
    # retries are counted separately (visibility into §4.5.4 convergence)
    hm3 = HealthMonitor()
    hm3.record_job(success=False, retried=True)
    assert hm3.system.counters["jobs_retried"] == 1


def test_staleness_gauge_per_feature_set():
    hm = HealthMonitor()
    hm.record_staleness("act", 1, 120_000)
    hm.record_staleness("act", 2, None)  # unknown: no gauge
    snap = hm.system.snapshot()
    assert snap["gauges"]["staleness_ms/act:v1"] == 120_000
    assert "staleness_ms/act:v2" not in snap["gauges"]


def test_staleness_reflects_schedule_lag():
    """End-to-end: staleness == now - materialized high-water mark."""
    from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
    from repro.core.dsl import DslTransform, RollingAgg
    from repro.core.featurestore import FeatureStore
    from repro.data.sources import SyntheticEventSource

    HOUR = 3_600_000
    fs = FeatureStore("stale", interpret=True)
    fs.register_source(SyntheticEventSource("tx", num_entities=4,
                                            events_per_bucket=10))
    fs.create_feature_set(FeatureSetSpec(
        name="act", version=1,
        entity=Entity("customer", ("entity_id",)),
        features=(Feature("s1", "float32"),),
        source_name="tx",
        transform=DslTransform("entity_id", "ts",
                               [RollingAgg("s1", "amount", HOUR, "sum")]),
        timestamp_col="ts", source_lookback=HOUR,
        materialization=MaterializationSettings(
            offline_enabled=True, online_enabled=False,
            schedule_interval=HOUR,
        ),
    ))
    fs.tick(now=3 * HOUR)
    # clock at 3h30 without a new tick-able hour: staleness = 30min... the
    # cadence materializes up to 3h, so at now=3h staleness is 0
    snap = fs.monitor.system.snapshot()
    assert snap["gauges"]["staleness_ms/act:v1"] == 0
    fs.advance_clock(3 * HOUR + 30 * 60_000)
    fs.tick()
    snap = fs.monitor.system.snapshot()
    assert snap["gauges"]["staleness_ms/act:v1"] == 30 * 60_000
