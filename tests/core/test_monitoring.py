"""§2.1/§3.1.2 health monitoring: metrics, alerts, staleness SLA."""


from repro.core.monitoring import HealthMonitor, Metrics


def test_counters_gauges_histograms():
    m = Metrics()
    m.inc("jobs")
    m.inc("jobs", 2)
    m.set_gauge("depth", 7)
    for v in range(100):
        m.observe("lat", float(v))
    snap = m.snapshot()
    assert snap["counters"]["jobs"] == 3
    assert snap["gauges"]["depth"] == 7
    assert snap["histograms"]["lat"]["p50"] == 50.0
    assert snap["histograms"]["lat"]["n"] == 100


def test_alert_hook_fires():
    got = []
    hm = HealthMonitor(alert_hook=got.append)
    hm.alert("region down")
    assert got == ["region down"] and hm.alerts == ["region down"]


def test_health_judgement():
    hm = HealthMonitor()
    for _ in range(99):
        hm.record_job(success=True)
    assert hm.healthy()
    hm2 = HealthMonitor()
    for _ in range(5):
        hm2.record_job(success=False)
    assert not hm2.healthy()
    # retries are counted separately (visibility into §4.5.4 convergence)
    hm3 = HealthMonitor()
    hm3.record_job(success=False, retried=True)
    assert hm3.system.counters["jobs_retried"] == 1


def test_staleness_gauge_per_feature_set():
    hm = HealthMonitor()
    hm.record_staleness("act", 1, 120_000)
    hm.record_staleness("act", 2, None)  # unknown: no gauge
    snap = hm.system.snapshot()
    assert snap["gauges"]["staleness_ms/act:v1"] == 120_000
    assert "staleness_ms/act:v2" not in snap["gauges"]


def test_staleness_reflects_schedule_lag():
    """End-to-end: staleness == now - materialized high-water mark."""
    from repro.core.assets import Entity, Feature, FeatureSetSpec, MaterializationSettings
    from repro.core.dsl import DslTransform, RollingAgg
    from repro.core.featurestore import FeatureStore
    from repro.data.sources import SyntheticEventSource

    HOUR = 3_600_000
    fs = FeatureStore("stale", interpret=True)
    fs.register_source(SyntheticEventSource("tx", num_entities=4,
                                            events_per_bucket=10))
    fs.create_feature_set(FeatureSetSpec(
        name="act", version=1,
        entity=Entity("customer", ("entity_id",)),
        features=(Feature("s1", "float32"),),
        source_name="tx",
        transform=DslTransform("entity_id", "ts",
                               [RollingAgg("s1", "amount", HOUR, "sum")]),
        timestamp_col="ts", source_lookback=HOUR,
        materialization=MaterializationSettings(
            offline_enabled=True, online_enabled=False,
            schedule_interval=HOUR,
        ),
    ))
    fs.tick(now=3 * HOUR)
    # clock at 3h30 without a new tick-able hour: staleness = 30min... the
    # cadence materializes up to 3h, so at now=3h staleness is 0
    snap = fs.monitor.system.snapshot()
    assert snap["gauges"]["staleness_ms/act:v1"] == 0
    fs.advance_clock(3 * HOUR + 30 * 60_000)
    fs.tick()
    snap = fs.monitor.system.snapshot()
    assert snap["gauges"]["staleness_ms/act:v1"] == 30 * 60_000
